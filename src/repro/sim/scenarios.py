"""ScenarioSpec: declarative fault scenarios + the curated library.

A :class:`ScenarioSpec` composes a testbed topology, a workload, a seeded
:class:`~repro.sim.faults.FaultPlan`, and the expected diagnosis into one
inert, reproducible object (the scenario-level sibling of
:class:`~repro.core.session.TraceSpec`).  ``run()`` closes the paper's loop
end to end:

    simulate (faults injected) -> ad-hoc logs -> TraceSpec weave
        -> ``core.analysis.diagnose`` -> findings vs expectation

The curated library (``SCENARIOS``) ships one named scenario per fault
class plus a healthy baseline; ``docs/scenarios.md`` is the cookbook that
documents each one's trace signature and the rule that catches it.

    from repro.sim.scenarios import get_scenario

    run = get_scenario("throttled_chip").run()
    print(run.report())
    assert run.ok           # expected fault classes ⊆ diagnosed classes

Reproducibility contract: the DES kernel is deterministic and every random
draw comes from the plan's seeded streams, so the same scenario + seed
yields byte-identical simulator logs *and* byte-identical span JSONL
(``run.span_jsonl``) — asserted property-style in ``tests/test_scenarios.py``.
"""
from __future__ import annotations

import io
import os
import tempfile
import time
from dataclasses import dataclass, field, fields as dataclasses_fields, replace
from typing import Callable, Dict, List, Optional, Tuple

from .cluster import ClusterOrchestrator
from .mitigation import (
    MitigationConflictError,
    MitigationPolicy,
    make_mitigation,
    mitigation_type,
)
from .faults import (
    ChunkReorder,
    ClockDrift,
    ClockStep,
    DeviceSlowdown,
    FaultPlan,
    FaultSpec,
    HostPause,
    LinkDegradation,
    LinkLoss,
    StragglerPod,
)
from .topology import scale
from .workload import ProgramSpec, Workload, make_workload, synthetic_program
from .workloads.rpc import rpc_handler_program

# the span-assembly modes ScenarioSpec.run / run_sweep / the trace CLI
# accept — the single source of truth callers validate against
WEAVE_MODES: Tuple[str, ...] = ("post", "inline", "sharded", "columnar")

PS_PER_MS = 1_000_000_000


def _default_program() -> ProgramSpec:
    """Small 2-layer FSDP-ish step: per-layer all-gather + compute on the
    ICI rings, one cross-pod gradient all-reduce on the DCN."""
    return synthetic_program(
        n_layers=2, layer_flops=5e11, layer_bytes=2e8, grad_bytes=1e8
    )


@dataclass(frozen=True)
class ScenarioSpec:
    """Topology + workload + fault plan + expected diagnosis, declaratively.

    ``workload`` names a registered workload type (``collective`` — the
    classic training step — or any of ``repro.sim.workloads``: ``rpc``,
    ``storage``, ``pipeline``); ``workload_params`` are extra knobs for it
    as an inert ``(key, value)`` tuple.  Every fault class composes with
    every workload: the same plan schedules regardless of what drives the
    cluster.

    ``mitigation`` names a registered remediation policy
    (``repro.sim.mitigation``); it attaches *between* fault scheduling and
    the workload drive, so its trigger loop competes on the same fault
    trace the workload experiences.  The default ``do_nothing`` baseline
    is a strict no-op: such runs are byte-identical to pre-mitigation-era
    runs.
    """

    name: str
    description: str
    faults: Tuple[FaultSpec, ...] = ()
    expected: Optional[Tuple[str, ...]] = None    # None -> derived from faults
    signature: str = ""                           # trace signature, for the cookbook
    seed: int = 0
    n_steps: int = 2
    n_pods: int = 2
    chips_per_pod: int = 4
    fabric: str = "mesh"                          # "mesh" (full DCN) | "fat-tree"
    program: Callable[[], ProgramSpec] = _default_program
    clock_read_every_ps: int = 2 * PS_PER_MS
    clock_reads: int = 30
    workload: str = "collective"                  # registered workload type
    workload_params: Tuple[Tuple[str, object], ...] = ()
    mitigation: str = "do_nothing"                # registered mitigation policy
    mitigation_params: Tuple[Tuple[str, object], ...] = ()
    fault_magnitude: float = 1.0                  # scales every fault's intensity

    @property
    def expected_classes(self) -> Tuple[str, ...]:
        """Fault classes diagnose() must name (override via ``expected``)."""
        if self.expected is not None:
            return self.expected
        return tuple(self.fault_plan().fault_classes())

    @property
    def expected_components(self) -> Dict[str, Tuple[str, ...]]:
        """Per fault class, the component names a correct diagnosis pins it
        on (each fault's :attr:`~repro.sim.faults.FaultSpec.target`) —
        ground truth for the evaluation harness's component-naming score."""
        out: Dict[str, List[str]] = {}
        for f in self.faults:
            targets = out.setdefault(f.fault_class, [])
            if f.target not in targets:
                targets.append(f.target)
        return {cls: tuple(ts) for cls, ts in out.items()}

    def fault_plan(self, seed: Optional[int] = None) -> FaultPlan:
        plan = FaultPlan(self.faults, self.seed if seed is None else seed)
        return plan.scaled(self.fault_magnitude)

    def with_seed(self, seed: int) -> "ScenarioSpec":
        return replace(self, seed=seed)

    def make_workload(self, seed: Optional[int] = None) -> Workload:
        """Instantiate this scenario's workload (standard knobs + params).

        ``workload_params`` naming one of the five standard knobs
        (``program``, ``n_steps``, ``seed``, ``clock_read_every_ps``,
        ``clock_reads``) overrides the scenario-level value; unknown
        knobs raise ``TypeError`` — the same no-silent-ignore contract
        :meth:`run` enforces for its kwargs."""
        params = dict(
            program=self.program(),
            n_steps=self.n_steps,
            seed=self.seed if seed is None else seed,
            clock_read_every_ps=self.clock_read_every_ps,
            clock_reads=self.clock_reads,
        )
        params.update(dict(self.workload_params))
        return make_workload(self.workload, **params)

    def make_mitigation(self, seed: Optional[int] = None) -> MitigationPolicy:
        """Instantiate this scenario's mitigation policy (seed + params).

        The policy inherits the scenario seed (its trigger loop draws from
        a third RNG-stream family, disjoint from fault and workload
        streams); ``mitigation_params`` are extra per-policy knobs, with
        the same no-silent-ignore contract as ``workload_params``."""
        params = dict(seed=self.seed if seed is None else seed)
        params.update(dict(self.mitigation_params))
        return make_mitigation(self.mitigation, **params)

    # -- execution ---------------------------------------------------------------

    def simulate(
        self,
        outdir: Optional[str],
        seed: Optional[int] = None,
        structured: bool = False,
        sink=None,
    ) -> ClusterOrchestrator:
        """Run only the full-system simulation; logs land in ``outdir``
        (text mode), stay in memory as structured event records
        (``structured=True``, the zero-parse fast path), or stream straight
        into an inline weaver (``sink=``, a
        :class:`~repro.core.streaming.StreamingWeaver`).  The scenario's
        registered workload drives the cluster (clock telemetry — offsets
        vs the sim's ground-truth global clock — is part of every
        workload's drive)."""
        topo = scale(
            pods=self.n_pods, chips_per_pod=self.chips_per_pod, fabric=self.fabric
        )
        cluster = ClusterOrchestrator(topo, outdir=outdir, structured=structured, sink=sink)
        self.fault_plan(seed).schedule(cluster)
        # the policy arms after faults are scheduled and before the workload
        # drives: its trigger loop competes on the same fault trace
        self.make_mitigation(seed=seed).attach(cluster)
        self.make_workload(seed=seed).drive(cluster)
        cluster.run()
        return cluster

    def run(
        self,
        outdir: Optional[str] = None,
        seed: Optional[int] = None,
        exporters: Tuple = (),
        structured: bool = False,
        weave: str = "post",
        jobs: int = 1,
        **overrides,
    ) -> "ScenarioRun":
        """Simulate, weave, diagnose.

        ``outdir=None`` simulates into a temporary directory that is removed
        after weaving; pass a path to keep the raw simulator logs.  Extra
        ``exporters`` (Chrome trace, Jaeger, ...) stream alongside the
        always-on in-memory SpanJSONL exporter.

        ``structured=True`` takes the zero-parse fast path: simulators hand
        ``Event`` records straight to the weavers (no text logs, no
        ``outdir``), producing byte-identical SpanJSONL to the text path
        (asserted in ``tests/test_structured.py``).

        ``weave`` selects how spans are assembled:

        * ``"post"`` (default) — post-hoc weave through a TraceSpec, over
          text logs or structured records.
        * ``"inline"`` — spans weave *during* the simulation
          (:class:`~repro.core.streaming.StreamingWeaver`); no logs, no
          parse, no replay.  SpanJSONL is byte-identical to ``"post"``
          (asserted in ``tests/test_streaming_weave.py``).
        * ``"sharded"`` — inline weave plus a ``jobs``-way parallel export:
          workers re-simulate deterministically and export disjoint
          ``trace_id % jobs`` shards, merged back in canonical order via
          :func:`~repro.core.exporters.merge_span_jsonl`.  Byte-identical
          to serial for any ``jobs``.
        * ``"columnar"`` — inline weave with the net stream (the dominant
          record class) kept in column arrays end to end: no Span objects
          on the hot path, vectorized finish, SpanJSONL rendered straight
          from the arrays (byte-identical again); Span objects
          materialize lazily only for diagnose and extra exporters.

        Any extra keyword argument must name a :class:`ScenarioSpec` field
        (``run(workload="rpc")``, ``run(n_pods=4)``): it overrides that
        field for this run.  Anything else raises ``TypeError`` — unknown
        kwargs are never silently ignored.
        """
        # late import: repro.core must not depend on repro.sim
        from ..core import SourceSpec, SpanJSONLExporter, TraceSpec, reset_ids
        from ..core.analysis import diagnose

        if weave not in WEAVE_MODES:
            raise ValueError(
                f"unknown weave mode {weave!r}; expected one of "
                f"{', '.join(repr(m) for m in WEAVE_MODES)}"
            )
        if weave != "post" and structured:
            raise ValueError(
                "structured=True is a post-hoc capture mode; it cannot be "
                "combined with weave='inline'/'sharded'/'columnar' (inline "
                "weaving keeps no record buffer to replay)"
            )
        if weave != "post" and outdir is not None:
            raise ValueError(
                "inline weaving writes no simulator logs; keep outdir only "
                "with the post-hoc path (weave='post')"
            )
        if jobs < 1:
            raise ValueError(f"jobs must be >= 1, got {jobs}")
        if jobs != 1 and weave != "sharded":
            raise ValueError(
                f"jobs={jobs} only applies to weave='sharded' "
                f"(got weave={weave!r}); not silently ignoring it"
            )

        if overrides:
            fields = {f.name for f in dataclasses_fields(ScenarioSpec)}
            unknown = sorted(set(overrides) - fields)
            if unknown:
                raise TypeError(
                    f"ScenarioSpec.run() got unexpected keyword arguments "
                    f"{unknown}; valid field overrides: {sorted(fields)}"
                )
            if (overrides.get("workload", self.workload) != self.workload
                    and "workload_params" not in overrides):
                # per-type knobs don't transfer across workload types: a
                # cross-type override starts from the new type's defaults
                overrides["workload_params"] = ()
            if (overrides.get("mitigation", self.mitigation) != self.mitigation
                    and "mitigation_params" not in overrides):
                # same contract for mitigations: per-policy knobs reset
                overrides["mitigation_params"] = ()
            candidate = replace(self, **overrides)
            if "mitigation" in overrides:
                cls = mitigation_type(overrides["mitigation"])
                masked = sorted(set(cls.masks) & set(candidate.expected_classes))
                if masked:
                    raise MitigationConflictError(
                        f"mitigation {overrides['mitigation']!r} masks the "
                        f"diagnosis of {masked}, which scenario "
                        f"{self.name!r} asserts; override expected= in the "
                        f"same call to opt in, or construct the ScenarioSpec "
                        f"directly (the sweep mitigations axis scores "
                        f"policies without asserting diagnosis)"
                    )
            return candidate.run(
                outdir=outdir, seed=seed, exporters=exporters,
                structured=structured, weave=weave, jobs=jobs,
            )

        plan = self.fault_plan(seed)

        if weave != "post":
            from ..core.session import stream_to
            from ..core.streaming import InlineTraceSession, StreamingWeaver

            sw = StreamingWeaver(columnar=(weave == "columnar"))
            cluster = self.simulate(None, seed=plan.seed, sink=sw)
            session = InlineTraceSession(sw)
            buf = io.StringIO()
            if weave == "columnar":
                # render JSONL array-native; Span objects materialize only
                # because diagnose() below walks the graph (and for any
                # extra exporters)
                woven = sw.finish_columns()
                woven.render_jsonl(buf)
                spans = woven.to_spans()
                if exporters:
                    stream_to(spans, exporters)
            elif weave == "inline":
                spans = sw.finish()
                stream_to(spans, (SpanJSONLExporter(buf), *exporters))
            else:
                spans = sw.finish()
                self._export_sharded(spans, plan.seed, jobs, buf)
                if exporters:
                    stream_to(spans, exporters)
            t0 = time.perf_counter()
            diagnosis = diagnose(spans)
            diag_wall_s = time.perf_counter() - t0
            return ScenarioRun(
                scenario=self,
                plan=plan,
                cluster=cluster,
                session=session,
                spans=spans,
                diagnosis=diagnosis,
                span_jsonl=buf.getvalue(),
                outdir=None,
                diag_wall_s=diag_wall_s,
            )

        tmp = None
        if outdir is None and not structured:
            tmp = tempfile.TemporaryDirectory(prefix=f"scenario-{self.name}-")
            outdir = tmp.name
        try:
            cluster = self.simulate(outdir, seed=plan.seed, structured=structured)
            # deterministic ids => same seed reproduces byte-identical JSONL
            reset_ids()
            buf = io.StringIO()
            if structured:
                sources = [
                    SourceSpec(sim_type=st, events=evs)
                    for st, evs in cluster.structured_sources()
                ]
            else:
                sources = [
                    SourceSpec(sim_type=st, paths=ps) if len(ps) > 1
                    else SourceSpec(sim_type=st, path=ps[0])
                    for st, ps in sorted(cluster.log_paths().items())
                ]
            spec = TraceSpec(
                sources=sources,
                exporters=[SpanJSONLExporter(buf), *exporters],
            )
            session = spec.run()
        finally:
            if tmp is not None:
                tmp.cleanup()
                outdir = None
        t0 = time.perf_counter()
        diagnosis = diagnose(session.spans)
        diag_wall_s = time.perf_counter() - t0
        return ScenarioRun(
            scenario=self,
            plan=plan,
            cluster=cluster,
            session=session,
            spans=session.spans,
            diagnosis=diagnosis,
            span_jsonl=buf.getvalue(),
            outdir=outdir,
            diag_wall_s=diag_wall_s,
        )

    def _export_sharded(self, spans, seed: int, jobs: int, buf) -> None:
        """``jobs``-way parallel SpanJSONL export of one inline-woven run.

        The parent already holds the full span list; workers re-simulate
        the same seed (the kernel is deterministic, so they weave identical
        spans) and export only their ``trace_id % jobs`` shard, while the
        parent exports shard 0.  Shards partition the id space, so the
        ``merge_span_jsonl`` heap-merge — keyed ``(trace_id, start_us,
        span_id)``, exactly the engine's canonical export order — never has
        to compare within a trace across shards, and the merged bytes equal
        the serial export for any ``jobs``."""
        from ..core.exporters import merge_span_jsonl

        with tempfile.TemporaryDirectory(prefix=f"shards-{self.name}-") as td:
            paths = [os.path.join(td, f"shard{i:03d}.jsonl") for i in range(jobs)]
            if jobs > 1:
                import multiprocessing

                ctx = multiprocessing.get_context("fork")
                work = [(self, seed, jobs, i, paths[i]) for i in range(1, jobs)]
                with ctx.Pool(processes=min(jobs - 1, os.cpu_count() or 1)) as pool:
                    result = pool.map_async(_weave_shard, work)
                    _export_shard(spans, jobs, 0, paths[0])
                    result.get()
            else:
                _export_shard(spans, jobs, 0, paths[0])
            merged = os.path.join(td, "merged.jsonl")
            merge_span_jsonl(paths, merged, disambiguate=False)
            # chunked copy: never hold the merged file in memory at once
            import shutil

            with open(merged) as f:
                shutil.copyfileobj(f, buf, 1 << 20)


def _export_shard(spans, n_shards: int, shard: int, path: str) -> None:
    from ..core.exporters import SpanJSONLExporter
    from ..core.session import stream_to

    stream_to(
        [s for s in spans if s.context.trace_id % n_shards == shard],
        (SpanJSONLExporter(path),),
    )


def _weave_shard(packed) -> str:
    """Pool worker (module-level for picklability): re-simulate the cell
    deterministically, weave inline, export this worker's trace_id shard."""
    spec, seed, n_shards, shard, path = packed
    from ..core.streaming import StreamingWeaver

    sw = StreamingWeaver()
    spec.simulate(None, seed=seed, sink=sw)
    _export_shard(sw.finish(), n_shards, shard, path)
    return path


@dataclass
class ScenarioRun:
    """Everything one scenario execution produced."""

    scenario: ScenarioSpec
    plan: FaultPlan
    cluster: ClusterOrchestrator
    session: object                    # TraceSession
    spans: List
    diagnosis: object                  # core.analysis.Diagnosis
    span_jsonl: str
    outdir: Optional[str] = None
    diag_wall_s: float = 0.0           # wall time spent inside diagnose()

    @property
    def detected(self) -> Tuple[str, ...]:
        return tuple(self.diagnosis.fault_classes)

    @property
    def ok(self) -> bool:
        """Round-trip verdict: every injected fault class was diagnosed,
        and a fault-free scenario produced no findings."""
        expected = self.scenario.expected_classes
        if not expected:
            return not self.diagnosis.findings
        return set(expected) <= set(self.detected)

    def report(self) -> str:
        lines = [
            f"scenario {self.scenario.name!r} (seed={self.plan.seed}): "
            f"{self.scenario.description}",
            f"  workload : {self.scenario.make_workload(self.plan.seed).describe()}",
        ]
        if self.scenario.mitigation != "do_nothing":
            lines.append(f"  mitigation: {self.scenario.mitigation}")
        lines += [
            f"  injected : {self.plan.describe() or ['none']}",
            f"  expected : {list(self.scenario.expected_classes) or ['(clean)']}",
            f"  diagnosed: {list(self.detected) or ['(clean)']}   "
            f"[{'OK' if self.ok else 'MISSED'}]",
        ]
        for f in self.diagnosis.findings:
            lines.append(f"    {f}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The curated library — one named scenario per fault class + a baseline.
# docs/scenarios.md documents each entry's trace signature in detail.
# ---------------------------------------------------------------------------

_LIBRARY: Tuple[ScenarioSpec, ...] = (
    ScenarioSpec(
        name="healthy_baseline",
        description="no faults — the control run every other scenario is read against",
        signature="uniform Op durations, FIFO links, zero clock offset; "
                  "diagnose() returns no findings",
    ),
    ScenarioSpec(
        name="degraded_ici_link",
        description="one intra-pod ICI link drops to 8% bandwidth (flaky cable)",
        faults=(LinkDegradation(link="ici.pod0.l1", bw_factor=0.08),),
        signature="LinkTransfer wire time per byte on ici.pod0.l1 is a k-MAD "
                  "outlier vs the other ICI links; collectives crossing it stretch",
    ),
    ScenarioSpec(
        name="lossy_dcn",
        description="cross-pod DCN link drops 30% of chunks; link layer retransmits",
        faults=(LinkLoss(link="dcn.h0h1", drop_prob=0.3, retransmit_ps=2 * PS_PER_MS),),
        signature="chunk_drop events on dcn.h0h1 LinkTransfer spans; gradient "
                  "all-reduce tail latency inflates by the retransmit delay",
    ),
    ScenarioSpec(
        name="reordered_ici",
        description="in-flight reordering: up to 3 ms propagation jitter on one ICI link",
        faults=(ChunkReorder(link="ici.pod0.l0", jitter_ps=3 * PS_PER_MS),),
        signature="transfers on ici.pod0.l0 complete out of enqueue order "
                  "(impossible on a healthy FIFO link) — arrival-inversion rule fires",
    ),
    ScenarioSpec(
        name="gc_pause_host0",
        description="host0's runtime freezes 30 ms mid-run (GC-style stall)",
        faults=(HostPause(host="host0", pause_ps=30 * PS_PER_MS, at_ps=1_000_000),),
        signature="a gc_stall span event inside host0's affected HostStep; that "
                  "step's DataLoad span stretches by the stall",
    ),
    ScenarioSpec(
        name="stepped_clock_host1",
        description="host1's clock steps +150 µs at t=5 ms (bad NTP step / VM migration)",
        faults=(ClockStep(host="host1", step_ps=150_000_000, at_ps=5 * PS_PER_MS),),
        signature="host1 clock_read offsets vs the global clock jump by 150 µs "
                  "in one sample — classified kind=step",
    ),
    ScenarioSpec(
        name="drifting_clock_host1",
        description="host1's oscillator drifts at 800 ppm from t=0",
        faults=(ClockDrift(host="host1", drift_ppm=800.0),),
        signature="host1 clock_read offsets grow linearly (~0.8 µs/ms) — "
                  "classified kind=drift with the fitted slope in evidence",
    ),
    ScenarioSpec(
        name="throttled_chip",
        description="pod1.chip02 thermally throttles to 1/3 compute for the whole run",
        faults=(DeviceSlowdown(chip="pod1.chip02", factor=3.0),),
        signature="pod1.chip02's median Op duration is a k-MAD outlier across "
                  "chips; every collective it joins stretches to match",
    ),
    ScenarioSpec(
        name="straggler_pod2",
        description="all of pod2 runs 2.5x slow (bad rack: cooling/power)",
        faults=(StragglerPod(pod=2, factor=2.5),),
        n_pods=3,
        chips_per_pod=2,
        signature="pod2's chips are uniformly slow: per-pod median Op duration "
                  "k-MAD outlier (pod rule needs >= 3 pods)",
    ),
    # -- workload-pinned scenarios: the serving / storage / pipeline axes -----
    ScenarioSpec(
        name="rpc_tail_latency",
        description="RPC serving while an ICI link in the frontend pod drops to 8% bw",
        workload="rpc",
        workload_params=(("n_requests", 10), ("rate_rps", 1500.0)),
        program=rpc_handler_program,
        faults=(LinkDegradation(link="ici.pod0.l1", bw_factor=0.08),),
        signature="per-request span trees; the slowest RpcRequest's critical "
                  "path runs through ici.pod0.l1, whose wire time per byte is "
                  "a k-MAD outlier vs sibling ICI links",
    ),
    ScenarioSpec(
        name="link_loss_rpc",
        description="RPC serving over a lossy DCN link — the scenario the "
                    "mitigation policies compete on (--mitigations sweep)",
        workload="rpc",
        workload_params=(("n_requests", 12), ("rate_rps", 2000.0)),
        program=rpc_handler_program,
        n_pods=3,
        chips_per_pod=2,
        faults=(LinkLoss(link="dcn.h0h1", drop_prob=0.35,
                         retransmit_ps=4 * PS_PER_MS),),
        signature="chunk_drop events on dcn.h0h1 inflate remote RpcCall legs "
                  "by the 4 ms re-send delay; 'retransmit' caps the recovery "
                  "delay, 'disable_and_reroute' detours via host2 at a "
                  "capacity penalty — compare with score_mitigations()",
    ),
    ScenarioSpec(
        name="ckpt_slow_dcn",
        description="checkpoint I/O + training while dcn.h0h1 runs at 10% bandwidth",
        workload="storage",
        n_pods=3,
        chips_per_pod=2,
        faults=(LinkDegradation(link="dcn.h0h1", bw_factor=0.1),),
        signature="ckpt shard flows and gradient all-reduce chunks contend on "
                  "the DCN; dcn.h0h1 wire time per byte is a k-MAD outlier vs "
                  "its sibling DCN links",
    ),
    ScenarioSpec(
        name="pipeline_stall_host1",
        description="pipelined training with a 30 ms GC pause on the stage-1 host",
        workload="pipeline",
        n_pods=3,
        chips_per_pod=2,
        faults=(HostPause(host="host1", pause_ps=30 * PS_PER_MS, at_ps=1_000_000),),
        signature="a gc_stall span event inside host1's microbatch HostStep; "
                  "every later stage's microbatches shift by the bubble",
    ),
)

SCENARIOS: Dict[str, ScenarioSpec] = {s.name: s for s in _LIBRARY}


def list_scenarios(workload: Optional[str] = None) -> List[str]:
    """Names of the curated scenario library, in definition order.

    ``workload`` filters to scenarios pinned to that workload type
    (``--list-scenarios --workload rpc`` on the CLI)."""
    return [
        name for name, s in SCENARIOS.items()
        if workload is None or s.workload == workload
    ]


def get_scenario(name: str) -> ScenarioSpec:
    """Look up a library scenario by name (KeyError lists what exists)."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {', '.join(SCENARIOS)}"
        ) from None
