"""Simulated testbed topologies (nodes + links + routes).

Two families:
* ``ntp_testbed()``   — the paper's §5 topology: client/server hosts behind
                        two switches, background traffic on the inter-switch
                        link.
* ``tpu_cluster()``   — a multi-pod TPU testbed: per-pod ICI ring of chips,
                        one host per pod (PCIe to each chip), DCN between
                        hosts.

Routing is static shortest-path (BFS), cached per (src, dst).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..hw import V5E, ChipSpec, PS_PER_S


@dataclass
class Link:
    name: str                    # e.g. "ici.pod0.l3", "dcn.h0h1", "pcie.pod0.c2"
    a: str
    b: str
    bw: float                    # bytes/s
    latency_ps: int = 500_000    # 0.5us default
    # runtime state (owned by netsim)
    busy_until: int = 0
    bytes_tx: int = 0
    queue_len: int = 0

    @property
    def bytes_per_ps(self) -> float:
        return self.bw / PS_PER_S


@dataclass
class Topology:
    name: str
    chip: ChipSpec = field(default_factory=lambda: V5E)
    nodes: List[str] = field(default_factory=list)
    links: Dict[str, Link] = field(default_factory=dict)
    adj: Dict[str, List[Tuple[str, str]]] = field(default_factory=dict)  # node -> [(peer, link)]
    pods: Dict[int, List[str]] = field(default_factory=dict)             # pod -> chip node names
    hosts: List[str] = field(default_factory=list)
    _routes: Dict[Tuple[str, str], List[str]] = field(default_factory=dict)

    def add_node(self, n: str) -> None:
        if n not in self.adj:
            self.nodes.append(n)
            self.adj[n] = []

    def add_link(self, name: str, a: str, b: str, bw: float, latency_ps: int = 500_000) -> Link:
        self.add_node(a)
        self.add_node(b)
        l = Link(name, a, b, bw, latency_ps)
        self.links[name] = l
        self.adj[a].append((b, name))
        self.adj[b].append((a, name))
        return l

    def route(self, src: str, dst: str) -> List[str]:
        """BFS shortest path, returned as list of link names."""
        key = (src, dst)
        r = self._routes.get(key)
        if r is not None:
            return r
        prev: Dict[str, Tuple[str, str]] = {}
        frontier = [src]
        seen = {src}
        while frontier and dst not in prev and dst != src:
            nxt = []
            for u in frontier:
                for v, ln in self.adj[u]:
                    if v not in seen:
                        seen.add(v)
                        prev[v] = (u, ln)
                        nxt.append(v)
            frontier = nxt
        path: List[str] = []
        cur = dst
        while cur != src:
            if cur not in prev:
                raise ValueError(f"no route {src} -> {dst}")
            u, ln = prev[cur]
            path.append(ln)
            cur = u
        path.reverse()
        self._routes[key] = path
        return path

    # -- id helpers ---------------------------------------------------------------

    @staticmethod
    def chip_name(pod: int, idx: int) -> str:
        return f"pod{pod}.chip{idx:02d}"

    @staticmethod
    def host_name(pod: int) -> str:
        return f"host{pod}"


def ntp_testbed(
    link_bw: float = 1.25e9,          # 10 Gbps, ns3-ish
    latency_ps: int = 5_000_000,      # 5 us per hop
) -> Topology:
    """Paper §5: client - sw1 - sw2 - server (+ bg src/sink on sw1/sw2)."""
    t = Topology(name="ntp_testbed")
    t.add_link("eth.client_sw1", "client", "sw1", link_bw, latency_ps)
    t.add_link("eth.sw1_sw2", "sw1", "sw2", link_bw, latency_ps)
    t.add_link("eth.sw2_server", "sw2", "server", link_bw, latency_ps)
    t.add_link("eth.bgsrc_sw1", "bgsrc", "sw1", link_bw, latency_ps)
    t.add_link("eth.bgsink_sw2", "bgsink", "sw2", link_bw, latency_ps)
    t.hosts = ["client", "server", "bgsrc", "bgsink"]
    return t


def tpu_cluster(
    n_pods: int = 2,
    chips_per_pod: int = 8,
    chip: ChipSpec = V5E,
    ici_latency_ps: int = 1_000_000,    # 1 us hop
    dcn_latency_ps: int = 10_000_000,   # 10 us hop
) -> Topology:
    """Multi-pod testbed: ICI ring per pod, PCIe host links, DCN host mesh.

    (The production 16x16 pod is a 2D torus; the simulated testbed uses a
    ring per pod — collective *schedules* are modeled per ring group, which
    matches how multi-axis collectives decompose into per-axis rings.)
    """
    t = Topology(name=f"tpu_{n_pods}x{chips_per_pod}", chip=chip)
    for p in range(n_pods):
        host = t.host_name(p)
        chips = [t.chip_name(p, i) for i in range(chips_per_pod)]
        t.pods[p] = chips
        t.hosts.append(host)
        for i, c in enumerate(chips):
            # bidirectional ICI ring: one link per neighbor pair
            nxt = chips[(i + 1) % chips_per_pod]
            t.add_link(f"ici.pod{p}.l{i}", c, nxt, chip.ici_link_bw, ici_latency_ps)
            t.add_link(f"pcie.pod{p}.c{i}", host, c, chip.pcie_bw, 2_000_000)
    for p in range(n_pods):
        for q in range(p + 1, n_pods):
            t.add_link(
                f"dcn.h{p}h{q}",
                t.host_name(p),
                t.host_name(q),
                chip.dcn_bw_per_host,
                dcn_latency_ps,
            )
    return t
