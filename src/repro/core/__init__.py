"""Columbo core: modular full-system-simulation logs -> end-to-end traces.

Public API surface of the paper's contribution (§3):

* events:    type-specific event streams per simulator type
* parsers:   simulator-specific log-format parsers
* pipeline:  producer -> actors -> SpanWeaver pipelines (+ online mode)
* weaver:    span weaving + implicit context propagation
* exporters: streaming Jaeger / Chrome trace / OTLP / JSONL / console
* analysis:  breakdowns, critical path, clock + straggler diagnostics
* evaluation: scored diagnosis — confusion matrices + sensitivity curves
* registry:  pluggable SimulatorRegistry (custom sim types, no core edits)
* session:   TraceSpec (declarative) + TraceSession (fluent) composition
* script:    deprecated ColumboScript shim over TraceSession
"""
from .actors import (
    FilterActor,
    KindFilterActor,
    MapActor,
    RateMeterActor,
    ReorderBufferActor,
    SourceFilterActor,
    SymbolizeActor,
    TagActor,
    TimeWindowActor,
)
from .analysis import (
    AggregateReport,
    Diagnosis,
    Finding,
    RunStats,
    aggregate,
    clock_offset_series,
    component_breakdown,
    critical_path,
    diagnose,
    ntp_estimated_offsets,
    ntp_path_asymmetry,
    percentile,
    percentiles,
    request_latency_stats,
    request_report,
    rpc_requests,
    slowest_request,
    span_name_breakdown,
    straggler_report,
    trace_summary,
)
from .context import ContextRegistry
from .evaluation import (
    ClassConfusion,
    DiagnosisEvaluation,
    SensitivityCurve,
    evaluate_diagnosis,
    sensitivity_curves,
)
from .errors import (
    ColumboError,
    SessionNotRunError,
    SessionStateError,
    TraceSpecError,
    UnknownSimTypeError,
)
from .events import Event, SimType, event_type_counts, event_types, sim_type_value
from .exporters import (
    ChromeTraceExporter,
    ConsoleExporter,
    Exporter,
    JaegerJSONExporter,
    OTLPJSONExporter,
    SpanJSONLExporter,
    iter_span_records,
    merge_span_jsonl,
)
from .parsers import DeviceLogParser, HostLogParser, LogParser, NetLogParser, parser_for
from .pipeline import (
    IterableProducer,
    LineIterProducer,
    LogFileProducer,
    MergedProducer,
    Pipeline,
    make_fifo,
)
from .registry import (
    DEFAULT_REGISTRY,
    SimulatorRegistry,
    SimulatorSpec,
    register_simulator,
    simulator_for,
)
from .script import ColumboScript
from .session import (
    ExecutionEngine,
    ExecutionPolicy,
    SourceSpec,
    TraceSession,
    TraceSpec,
    sniff_sim_type,
)
from .span import Span, SpanContext, Trace, assemble_traces, reset_ids
from .weaver import (
    DeviceSpanWeaver,
    HostSpanWeaver,
    NetSpanWeaver,
    SpanWeaver,
    finalize_spans,
    span_type_counts,
)

__all__ = [k for k in dir() if not k.startswith("_")]
