"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192,
vocab=202048, MoE 16 experts top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  head_dim=128.

40 heads are not divisible by the 16-way model axis -> attention head
sharding falls back to replication (a roofline finding; §Perf examines the
pad-to-48 alternative).  Vision frontend is a STUB (precomputed patch
embeddings).
"""
from ..models.config import ModelConfig
from . import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        head_dim=128,
        n_experts=16,
        top_k=1,
        expert_d_ff=8192,
        shared_expert_d_ff=8192,
        capacity_factor=1.25,
        mlp_act="swiglu",
        frontend="vision",
        rope_theta=500_000.0,
        param_dtype="bfloat16",
    ),
    microbatches={"train_4k": 16},
    kv_cache_dtype={"decode_32k": "int8", "prefill_32k": "int8"},
)
