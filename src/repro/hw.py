"""Hardware constants for the TPU v5e-class target (single source of truth).

Used by the roofline analysis (benchmarks/roofline.py) and by the device /
interconnect simulators (repro/sim).  The container is CPU-only: these model
the *target*, they are never measured here.
"""
from __future__ import annotations

from dataclasses import dataclass

PS_PER_S = 1_000_000_000_000


@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    hbm_bytes: float = 16e9              # HBM capacity per chip
    vmem_bytes: float = 128 * 2**20      # ~128 MiB VMEM
    ici_link_bw: float = 50e9            # bytes/s per ICI link (per direction)
    ici_links_per_chip: int = 4          # 2D torus: +x/-x/+y/-y
    dcn_bw_per_host: float = 25e9        # bytes/s cross-pod per host
    pcie_bw: float = 32e9                # bytes/s host<->chip
    op_overhead_ps: int = 2_000_000      # ~2us fixed launch overhead per fused op

    # convenience: per-picosecond rates
    @property
    def flops_per_ps(self) -> float:
        return self.peak_flops_bf16 / PS_PER_S

    @property
    def hbm_bytes_per_ps(self) -> float:
        return self.hbm_bw / PS_PER_S

    @property
    def ici_bytes_per_ps(self) -> float:
        return self.ici_link_bw / PS_PER_S


V5E = ChipSpec()
