"""Scored diagnosis evaluation: confusion matrices + sensitivity curves.

The paper's claim is attribution — a trace should pin an anomalous request
on the component that caused it.  ``sim/faults.py`` injects ground truth,
``analysis.diagnose`` attributes blind, and this module *scores* the
round-trip across a population of runs (Anand et al. and Zhang et al. both
argue attribution quality is a population property, not a spot check):

* :func:`evaluate_diagnosis` folds a sweep's per-cell
  :class:`~repro.core.analysis.RunStats` into one per-fault-class
  confusion matrix (:class:`ClassConfusion`) — precision / recall / F1,
  false-positive rate on healthy cells, component-naming accuracy (did the
  finding name the actually-faulted link/host/chip/pod), and the wall time
  ``diagnose()`` itself spent.

* :func:`sensitivity_curves` reads the sweep's fault-magnitude axis
  (``SweepSpec(magnitudes=...)``) into per-scenario detection-rate curves:
  at what fraction of its published intensity does each fault class stop
  being diagnosed.

``benchmarks/diag_bench.py`` drives both over the curated scenario library
and commits the result as the ``BENCH_diag.json`` leaderboard; the scoring
itself lives here so notebooks and tests can evaluate any
``run_sweep`` / ``load_sweep`` output the same way.

Scoring semantics, per cell and fault class: injected ∧ diagnosed → TP,
injected ∧ missed → FN, diagnosed ∧ not injected → FP, neither → TN (over
the union of classes seen anywhere in the population).  A TP cell also
scores component naming: a hit iff some finding of that class named one of
the cell's ground-truth targets (``RunStats.finding_components`` ∩
``RunStats.expected_components``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .analysis import RunStats


def _safe_div(num: float, den: float, default: float = 1.0) -> float:
    """``num / den`` with an explicit vacuous value for an empty denominator
    (no predictions → precision is vacuously perfect, etc.)."""
    return num / den if den else default


@dataclass
class ClassConfusion:
    """One fault class's confusion-matrix counts across a cell population."""

    fault_class: str
    tp: int = 0                 # injected and diagnosed
    fn: int = 0                 # injected, missed
    fp: int = 0                 # diagnosed, not injected
    tn: int = 0                 # neither
    component_hits: int = 0     # TP cells whose finding named a true target
    component_total: int = 0    # TP cells with component ground truth

    @property
    def injected(self) -> int:
        """Cells where this class was injected (``tp + fn``)."""
        return self.tp + self.fn

    @property
    def precision(self) -> float:
        """``tp / (tp + fp)`` — vacuously 1.0 with no positive predictions."""
        return _safe_div(self.tp, self.tp + self.fp)

    @property
    def recall(self) -> float:
        """``tp / (tp + fn)`` — vacuously 1.0 with no injected cells."""
        return _safe_div(self.tp, self.tp + self.fn)

    @property
    def f1(self) -> float:
        """Harmonic mean of precision and recall."""
        p, r = self.precision, self.recall
        return _safe_div(2 * p * r, p + r, default=0.0)

    @property
    def fpr(self) -> float:
        """``fp / (fp + tn)`` — false alarms among clean-of-this-class
        cells (vacuously 0.0 when every cell injected the class)."""
        return _safe_div(self.fp, self.fp + self.tn, default=0.0)

    @property
    def component_accuracy(self) -> float:
        """Of TP cells with component ground truth, the fraction whose
        finding named the actually-faulted component."""
        return _safe_div(self.component_hits, self.component_total)

    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable form (one leaderboard row)."""
        return {
            "fault_class": self.fault_class,
            "tp": self.tp, "fn": self.fn, "fp": self.fp, "tn": self.tn,
            "precision": self.precision,
            "recall": self.recall,
            "f1": self.f1,
            "fpr": self.fpr,
            "component_hits": self.component_hits,
            "component_total": self.component_total,
            "component_accuracy": self.component_accuracy,
        }


@dataclass
class DiagnosisEvaluation:
    """What :func:`evaluate_diagnosis` returns: the scored population."""

    classes: Dict[str, ClassConfusion] = field(default_factory=dict)
    n_cells: int = 0
    healthy_cells: int = 0               # cells with nothing injected
    healthy_false_positives: int = 0     # healthy cells with any finding
    diag_wall_s_total: float = 0.0       # summed diagnose() wall time
    diag_wall_s_max: float = 0.0

    @property
    def healthy_fpr(self) -> float:
        """Fraction of healthy-baseline cells where diagnose() cried wolf."""
        return _safe_div(self.healthy_false_positives, self.healthy_cells,
                         default=0.0)

    @property
    def macro_precision(self) -> float:
        """Unweighted mean per-class precision."""
        return self._macro("precision")

    @property
    def macro_recall(self) -> float:
        """Unweighted mean per-class recall."""
        return self._macro("recall")

    @property
    def macro_f1(self) -> float:
        """Unweighted mean per-class F1."""
        return self._macro("f1")

    @property
    def micro_precision(self) -> float:
        """Pooled-count precision over every class."""
        tp = sum(c.tp for c in self.classes.values())
        fp = sum(c.fp for c in self.classes.values())
        return _safe_div(tp, tp + fp)

    @property
    def micro_recall(self) -> float:
        """Pooled-count recall over every class."""
        tp = sum(c.tp for c in self.classes.values())
        fn = sum(c.fn for c in self.classes.values())
        return _safe_div(tp, tp + fn)

    @property
    def component_accuracy(self) -> float:
        """Pooled component-naming accuracy over every class's TP cells."""
        hits = sum(c.component_hits for c in self.classes.values())
        total = sum(c.component_total for c in self.classes.values())
        return _safe_div(hits, total)

    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable form (the leaderboard's ``confusion`` block)."""
        return {
            "n_cells": self.n_cells,
            "healthy_cells": self.healthy_cells,
            "healthy_false_positives": self.healthy_false_positives,
            "healthy_fpr": self.healthy_fpr,
            "macro_precision": self.macro_precision,
            "macro_recall": self.macro_recall,
            "macro_f1": self.macro_f1,
            "micro_precision": self.micro_precision,
            "micro_recall": self.micro_recall,
            "component_accuracy": self.component_accuracy,
            "diag_wall_s_total": self.diag_wall_s_total,
            "diag_wall_s_max": self.diag_wall_s_max,
            "classes": {k: c.to_dict() for k, c in sorted(self.classes.items())},
        }

    def report(self) -> str:
        """Human-readable leaderboard table."""
        lines = [
            f"diagnosis evaluation: {self.n_cells} cells "
            f"({self.healthy_cells} healthy, "
            f"healthy FPR {self.healthy_fpr:.2f}); "
            f"diagnose() wall {self.diag_wall_s_total * 1e3:.1f} ms total / "
            f"{self.diag_wall_s_max * 1e3:.2f} ms max",
            f"  {'fault class':18s} {'inj':>4s} {'tp':>4s} {'fn':>4s} "
            f"{'fp':>4s} {'prec':>6s} {'rec':>6s} {'f1':>6s} {'comp':>6s}",
        ]
        for name in sorted(self.classes):
            c = self.classes[name]
            lines.append(
                f"  {name:18s} {c.injected:4d} {c.tp:4d} {c.fn:4d} {c.fp:4d} "
                f"{c.precision:6.2f} {c.recall:6.2f} {c.f1:6.2f} "
                f"{c.component_accuracy:6.2f}"
            )
        lines.append(
            f"  {'macro':18s} {'':4s} {'':4s} {'':4s} {'':4s} "
            f"{self.macro_precision:6.2f} {self.macro_recall:6.2f} "
            f"{self.macro_f1:6.2f} {self.component_accuracy:6.2f}"
        )
        return "\n".join(lines)

    def _macro(self, metric: str) -> float:
        scored = [c for c in self.classes.values() if c.injected or c.fp]
        if not scored:
            return 1.0
        return sum(getattr(c, metric) for c in scored) / len(scored)


def evaluate_diagnosis(stats: Sequence[RunStats]) -> DiagnosisEvaluation:
    """Score a population of cells into a per-fault-class confusion matrix.

    ``stats`` is any collection of pre-reduced cells —
    ``SweepResult.run_stats()``, a re-hydrated ``load_sweep`` result, or
    hand-built :class:`~repro.core.analysis.RunStats`.  The class universe
    (for TN counting) is the union of every cell's expected and detected
    classes, so the evaluation never needs the injection registry.
    """
    ev = DiagnosisEvaluation(n_cells=len(stats))
    universe: List[str] = []
    for s in stats:
        for cls in tuple(s.expected) + tuple(s.detected):
            if cls not in universe:
                universe.append(cls)
    for cls in universe:
        ev.classes[cls] = ClassConfusion(fault_class=cls)
    for s in stats:
        expected = set(s.expected)
        detected = set(s.detected)
        if not expected:
            ev.healthy_cells += 1
            if detected:
                ev.healthy_false_positives += 1
        ev.diag_wall_s_total += s.diag_wall_s
        ev.diag_wall_s_max = max(ev.diag_wall_s_max, s.diag_wall_s)
        for cls in universe:
            c = ev.classes[cls]
            if cls in expected and cls in detected:
                c.tp += 1
                truth = s.expected_components.get(cls)
                if truth:
                    c.component_total += 1
                    named = s.finding_components.get(cls, ())
                    if set(named) & set(truth):
                        c.component_hits += 1
            elif cls in expected:
                c.fn += 1
            elif cls in detected:
                c.fp += 1
            else:
                c.tn += 1
    return ev


@dataclass
class SensitivityCurve:
    """Detection rate vs fault magnitude for one (scenario, fault class).

    ``points`` are ``(magnitude, detection_rate)`` sorted by magnitude,
    where detection rate pools every cell of that scenario/magnitude
    (across seeds, and workloads/mitigations if swept).
    """

    scenario: str
    fault_class: str
    points: List[Tuple[float, float]] = field(default_factory=list)

    @property
    def detection_threshold(self) -> Optional[float]:
        """The smallest swept magnitude with a majority (>= 0.5) detection
        rate — where the rule starts reliably firing; ``None`` if it never
        does."""
        for mag, rate in self.points:
            if rate >= 0.5:
                return mag
        return None

    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable form (one leaderboard curve)."""
        return {
            "scenario": self.scenario,
            "fault_class": self.fault_class,
            "points": [{"magnitude": m, "detection_rate": r}
                       for m, r in self.points],
            "detection_threshold": self.detection_threshold,
        }

    def report(self) -> str:
        """One-line curve summary."""
        pts = " ".join(f"{m:g}:{r:.2f}" for m, r in self.points)
        thr = self.detection_threshold
        return (f"{self.scenario}/{self.fault_class}: {pts} "
                f"(threshold {'-' if thr is None else f'{thr:g}'})")


def sensitivity_curves(stats: Sequence[RunStats]) -> List[SensitivityCurve]:
    """Fold a magnitude-axis sweep into per-scenario detection curves.

    Cells are grouped by ``(scenario, injected fault class)``; each swept
    magnitude contributes one point whose rate is the fraction of that
    group's cells where the class was diagnosed.  Scenarios without any
    injected class (healthy baselines) produce no curve.
    """
    rates: Dict[Tuple[str, str], Dict[float, List[bool]]] = {}
    for s in stats:
        for cls in s.expected:
            hits = rates.setdefault((s.scenario, cls), {})
            hits.setdefault(s.magnitude, []).append(cls in s.detected)
    curves = []
    for (scenario, cls), by_mag in sorted(rates.items()):
        points = [
            (mag, sum(hits) / len(hits))
            for mag, hits in sorted(by_mag.items())
        ]
        curves.append(
            SensitivityCurve(scenario=scenario, fault_class=cls, points=points)
        )
    return curves
