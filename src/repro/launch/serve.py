"""Serving launcher: batched request serving with a reduced config on CPU.

``python -m repro.launch.serve --arch qwen3-8b --requests 8 --smoke``
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    import jax
    import numpy as np

    from ..configs import get_arch
    from ..models import init_params, model_pspecs
    from ..serving import Request, ServingEngine

    cfg = get_arch(args.arch).config.reduced()
    params = init_params(jax.random.PRNGKey(0), model_pspecs(cfg))
    engine = ServingEngine(cfg, params, batch_size=args.batch,
                           max_seq=args.prompt_len + args.max_new)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=0.7 if i % 2 else 0.0,
        )
        for i in range(args.requests)
    ]
    engine.serve(reqs)
    s = engine.stats
    print(
        f"served {s.requests} requests in {s.waves} waves: "
        f"{s.prefill_tokens} prefill + {s.decode_tokens} decode tokens, "
        f"{s.tokens_per_s:.0f} tok/s"
    )


if __name__ == "__main__":
    main()
