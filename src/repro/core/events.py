"""Type-specific event streams (Columbo §3.4).

Every component simulator in a modular full-system simulation logs in its own
ad-hoc format.  Columbo standardizes *per simulator type*: for each type
(HOST runtime, DEVICE/chip, NET/interconnect) there is a closed set of typed
events that any simulator of that type must be parsed into.  Supporting a new
simulator of an existing type only requires a new parser (core/parsers.py);
the rest of the pipeline is unchanged.

Times are integer picoseconds on the simulation's global virtual clock
(gem5-style ticks).  Exporters convert to µs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, ClassVar, Dict, List, Optional, Type

PS_PER_US = 1_000_000
PS_PER_NS = 1_000
PS_PER_S = 1_000_000_000_000


class SimType(str, Enum):
    """Built-in simulator *types* (paper §3.4): the unit of event-stream
    standardization.  Custom types (a storage sim, a DPU sim, ...) are plain
    strings registered through ``core.registry.register_simulator``; every
    core API accepts either a ``SimType`` member or a bare string."""

    HOST = "host"        # host runtime: input pipeline, dispatch, DMA, ckpt
    DEVICE = "device"    # accelerator chip: op timeline, HBM, collectives
    NET = "net"          # interconnect: ICI/DCN links, chunk transfers


def sim_type_value(sim_type) -> str:
    """Canonical string name of a simulator type (``SimType`` or str)."""
    if isinstance(sim_type, Enum):
        return sim_type.value
    return str(sim_type)


# ---------------------------------------------------------------------------
# Event base + registry
# ---------------------------------------------------------------------------

# Keyed by the canonical string value so user-registered simulator types
# participate without core edits (SimType is a str-enum: either spells work).
_EVENT_REGISTRY: Dict[str, Dict[str, Type["Event"]]] = {t.value: {} for t in SimType}


def register_event(cls: Type["Event"]) -> Type["Event"]:
    """Class decorator: add an event type to its simulator type's registry."""
    _EVENT_REGISTRY.setdefault(sim_type_value(cls.sim_type), {})[cls.kind] = cls
    return cls


def event_types(sim_type) -> Dict[str, Type["Event"]]:
    """kind -> Event class for one simulator type's registered events."""
    return dict(_EVENT_REGISTRY.get(sim_type_value(sim_type), {}))


def event_type_counts() -> Dict[str, int]:
    """Per-simulator-type event counts — the Table 1 inventory."""
    return {t: len(kinds) for t, kinds in _EVENT_REGISTRY.items()}


@dataclass(slots=True)
class Event:
    """Base event: a timestamped fact from one component simulator instance."""

    sim_type: ClassVar[SimType]
    kind: ClassVar[str]

    ts: int                    # picoseconds, global virtual clock
    source: str                # component instance id, e.g. "chip03", "host0", "ici.l7"
    attrs: Dict[str, Any] = field(default_factory=dict)

    def copy(self, **updates: Any) -> "Event":
        return dataclasses.replace(self, **updates)

    def __repr__(self) -> str:  # compact: useful when debugging weaves
        return f"{type(self).__name__}(ts={self.ts}, src={self.source}, {self.attrs})"


# ---------------------------------------------------------------------------
# HOST runtime events (paper: host simulator had 16 event types)
# ---------------------------------------------------------------------------


@register_event
@dataclass(slots=True, repr=False)
class HostStepBegin(Event):
    """Host begins a training step."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "step_begin"


@register_event
@dataclass(slots=True, repr=False)
class HostStepEnd(Event):
    """Host finishes a training step."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "step_end"


@register_event
@dataclass(slots=True, repr=False)
class DataLoadBegin(Event):
    """Input pipeline starts producing this step's batch."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "data_load_begin"


@register_event
@dataclass(slots=True, repr=False)
class DataLoadEnd(Event):
    """Batch ready; per-chip H2D DMAs can start."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "data_load_end"


@register_event
@dataclass(slots=True, repr=False)
class ProgramEnqueue(Event):
    """Dispatch of a compiled program to a chip (the PCIe mmio-write analogue)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "program_enqueue"


@register_event
@dataclass(slots=True, repr=False)
class ProgramRetire(Event):
    """A dispatched program completed on its chip (host view)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "program_retire"


@register_event
@dataclass(slots=True, repr=False)
class DmaH2DIssue(Event):
    """Host issues a host-to-device DMA (batch upload)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "dma_h2d_issue"


@register_event
@dataclass(slots=True, repr=False)
class DmaH2DComplete(Event):
    """Host-side completion of a host-to-device DMA."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "dma_h2d_complete"


@register_event
@dataclass(slots=True, repr=False)
class DmaD2HIssue(Event):
    """Host issues a device-to-host DMA (readback)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "dma_d2h_issue"


@register_event
@dataclass(slots=True, repr=False)
class DmaD2HComplete(Event):
    """Host-side completion of a device-to-host DMA."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "dma_d2h_complete"


@register_event
@dataclass(slots=True, repr=False)
class CkptBegin(Event):
    """Checkpoint write begins at a step boundary."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "ckpt_begin"


@register_event
@dataclass(slots=True, repr=False)
class CkptShardWrite(Event):
    """One checkpoint shard written to disk."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "ckpt_shard_write"


@register_event
@dataclass(slots=True, repr=False)
class CkptShardRead(Event):
    """One checkpoint shard read back from storage (restore path — the
    storage workload's read rounds)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "ckpt_shard_read"


@register_event
@dataclass(slots=True, repr=False)
class CkptEnd(Event):
    """Checkpoint write finished."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "ckpt_end"


@register_event
@dataclass(slots=True, repr=False)
class Heartbeat(Event):
    """Periodic liveness beacon from the host runtime."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "heartbeat"


@register_event
@dataclass(slots=True, repr=False)
class ClockRead(Event):
    """Host reads its local system clock (the NTP case study's raw material)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "clock_read"


@register_event
@dataclass(slots=True, repr=False)
class NtpExchange(Event):
    """One NTP request/response with t1..t4 timestamps (case study §5)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "ntp_exchange"


@register_event
@dataclass(slots=True, repr=False)
class GcStall(Event):
    """Host runtime pause (GC / page fault / scheduler stall): the input
    pipeline freezes for ``dur`` ps before the step's data load proceeds."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "gc_stall"


@register_event
@dataclass(slots=True, repr=False)
class HostFailure(Event):
    """Host crash (failure-injection scenarios)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "host_failure"


@register_event
@dataclass(slots=True, repr=False)
class HostRestart(Event):
    """Host rejoined after a failure, restored to a step."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "host_restart"


# -- RPC serving workload (sim/workloads/rpc.py): one span tree per request --


@register_event
@dataclass(slots=True, repr=False)
class RpcRecv(Event):
    """Frontend host admits one RPC request (``rid`` is the trace-context
    id every downstream event of the request carries)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_recv"


@register_event
@dataclass(slots=True, repr=False)
class RpcSend(Event):
    """Frontend fans one subrequest (``sub``) out toward a serving pod."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_send"


@register_event
@dataclass(slots=True, repr=False)
class RpcWorkBegin(Event):
    """A serving host dequeues subrequest ``sub`` and starts executing its
    handler program on the pod's chips."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_work_begin"


@register_event
@dataclass(slots=True, repr=False)
class RpcWorkEnd(Event):
    """The serving host finished subrequest ``sub`` (reply leaves next)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_work_end"


@register_event
@dataclass(slots=True, repr=False)
class RpcReply(Event):
    """Frontend received the reply for subrequest ``sub`` (fan-in)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_reply"


@register_event
@dataclass(slots=True, repr=False)
class RpcDone(Event):
    """All fan-out replies are in: request ``rid`` completes, ``lat``
    carries its end-to-end latency in ps.  Saturation-mode runs add
    ``outcome`` (completed | dropped | timed_out) and ``attempts`` —
    every admitted ``rid`` terminates in exactly one ``rpc_done``."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_done"


@register_event
@dataclass(slots=True, repr=False)
class RpcLbPick(Event):
    """The frontend's load balancer chose backend ``dst`` for attempt
    ``attempt`` of request ``rid`` (``policy`` names the registered LB
    policy, ``qlen`` is the chosen backend's load at pick time)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_lb_pick"


@register_event
@dataclass(slots=True, repr=False)
class RpcQueueDrop(Event):
    """A backend's bounded FIFO was full: subrequest ``sub`` was dropped
    deterministically on arrival (``qlen`` queued at ``depth`` capacity)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_queue_drop"


@register_event
@dataclass(slots=True, repr=False)
class RpcTimeout(Event):
    """The frontend's per-request deadline (``deadline`` ps) expired before
    attempt ``attempt`` of ``rid`` replied; closes the attempt's span."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_timeout"


@register_event
@dataclass(slots=True, repr=False)
class RpcRetry(Event):
    """The frontend re-issues ``rid`` after a drop/timeout (``reason``):
    attempt ``attempt`` starts after a seeded exponential ``backoff`` ps."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "rpc_retry"


# -- mitigation engine (sim/mitigation.py): remediation trigger/action/done --


@register_event
@dataclass(slots=True, repr=False)
class MitigationTrigger(Event):
    """A mitigation policy's trigger loop fired: the watched telemetry
    (``signal``) crossed its threshold for ``target``.  Opens the policy's
    ``Mitigation`` span."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "mitigation_trigger"


@register_event
@dataclass(slots=True, repr=False)
class MitigationAction(Event):
    """A remediation action taken by a triggered policy (reroute, evict,
    rollback, ...); ``penalty`` records the capacity cost it pays."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "mitigation_action"


@register_event
@dataclass(slots=True, repr=False)
class MitigationDone(Event):
    """The policy's remediation completed; closes its ``Mitigation`` span
    (trigger→done duration is the detection-to-mitigation latency
    ``score_mitigations`` reports)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "mitigation_done"


@register_event
@dataclass(slots=True, repr=False)
class RetransmitBegin(Event):
    """Loss-protection resend of a dropped chunk starts (``retransmit``
    policy); opens a ``Retransmit`` span under the policy's span."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "retransmit_begin"


@register_event
@dataclass(slots=True, repr=False)
class RetransmitEnd(Event):
    """The resent chunk was delivered; closes its ``Retransmit`` span."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "retransmit_end"


# -- pipelined-training workload (sim/workloads/pipeline.py) ----------------


@register_event
@dataclass(slots=True, repr=False)
class PipeSend(Event):
    """Stage host ships microbatch ``mb``'s activations to the next stage
    (``chunk`` names the interconnect transfer that carries them)."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "pipe_send"


@register_event
@dataclass(slots=True, repr=False)
class PipeRecv(Event):
    """Stage host received the previous stage's activations for ``mb``."""

    sim_type: ClassVar[SimType] = SimType.HOST
    kind: ClassVar[str] = "pipe_recv"


# ---------------------------------------------------------------------------
# DEVICE (chip) events (paper: NIC simulator had 9; our chip sim is richer,
# closer to the gem5 role: 12 types)
# ---------------------------------------------------------------------------


@register_event
@dataclass(slots=True, repr=False)
class ProgramStart(Event):
    """Chip starts executing a dispatched program."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "program_start"


@register_event
@dataclass(slots=True, repr=False)
class ProgramEnd(Event):
    """Chip finished the program's op list."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "program_end"


@register_event
@dataclass(slots=True, repr=False)
class OpBegin(Event):
    """A fused HLO op starts executing on the chip."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "op_begin"


@register_event
@dataclass(slots=True, repr=False)
class OpEnd(Event):
    """A fused HLO op finished executing on the chip."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "op_end"


@register_event
@dataclass(slots=True, repr=False)
class HbmRead(Event):
    """HBM read traffic attributed to an op."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "hbm_read"


@register_event
@dataclass(slots=True, repr=False)
class HbmWrite(Event):
    """HBM write traffic attributed to an op."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "hbm_write"


@register_event
@dataclass(slots=True, repr=False)
class MxuIssue(Event):
    """Systolic-array busy interval attribution for a matmul-like op."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "mxu_issue"


@register_event
@dataclass(slots=True, repr=False)
class CollectiveStart(Event):
    """Chip reaches a collective and joins its ring rendezvous."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "collective_start"


@register_event
@dataclass(slots=True, repr=False)
class CollectiveChunkTx(Event):
    """Chip hands one chunk of a collective to the interconnect (the Ethernet-
    style natural boundary between the DEVICE and NET simulators)."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "collective_chunk_tx"


@register_event
@dataclass(slots=True, repr=False)
class CollectiveChunkRx(Event):
    """A collective ring chunk arrived at this chip."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "collective_chunk_rx"


@register_event
@dataclass(slots=True, repr=False)
class CollectiveEnd(Event):
    """The collective completed for this chip."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "collective_end"


@register_event
@dataclass(slots=True, repr=False)
class DeviceDmaRecv(Event):
    """H2D DMA lands in HBM (the PCIe natural boundary, device side)."""

    sim_type: ClassVar[SimType] = SimType.DEVICE
    kind: ClassVar[str] = "dma_recv"


# ---------------------------------------------------------------------------
# NET (interconnect) events (paper: network simulator had 3 event types)
# ---------------------------------------------------------------------------


@register_event
@dataclass(slots=True, repr=False)
class ChunkEnqueue(Event):
    """'+' in ns3 ascii traces: chunk enters a link's tx queue."""

    sim_type: ClassVar[SimType] = SimType.NET
    kind: ClassVar[str] = "chunk_enqueue"


@register_event
@dataclass(slots=True, repr=False)
class ChunkTx(Event):
    """'-' in ns3 ascii traces: chunk leaves the tx queue onto the wire."""

    sim_type: ClassVar[SimType] = SimType.NET
    kind: ClassVar[str] = "chunk_tx"


@register_event
@dataclass(slots=True, repr=False)
class ChunkRx(Event):
    """'r' in ns3 ascii traces: chunk received at the far end of a link."""

    sim_type: ClassVar[SimType] = SimType.NET
    kind: ClassVar[str] = "chunk_rx"


@register_event
@dataclass(slots=True, repr=False)
class ChunkDrop(Event):
    """'d' in ns3 ascii traces: chunk dropped on the wire (the link-layer
    retransmits it, so delivery still happens — delayed)."""

    sim_type: ClassVar[SimType] = SimType.NET
    kind: ClassVar[str] = "chunk_drop"


ALL_SIM_TYPES = (SimType.HOST, SimType.DEVICE, SimType.NET)
