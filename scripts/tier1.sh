#!/usr/bin/env bash
# Tier-1 verification — the exact command the builder and CI both run.
# Pins PYTHONPATH=src and the default "-m 'not slow'" pytest profile
# (from pyproject.toml), then the end-to-end smoke benchmark and the
# documentation checks (broken doc links / non-importing doc code blocks).
#
#   scripts/tier1.sh            # tier-1 tests + smoke + docs checks
#   scripts/tier1.sh --full     # include slow model/serving tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -m "" -x -q
else
    python -m pytest -x -q
fi

python -m benchmarks.run smoke

# doc'd examples can't rot: smoke-run the quickstarts end to end into a
# throwaway outdir (the README's headline paths)
EXAMPLES_TMP="$(mktemp -d)"
trap 'rm -rf "$EXAMPLES_TMP"' EXIT
QUICKSTART_OUT="$EXAMPLES_TMP/quickstart" python examples/quickstart.py > /dev/null
RPC_TRACE_OUT="$EXAMPLES_TMP/rpc_trace" python examples/rpc_request_trace.py > /dev/null
python examples/mitigation_comparison.py --seeds 1 > /dev/null
echo "[tier1] examples smoke: quickstart.py + rpc_request_trace.py + mitigation_comparison.py OK"

# engine perf harness pre-flight: tiny sizes, validates that the bench
# itself still runs end to end (schema is asserted in tests/test_sweep.py)
mkdir -p results
python -m benchmarks.engine_bench --smoke --out results/BENCH_engine.smoke.json

# diagnosis accuracy gate: the curated library must stay fully recalled
# (recall == 1.0 per fault class, zero healthy false positives — asserted
# inside the bench; schema is validated in tests/test_sweep.py)
python -m benchmarks.diag_bench --smoke --out results/BENCH_diag.smoke.json
python - <<'PY'
import json

with open("results/BENCH_diag.smoke.json") as f:
    payload = json.load(f)
conf = payload["curated"]["confusion"]
assert conf["macro_recall"] == 1.0, (
    f"curated library macro recall {conf['macro_recall']} != 1.0"
)
assert conf["healthy_false_positives"] == 0
print(f"[tier1] diag smoke: curated recall 1.00 over "
      f"{payload['curated']['cells']} cells, healthy FPR "
      f"{conf['healthy_fpr']:.2f}")
PY

# perf smoke: the events/sec order must hold — columnar >= inline >=
# structured >= text (ratio checks, not absolute bars, so loaded CI hosts
# don't flake — the committed full run shows the real multiples; the
# committed-recording order is asserted without guards in
# tests/test_sweep.py).  Simulate/fused-weave walls are best-of-3 inside
# the bench, but the other stage walls are single-shot: a pair is
# skipped when any stage wall feeding either side is under 10ms, where
# one scheduler blip flips the order regardless of the code.
python - <<'PY'
import json

with open("results/BENCH_engine.smoke.json") as f:
    payload = json.load(f)

def check(row, rates, fast, slow, what, walls):
    if min(walls) < 0.01:
        print(f"[tier1] perf smoke: pods={row['pods']} {fast}/{slow} {what} "
              f"has stage walls under 10ms — order check skipped")
        return
    assert rates[fast] >= rates[slow], (
        f"pods={row['pods']}: {fast} {what} path ({rates[fast]} ev/s) "
        f"fell below the {slow} path ({rates[slow]} ev/s)"
    )

for row in payload["pipeline"]:
    ev, st = row["events"], row["stages_s"]
    fs = row["full_sim_events_per_sec"]
    check(row, fs, "structured", "text", "full-sim",
          [ev / fs["text"], ev / fs["structured"]])
    ee = row["end_to_end_events_per_sec"]
    post = [st[k] for k in ("simulate", "format", "parse", "weave",
                            "export", "analyze")]
    inl = list(row["inline_stages_s"].values())
    col = list(row["columnar_stages_s"].values())
    check(row, ee, "structured", "text", "end-to-end", post)
    check(row, ee, "inline", "structured", "end-to-end", inl + post)
    check(row, ee, "columnar", "inline", "end-to-end", col + inl)
print("[tier1] perf smoke: columnar >= inline >= structured >= text "
      "on all pipeline rows (sub-10ms pairs skipped)")
PY

# serving saturation gate: three small open-loop cells on a 4-pod testbed
# (healthy / saturated-unbounded / bounded-with-retries).  Each must show
# exact request conservation (issued == completed + dropped + timed_out);
# the bounded cell must actually exercise the drop/retry machinery; and
# the queue-bound tail must dominate the healthy tail (virtual-time
# percentiles — deterministic at seed 0, so no flake guard is needed).
python - <<'PY'
from repro.core.analysis import percentile
from repro.sim.cluster import ClusterOrchestrator
from repro.sim.topology import scale
from repro.sim.workload import make_workload
from repro.sim.workloads.rpc import rpc_handler_program

def cell(**knobs):
    wl = make_workload("rpc", program=rpc_handler_program(), clock_reads=2,
                       seed=0, n_requests=40, arrival="open", **knobs)
    cluster = ClusterOrchestrator(scale(pods=4, chips_per_pod=2))
    wl.drive(cluster)
    cluster.run()
    out = wl.outcomes
    terminal = out["completed"] + out["dropped"] + out["timed_out"]
    assert out["issued"] == terminal == 40, (
        f"conservation violated: issued={out['issued']} vs terminal={terminal}"
    )
    assert out["in_flight"] == 0 and out["finalized"] == 40
    return out

healthy = cell(rate_rps=200.0, lb="round_robin")
saturated = cell(rate_rps=2_000_000.0, lb="round_robin")
bounded = cell(rate_rps=2_000_000.0, lb="least_loaded", queue_depth=1,
               timeout_ps=5_000_000_000, max_retries=2)
assert bounded["dropped"] + bounded["timed_out"] > 0, (
    "bounded cell exercised no drops or timeouts"
)
assert bounded["retries"] > 0, "bounded cell exercised no retries"
assert saturated["max_in_flight"] > healthy["max_in_flight"]
h999 = percentile(healthy["lat_ps"], 99.9)
s999 = percentile(saturated["lat_ps"], 99.9)
assert s999 > h999, (
    f"queue-bound p99.9 {s999/1e6:.0f}us must exceed healthy {h999/1e6:.0f}us"
)
print(f"[tier1] saturation smoke: 3x40 requests conserved exactly; "
      f"bounded cell dropped={bounded['dropped']} retried={bounded['retries']}; "
      f"p99.9 healthy {h999/1e6:.0f}us -> saturated {s999/1e6:.0f}us "
      f"(inflight {healthy['max_in_flight']} -> {saturated['max_in_flight']})")
PY

scripts/docs_check.sh
