#!/usr/bin/env bash
# Tier-1 verification — the exact command the builder and CI both run.
# Pins PYTHONPATH=src and the default "-m 'not slow'" pytest profile
# (from pyproject.toml), then the end-to-end smoke benchmark and the
# documentation checks (broken doc links / non-importing doc code blocks).
#
#   scripts/tier1.sh            # tier-1 tests + smoke + docs checks
#   scripts/tier1.sh --full     # include slow model/serving tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -m "" -x -q
else
    python -m pytest -x -q
fi

python -m benchmarks.run smoke

# engine perf harness pre-flight: tiny sizes, validates that the bench
# itself still runs end to end (schema is asserted in tests/test_sweep.py)
mkdir -p results
python -m benchmarks.engine_bench --smoke --out results/BENCH_engine.smoke.json

scripts/docs_check.sh
