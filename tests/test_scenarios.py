"""Fault-injection scenarios: round-trip (inject F -> diagnose names F's
fault class), seeded reproducibility, and the diagnose()/breakdown rules.

The round-trip assertions are the acceptance contract of the ScenarioSpec
framework: every library scenario's injected fault classes must appear in
``diagnose()``'s findings, the healthy baseline must produce none, and the
same seed must reproduce byte-identical SpanJSONL output.
"""
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import component_breakdown, diagnose
from repro.core.span import Span, SpanContext, Trace
from repro.sim import (
    ChunkReorder,
    FaultPlan,
    LinkLoss,
    ScenarioSpec,
    get_scenario,
    list_scenarios,
    synthetic_program,
)
from repro.sim.scenarios import SCENARIOS


# ---------------------------------------------------------------------------
# Round-trip: every library scenario's injected faults are diagnosed
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def scenario_runs():
    """Run the whole library once; individual tests assert against it."""
    return {name: spec.run() for name, spec in SCENARIOS.items()}


@pytest.mark.parametrize("name", list(SCENARIOS))
def test_scenario_roundtrip(scenario_runs, name):
    run = scenario_runs[name]
    expected = set(run.scenario.expected_classes)
    assert expected <= set(run.detected), (
        f"scenario {name}: injected {sorted(expected)} but diagnose() found "
        f"{list(run.detected)}\n{run.diagnosis.summary()}"
    )
    assert run.ok


def test_healthy_baseline_is_clean(scenario_runs):
    run = scenario_runs["healthy_baseline"]
    assert run.diagnosis.findings == []


def test_findings_point_at_the_faulty_component(scenario_runs):
    by_class = {
        "degraded_ici_link": ("link_degradation", "ici.pod0.l1"),
        "lossy_dcn": ("link_loss", "dcn.h0h1"),
        "reordered_ici": ("link_reorder", "ici.pod0.l0"),
        "gc_pause_host0": ("host_pause", "host0"),
        "stepped_clock_host1": ("clock_fault", "host1"),
        "throttled_chip": ("device_slowdown", "pod1.chip02"),
        "straggler_pod2": ("straggler_pod", "pod2"),
    }
    for name, (fault_class, component) in by_class.items():
        found = [
            f for f in scenario_runs[name].diagnosis.findings
            if f.fault_class == fault_class
        ]
        assert any(f.component == component for f in found), (
            f"{name}: {fault_class} findings {found} miss component {component}"
        )


def test_scenario_weave_has_no_orphans(scenario_runs):
    for name, run in scenario_runs.items():
        assert run.session.finalize_stats["orphans"] == 0, name


def test_same_seed_reproduces_byte_identical_jsonl(scenario_runs):
    # second run of a scenario whose faults consume randomness
    again = SCENARIOS["lossy_dcn"].run()
    assert again.span_jsonl == scenario_runs["lossy_dcn"].span_jsonl


def test_different_seed_changes_the_trace(scenario_runs):
    other = SCENARIOS["lossy_dcn"].run(seed=1234)
    assert other.span_jsonl != scenario_runs["lossy_dcn"].span_jsonl


def test_library_covers_every_fault_class():
    from repro.sim.faults import FAULT_CLASSES

    covered = set()
    for name in list_scenarios():
        covered.update(get_scenario(name).expected_classes)
    assert covered == set(FAULT_CLASSES)


def test_get_scenario_unknown_name():
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("no_such_scenario")


# ---------------------------------------------------------------------------
# Property: any seeded FaultPlan is reproducible
# ---------------------------------------------------------------------------

# deliberately randomness-heavy: loss draws + jitter draws on busy links
_MICRO = ScenarioSpec(
    name="micro_repro",
    description="tiny randomness-heavy scenario for the reproducibility property",
    faults=(
        LinkLoss(link="dcn.h0h1", drop_prob=0.4, retransmit_ps=1_000_000_000),
        ChunkReorder(link="ici.pod0.l0", jitter_ps=2_000_000_000),
    ),
    n_steps=1,
    chips_per_pod=2,
    clock_reads=4,
    program=lambda: synthetic_program(
        n_layers=1, layer_flops=2e11, layer_bytes=1e8, grad_bytes=5e7
    ),
)


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=5, deadline=None)
def test_fault_plan_reproducible_for_any_seed(seed):
    first = _MICRO.run(seed=seed)
    second = _MICRO.run(seed=seed)
    assert first.span_jsonl, "scenario produced no spans"
    assert first.span_jsonl == second.span_jsonl


def test_fault_plan_rng_streams_independent_per_fault():
    plan = FaultPlan(_MICRO.faults, seed=7)
    a0, b0 = plan.rng_for(0), plan.rng_for(1)
    assert [a0.random() for _ in range(4)] != [b0.random() for _ in range(4)]
    # re-deriving yields the same stream
    assert plan.rng_for(0).random() == FaultPlan(_MICRO.faults, seed=7).rng_for(0).random()


# ---------------------------------------------------------------------------
# component_breakdown: overlapping sibling children count their overlap once
# ---------------------------------------------------------------------------


def _span(name, start, end, sid, parent=None, component="c0", sim_type="host"):
    return Span(
        name=name, start=start, end=end,
        context=SpanContext(trace_id=1, span_id=sid),
        parent=parent, component=component, sim_type=sim_type,
    )


def test_component_breakdown_overlapping_children_regression():
    parent = _span("Step", 0, 100_000_000, 1)
    # overlapping siblings (async collective overlapped with compute):
    # [10, 50] and [30, 80] cover [10, 80] = 70 of the parent
    a = _span("A", 10_000_000, 50_000_000, 2, parent=parent.context)
    b = _span("B", 30_000_000, 80_000_000, 3, parent=parent.context)
    bd = component_breakdown(Trace(1, [parent, a, b]))
    # parent leaf = [0,10]+[80,100] = 30; children union = 70 -> 100 total,
    # i.e. exactly the busy wall-clock (the old sum double-counted [30,50])
    assert bd == {"host:c0": 100.0}


def test_component_breakdown_disjoint_children_unchanged():
    parent = _span("Step", 0, 100_000_000, 1)
    a = _span("A", 10_000_000, 30_000_000, 2, parent=parent.context)
    b = _span("B", 40_000_000, 80_000_000, 3, parent=parent.context)
    bd = component_breakdown(Trace(1, [parent, a, b]))
    assert bd == {"host:c0": 100.0}
    # leaf_only=False still reports the plain sum
    flat = component_breakdown(Trace(1, [parent, a, b]), leaf_only=False)
    assert flat == {"host:c0": 160.0}


def test_component_breakdown_separates_components():
    parent = _span("Step", 0, 100_000_000, 1)
    child = _span("Op", 20_000_000, 60_000_000, 2, parent=parent.context,
                  component="chip0", sim_type="device")
    bd = component_breakdown(Trace(1, [parent, child]))
    assert bd == {"host:c0": 60.0, "device:chip0": 40.0}


# ---------------------------------------------------------------------------
# diagnose() unit behaviour
# ---------------------------------------------------------------------------


def test_diagnose_empty_and_healthy():
    assert diagnose([]).findings == []
    healthy = [
        _span("Op", i * 10, i * 10 + 5, 10 + i, component=f"pod0.chip{i:02d}",
              sim_type="device")
        for i in range(4)
    ]
    assert diagnose(healthy).findings == []


def test_diagnose_flags_the_slow_chip():
    spans = []
    sid = 1
    for step in range(3):
        for i in range(6):
            dur = 30_000_000 if i != 2 else 95_000_000
            start = step * 1_000_000_000
            spans.append(
                _span("Op", start, start + dur, sid,
                      component=f"pod{i % 2}.chip{i:02d}", sim_type="device")
            )
            sid += 1
    diag = diagnose(spans)
    assert [f.component for f in diag.findings if f.fault_class == "device_slowdown"] \
        == ["pod0.chip02"]
