"""``retransmit``: fast-retransmit dropped chunks under a timeout cap."""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar, TYPE_CHECKING

from ..mitigation import MitigationPolicy, register_mitigation

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..cluster import ClusterOrchestrator


@register_mitigation
@dataclass
class Retransmit(MitigationPolicy):
    """Loss protection: once drops are observed, cap every subsequent
    drop's recovery delay at ``timeout_ps`` (a fast-retransmit timer)
    instead of the network's default exponential-ish re-send backoff.

    The trigger loop watches the fleet drop counter
    (:attr:`~repro.sim.netsim.NetSim.chunks_dropped`); on trigger it
    installs a retransmit-override callback via
    :meth:`~repro.sim.netsim.NetSim.set_retransmit_policy`.  Every re-send
    the callback governs logs ``retransmit_begin`` / ``retransmit_end``
    host events, which weave into ``Retransmit`` spans parented under this
    policy's ``Mitigation`` span.
    """

    mitigation_name: ClassVar[str] = "retransmit"

    #: recovery-delay cap per dropped chunk (default 100 us)
    timeout_ps: int = 100_000_000
    #: fleet-wide drops observed before the policy arms
    trigger_drops: int = 1

    def attach(self, cluster: "ClusterOrchestrator") -> None:
        """Watch the drop counter; on trigger install the re-send cap."""
        net = cluster.net
        kernel = cluster.sim
        host = self.controller(cluster)
        state = {"seq": 0}

        def _cb(link: str, cid: str, drop_ps: int, default_ps: int) -> int:
            retrans = min(default_ps, self.timeout_ps)
            # unique per re-send (a chunk can drop on several hops), so
            # concurrent Retransmit spans never collide on the weave key
            tag = f"{cid}~{state['seq']}"
            state["seq"] += 1
            kernel.at(drop_ps, lambda: host.log_event(
                "retransmit_begin", policy=self.mitigation_name,
                chunk=tag, link=link,
            ))
            kernel.at(drop_ps + retrans, lambda: host.log_event(
                "retransmit_end", policy=self.mitigation_name,
                chunk=tag, link=link,
            ))
            return retrans

        def _probe(i: int) -> bool:
            if net.chunks_dropped < self.trigger_drops:
                return False
            self.log_trigger(cluster, drops=net.chunks_dropped)
            net.set_retransmit_policy(_cb)
            self.log_action(
                cluster, action="fast_retransmit", target="net",
                penalty=0.0, timeout_us=self.timeout_ps // 1_000_000,
            )
            self.log_done(cluster)
            return True

        self.watch(cluster, _probe)
