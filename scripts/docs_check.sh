#!/usr/bin/env bash
# Documentation checks: broken intra-repo links / [[file:line]] anchors in
# README.md + docs/*.md, and python code blocks that don't compile or whose
# imports fail.  Part of scripts/tier1.sh; also runnable standalone:
#
#   scripts/docs_check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python scripts/docs_check.py
