"""Host-runtime simulator (the SimBricks host/NIC-driver role).

Simulates the training framework's host side: input pipeline, H2D DMA,
program dispatch (the PCIe mmio-write analogue), checkpointing, heartbeats —
and, for the paper's §5 case study, a local system clock with drift plus an
NTP/chrony-style synchronization loop whose packets travel through the
interconnect simulator.

Log format (SimBricks nicbm flavour)::

    main_time = <tick>: hostsim-host0: ev=step_begin step=3
    main_time = <tick>: hostsim-host0: ev=program_enqueue chip=chip00 step=3 program=train_step
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional

from .clock import LogWriter
from .engine import PeriodicTask, SimPort
from .netsim import NetSim
from .topology import Topology
from .workload import ProgramSpec

NTP_PACKET_BYTES = 90


class HostClock:
    """Local system clock: local(t) = t + offset + drift*t, slewable.

    ``offset`` is the true offset from the global clock (ground truth the
    simulation knows but a real system would not, §1 advantage iii).
    """

    def __init__(self, offset_ps: int = 0, drift_ppm: float = 0.0) -> None:
        self.base_offset = float(offset_ps)
        self.drift = drift_ppm * 1e-6
        self.slew_total = 0.0

    def local(self, t: int) -> int:
        return int(t + self.base_offset + self.drift * t + self.slew_total)

    def true_offset(self, t: int) -> int:
        return self.local(t) - t

    def slew(self, delta_ps: float) -> None:
        """chrony-style gradual correction (applied instantaneously here;
        the slew *decision* cadence is what the case study examines)."""
        self.slew_total += delta_ps

    def step(self, delta_ps: float) -> None:
        """Fault hook: a hard clock step (NTP stepping, VM migration)."""
        self.slew_total += delta_ps

    def set_drift(self, drift_ppm: float, now_ps: int) -> None:
        """Fault hook: change the oscillator's drift rate from ``now_ps``
        onward without a discontinuity in local time."""
        new = drift_ppm * 1e-6
        self.base_offset += (self.drift - new) * now_ps
        self.drift = new


class HostSim:
    """One training host (or NTP client/server in the testbed topology)."""

    def __init__(
        self,
        sim: SimPort,
        cluster: "ClusterOrchestrator",
        name: str,
        log: LogWriter,
        chips: Optional[List[str]] = None,
        clock: Optional[HostClock] = None,
        data_load_ps: int = 2_000_000_000,      # 2 ms synthetic input pipeline
        batch_bytes_per_chip: int = 4 << 20,
        ckpt_every: int = 0,
        ckpt_shard_bytes: int = 64 << 20,
        disk_bw: float = 2e9,
    ) -> None:
        self.sim = sim
        self.cluster = cluster
        self.name = name
        self.log = log
        # hot-path bindings (clock read + emit happen per logged event)
        self._kernel = sim.kernel
        self._emit = log.emit_host
        self.chips = chips or []
        self.clock = clock or HostClock()
        self.data_load_ps = data_load_ps
        self.batch_bytes_per_chip = batch_bytes_per_chip
        self.ckpt_every = ckpt_every
        self.ckpt_shard_bytes = ckpt_shard_bytes
        self.disk_bw = disk_bw
        self._dma_ids = itertools.count()
        self._step_cb: Optional[Callable[[int], None]] = None
        self.steps_done = 0
        self.failed = False
        self._stall_ps = 0
        self._stall_kind = "gc"

    # -- logging ----------------------------------------------------------------------

    def log_event(self, kind: str, **attrs) -> None:
        # the sink owns the format: text (SimBricks nicbm flavour) on the
        # compatibility path, a zero-format record capture on the fast path
        self._emit((self._kernel.now, self.name, kind, attrs))

    # -- training-step loop --------------------------------------------------------------

    def run_steps(
        self,
        program: ProgramSpec,
        n_steps: int,
        on_all_done: Optional[Callable[[], None]] = None,
    ) -> None:
        self._run_step(program, 0, n_steps, on_all_done)

    def _run_step(
        self,
        program: ProgramSpec,
        step: int,
        n_steps: int,
        on_all_done: Optional[Callable[[], None]],
    ) -> None:
        if step >= n_steps:
            if on_all_done:
                on_all_done()
            return
        if self.failed:
            # parked; restart() re-enters the loop
            self._resume = lambda: self._run_step(program, step, n_steps, on_all_done)
            return
        self.log_event("step_begin", step=step)
        self.log_event("data_load_begin", step=step)
        # injected runtime pause (sim/faults.py HostPause): the input
        # pipeline freezes before this step's batch is ready
        wait_ps = self.data_load_ps + self.consume_stall(step=step)

        def _after_load() -> None:
            self.log_event("data_load_end", step=step, bytes=self.batch_bytes_per_chip * len(self.chips))
            pending = {"n": len(self.chips)}

            def _chip_ready(chip: str) -> None:
                self.log_event("program_enqueue", chip=_short(chip), step=step, program=program.name)
                self.cluster.dispatch(self, chip, program, step, _chip_done)

            def _chip_done(chip: str, t: int) -> None:
                self.log_event("program_retire", chip=_short(chip), step=step, program=program.name)
                pending["n"] -= 1
                if pending["n"] == 0:
                    self._finish_step(program, step, n_steps, on_all_done)

            for chip in self.chips:
                dma = f"d{next(self._dma_ids)}.{self.name}"
                self.log_event("dma_h2d_issue", dma=dma, chip=_short(chip), bytes=self.batch_bytes_per_chip)
                self.cluster.net.transfer(
                    self.name,
                    chip,
                    self.batch_bytes_per_chip,
                    meta={"dma": dma},
                    on_delivered=lambda t, c=chip, d=dma: (
                        self.cluster.device_sim_for(c).dma_landed(c, d, self.batch_bytes_per_chip),
                        self.log_event("dma_h2d_complete", dma=d, chip=_short(c)),
                        _chip_ready(c),
                    ),
                )

        self.sim.call_after(wait_ps, _after_load)

    def _finish_step(
        self,
        program: ProgramSpec,
        step: int,
        n_steps: int,
        on_all_done: Optional[Callable[[], None]],
    ) -> None:
        def _next() -> None:
            self.log_event("step_end", step=step)
            self.steps_done += 1
            self._run_step(program, step + 1, n_steps, on_all_done)

        if self.ckpt_every and (step + 1) % self.ckpt_every == 0:
            self.log_event("ckpt_begin", step=step)
            n_shards = max(1, len(self.chips))
            shard_ps = int(self.ckpt_shard_bytes / (self.disk_bw / 1e12))

            def _write(i: int) -> None:
                if i >= n_shards:
                    self.log_event("ckpt_end", step=step)
                    _next()
                    return
                self.log_event("ckpt_shard_write", step=step, shard=i, bytes=self.ckpt_shard_bytes)
                self.sim.call_after(shard_ps, lambda: _write(i + 1))

            _write(0)
        else:
            _next()

    # -- failure injection ------------------------------------------------------------------

    def inject_stall(self, dur_ps: int, kind: str = "gc") -> None:
        """Fault hook: pause the host runtime for ``dur_ps`` at the next
        unit-of-work boundary (GC pause, page-fault storm, scheduler
        stall).  The stall is logged as a ``gc_stall`` event inside the
        affected step / request / microbatch when the workload driver
        drains it via :meth:`consume_stall`."""
        self._stall_ps += int(dur_ps)
        self._stall_kind = kind

    def consume_stall(self, **attrs) -> int:
        """Drain a pending injected stall: log it as a ``gc_stall`` event
        (the caller's unit-of-work attrs lead, then ``dur``/``cause``) and
        return the extra wait in ps, or 0 when none is pending.  Every
        workload driver calls this at its work boundaries, which is what
        makes the ``host_pause`` fault class compose with any workload."""
        if not self._stall_ps:
            return 0
        dur = self._stall_ps
        self.log_event("gc_stall", **attrs, dur=dur, cause=self._stall_kind)
        self._stall_ps = 0
        return dur

    @property
    def pending_stall_ps(self) -> int:
        """Injected-but-not-yet-drained stall time (mitigation telemetry:
        the ``checkpoint_restore`` trigger loop polls this)."""
        return self._stall_ps

    def cancel_stall(self) -> int:
        """Mitigation hook: drop a pending injected stall before the
        workload drains it, returning the cancelled duration in ps.  The
        caller (e.g. ``checkpoint_restore``) typically re-injects a shorter
        replay cost via :meth:`inject_stall`."""
        dur = self._stall_ps
        self._stall_ps = 0
        return dur

    def fail(self) -> None:
        self.failed = True
        self.log_event("host_failure")

    def restart(self, restored_step: int) -> None:
        self.failed = False
        self.log_event("host_restart", restored_step=restored_step)
        if hasattr(self, "_resume"):
            cb = self._resume
            del self._resume
            cb()

    # -- clock reads + NTP (case study §5) ---------------------------------------------------

    def start_clock_reads(self, every_ps: int, n: Optional[int] = None) -> PeriodicTask:
        """Sample the local clock every ``every_ps`` (``clock_read`` log
        events carry the host's view; the log line's timestamp carries the
        ground-truth global clock)."""
        return self.sim.every(
            every_ps,
            lambda i: self.log_event("clock_read", local=self.clock.local(self.sim.now)),
            n=n,
        )

    def start_ntp_client(
        self,
        server: "HostSim",
        every_ps: int = 1_000_000_000_000,   # 1 s
        n: Optional[int] = None,
        gain: float = 0.5,
        server_proc_ps: int = 50_000_000,    # 50 us server processing
    ) -> PeriodicTask:
        """chrony/NTP: request -> server -> response; estimate offset
        ((t2-t1)+(t3-t4))/2 and slew by -gain*estimate."""

        def _poll(i: int) -> None:
            t1 = self.clock.local(self.sim.now)

            def _at_server(_t: int) -> None:
                t2 = server.clock.local(self.sim.now)

                def _respond() -> None:
                    t3 = server.clock.local(self.sim.now)

                    def _at_client(_t2: int) -> None:
                        t4 = self.clock.local(self.sim.now)
                        est = ((t2 - t1) + (t3 - t4)) / 2
                        true_off = server.clock.true_offset(self.sim.now) - self.clock.true_offset(self.sim.now)
                        self.log_event(
                            "ntp_exchange",
                            t1=t1, t2=t2, t3=t3, t4=t4,
                            est_off=int(est), true_off=int(true_off), seq=i,
                        )
                        self.clock.slew(gain * est)

                    self.cluster.net.transfer(
                        server.name, self.name, NTP_PACKET_BYTES,
                        meta={"proto": "ntp", "dir": "resp", "seq": i, "peer": self.name},
                        on_delivered=_at_client,
                    )

                self.sim.call_after(server_proc_ps, _respond)

            self.cluster.net.transfer(
                self.name, server.name, NTP_PACKET_BYTES,
                meta={"proto": "ntp", "dir": "req", "seq": i, "peer": self.name},
                on_delivered=_at_server,
            )

        return self.sim.every(every_ps, _poll, n=n)

    def start_heartbeats(self, every_ps: int = 10_000_000_000, n: Optional[int] = None) -> PeriodicTask:
        """Emit ``heartbeat`` log events every ``every_ps`` (liveness
        telemetry; the failure scenarios read their absence)."""
        return self.sim.every(every_ps, lambda i: self.log_event("heartbeat", seq=i), n=n)


def _short(chip: str) -> str:
    """'pod0.chip03' -> 'chip03' (hosts address chips by local id)."""
    return chip.rsplit(".", 1)[-1]
