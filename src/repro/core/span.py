"""Span / trace model (Columbo's internal representation, §3.6–3.7).

Deliberately close to OpenTelemetry semantics so exporters are thin:
a Span has a SpanContext (trace_id, span_id), an optional parent, zero or
more *links* (causal, non-tree edges — used across simulator boundaries),
timestamps in picoseconds, attributes, and point-in-time span events.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

_span_counter = itertools.count(1)
_trace_counter = itertools.count(1)


def new_span_id() -> int:
    """Next process-wide span id (see ``reset_ids`` for determinism)."""
    return next(_span_counter)


def new_trace_id() -> int:
    """Next process-wide trace id (see ``reset_ids`` for determinism)."""
    return next(_trace_counter)


def reset_ids() -> None:
    """Test hook: deterministic ids."""
    global _span_counter, _trace_counter
    _span_counter = itertools.count(1)
    _trace_counter = itertools.count(1)


@dataclass(frozen=True, slots=True)
class SpanContext:
    """What gets propagated between SpanWeavers (paper §3.6)."""

    trace_id: int
    span_id: int

    def hex_trace(self) -> str:
        return f"{self.trace_id:032x}"

    def hex_span(self) -> str:
        return f"{self.span_id:016x}"


@dataclass(slots=True)
class Span:
    """One finished operation interval (OpenTelemetry-shaped): context,
    optional parent, causal links, attributes, point-in-time events."""

    name: str
    start: int                       # ps
    end: int                         # ps
    context: SpanContext
    parent: Optional[SpanContext] = None
    links: List[SpanContext] = field(default_factory=list)
    component: str = ""              # component instance ("chip03", "host0", ...)
    sim_type: str = ""               # host | device | net
    attrs: Dict[str, Any] = field(default_factory=dict)
    events: List[Tuple[int, str, Dict[str, Any]]] = field(default_factory=list)

    @property
    def duration(self) -> int:
        return self.end - self.start

    def add_event(self, ts: int, name: str, attrs: Optional[Dict[str, Any]] = None) -> None:
        self.events.append((ts, name, attrs or {}))

    def add_link(self, ctx: SpanContext) -> None:
        self.links.append(ctx)


class SpanBuilder:
    """Mutable under-construction span held by a SpanWeaver."""

    __slots__ = ("span",)

    def __init__(
        self,
        name: str,
        start: int,
        trace_id: int,
        parent: Optional[SpanContext] = None,
        component: str = "",
        sim_type: str = "",
        attrs: Optional[Dict[str, Any]] = None,
    ) -> None:
        self.span = Span(
            name=name,
            start=start,
            end=start,
            context=SpanContext(trace_id=trace_id, span_id=new_span_id()),
            parent=parent,
            component=component,
            sim_type=sim_type,
            attrs=dict(attrs or {}),
        )

    @property
    def context(self) -> SpanContext:
        return self.span.context

    def finish(self, end: int) -> Span:
        self.span.end = max(end, self.span.start)
        return self.span


@dataclass
class Trace:
    """Assembled view over spans sharing one trace_id."""

    trace_id: int
    spans: List[Span] = field(default_factory=list)

    def roots(self) -> List[Span]:
        ids = {s.context.span_id for s in self.spans}
        return [s for s in self.spans if s.parent is None or s.parent.span_id not in ids]

    def children_of(self, span: Span) -> List[Span]:
        sid = span.context.span_id
        return [s for s in self.spans if s.parent is not None and s.parent.span_id == sid]

    @property
    def start(self) -> int:
        return min(s.start for s in self.spans)

    @property
    def end(self) -> int:
        return max(s.end for s in self.spans)


def assemble_traces(spans: Iterable[Span]) -> Dict[int, Trace]:
    """Group spans by trace_id into :class:`Trace` views."""
    traces: Dict[int, Trace] = {}
    for s in spans:
        traces.setdefault(s.context.trace_id, Trace(s.context.trace_id)).spans.append(s)
    return traces
