"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global attention (window 1024), 128k context.
[hf:google/gemma-3-27b-pt; unverified].  head_dim=128 per the public config
(not d_model/n_heads); GeGLU MLP.

long_500k: SKIPPED — every 6th layer is full global attention (assignment
rule: skip for archs whose attention path is quadratic at 500k prefill).
"""
from ..models.config import ModelConfig
from . import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab_size=262144,
        head_dim=128,
        block_pattern=("attn_local",) * 5 + ("attn",),
        window=1024,
        qk_norm=True,
        mlp_act="geglu",
        rope_theta=1_000_000.0,
    ),
    microbatches={"train_4k": 8},
    kv_cache_dtype={"decode_32k": "int8"},
    notes="62 = 10 full (5L+1G) groups + 2 remainder local layers; "
    "int8 KV for decode_32k (global-layer caches dominate HBM)",
)
