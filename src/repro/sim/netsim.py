"""Interconnect simulator (the ns3 role in the paper's testbed).

Simulates ICI / DCN / PCIe / Ethernet links with FIFO tx queues and fixed
propagation latency, moves "chunks" (collective shards, DMA buffers, NTP
packets, background-traffic segments) along multi-link routes, and writes an
ns3-ascii-flavoured log::

    + <t_s> /<LinkPath> chunk=<id> size=<bytes> ...     (enqueued)
    - <t_s> /<LinkPath> chunk=<id> ...                  (starts on the wire)
    r <t_s> /<LinkPath> chunk=<id> ...                  (received at far end)

Background traffic (paper §5 scenario 2) is a BulkSend-style flow that
saturates a link with back-to-back segments, inducing queueing delay for
everything sharing the link.
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .clock import LogWriter
from .engine import PeriodicTask, SimPort
from .topology import Link, Topology

PS_PER_S = 1_000_000_000_000


@dataclass
class LinkFault:
    """Runtime fault state installed on one link (see sim/faults.py).

    * ``loss_prob``     — per-chunk probability the wire copy is dropped;
      the link layer retransmits after ``retransmit_ps``, so delivery still
      happens (collectives terminate) but late, and a ``d`` mark is logged.
    * ``jitter_ps``     — uniform extra propagation delay in [0, jitter_ps),
      breaking the link's natural FIFO arrival order (in-flight reordering).
    * ``loss_trace``    — optional ``now -> prob`` callable (compiled from a
      :class:`~repro.sim.faults.LossRateTrace`) making the drop probability
      time-varying; ``None`` keeps the constant ``loss_prob`` behaviour and
      its exact draw sequence.

    Draws come from the fault's own seeded ``rng``; the DES executes in a
    deterministic order, so the same seed reproduces the same byte stream.
    """

    loss_prob: float = 0.0
    retransmit_ps: int = 0
    jitter_ps: int = 0
    start_ps: int = 0
    stop_ps: Optional[int] = None
    # seeded default so direct install_link_fault() users keep the
    # reproducibility contract; FaultPlan supplies per-fault streams
    rng: random.Random = field(default_factory=lambda: random.Random(0))
    drops: int = 0
    loss_trace: Optional[Callable[[int], float]] = None

    def active(self, now: int) -> bool:
        return now >= self.start_ps and (self.stop_ps is None or now < self.stop_ps)


class _Transfer:
    """One in-flight chunk: per-transfer state reused across hops.

    Replaces the two per-hop closures the hot path used to allocate (the
    wire mark and the receive continuation) with slot mutations on one
    object; safe because a hop's wire event always fires strictly before
    the next hop overwrites the per-hop fields (``arrive > start`` — links
    have non-zero latency)."""

    __slots__ = (
        "net", "cid", "route", "i", "nbytes", "meta", "on_delivered",
        "quiet", "start", "arrive", "link_name", "wire_cb", "rx_cb",
    )

    def __init__(self, net: "NetSim", cid: str, route: List["Link"], nbytes: int,
                 meta: Dict, on_delivered: Optional[Callable[[int], None]],
                 quiet: bool) -> None:
        self.net = net
        self.cid = cid
        self.route = route                # pre-resolved Link objects
        self.i = 0
        self.nbytes = nbytes
        self.meta = meta
        self.on_delivered = on_delivered
        self.quiet = quiet
        # bind the continuations once per transfer, not once per hop
        self.wire_cb = self.wire
        self.rx_cb = self.rx

    def wire(self) -> None:
        """'-' mark: the chunk's current hop starts on the wire."""
        self.net._emit(
            (self.start, "-", self.link_name, self.cid, self.nbytes, self.meta)
        )

    def drop(self) -> None:
        """'d' mark: the wire copy was lost (link layer will retransmit)."""
        self.net._emit(
            (self.start, "d", self.link_name, self.cid, self.nbytes, self.meta)
        )

    def rx(self) -> None:
        """'r' mark + continue: next hop, or final delivery callback."""
        net = self.net
        if not self.quiet:
            net._emit(
                (self.arrive, "r", self.link_name, self.cid, self.nbytes, self.meta)
            )
        i = self.i + 1
        if i < len(self.route):
            self.i = i
            net._hop(self)
        else:
            # break the self -> bound-method -> self cycle so a delivered
            # transfer is reclaimed by refcounting alone — the kernel
            # pauses the cyclic GC for the whole drain, so cyclic garbage
            # would otherwise accumulate for the run's duration
            self.wire_cb = self.rx_cb = None
            net.chunks_delivered += 1
            net.bytes_delivered += self.nbytes
            if self.on_delivered is not None:
                self.on_delivered(self.arrive)


class NetSim:
    """Interconnect simulator: moves chunks along multi-link FIFO routes."""

    def __init__(self, sim: SimPort, topo: Topology, log: LogWriter) -> None:
        self.sim = sim
        self.topo = topo
        self.log = log
        self._chunk_ids = itertools.count()
        self.chunks_delivered = 0
        self.bytes_delivered = 0
        self.chunks_dropped = 0
        self.flows_stopped = False
        self._flow_tasks: List[PeriodicTask] = []
        self.link_faults: Dict[str, List[LinkFault]] = {}
        # mitigation hook: when set, rewrites the link-layer retransmit
        # delay of each dropped chunk (consulted only on the drop branch,
        # so the no-mitigation hot path pays nothing)
        self._retransmit_cb: Optional[Callable[[str, str, int, int], int]] = None
        # hot-path bindings: every chunk hop logs up to 3 marks and
        # schedules 2 events, so skip the SimPort/property indirection
        self._kernel = sim.kernel
        self._emit = log.emit_net

    # -- fault hooks (driven by sim/faults.py) ------------------------------------

    def install_link_fault(self, link_name: str, fault: LinkFault) -> LinkFault:
        """Attach loss / jitter behaviour to one link.  Multiple faults on a
        link compose (each consulted per chunk)."""
        if link_name not in self.topo.links:
            raise KeyError(f"unknown link {link_name!r}")
        self.link_faults.setdefault(link_name, []).append(fault)
        return fault

    def scale_link_bw(self, link_name: str, factor: float) -> None:
        """Degrade (or restore) a link's bandwidth in place, effective for
        chunks that start transmitting after ``sim.now``."""
        self.topo.links[link_name].bw *= factor

    # -- mitigation hooks (driven by sim/mitigation.py) ----------------------------

    def set_retransmit_policy(
        self, cb: Optional[Callable[[str, str, int, int], int]]
    ) -> None:
        """Install (or clear) a retransmit override for dropped chunks.

        ``cb(link_name, chunk_id, drop_ps, default_retrans_ps)`` returns the
        retransmit delay to charge instead of the link layer's default —
        the ``retransmit`` mitigation policy's loss-protection hook.
        """
        self._retransmit_cb = cb

    def link_drop_counts(self) -> Dict[str, int]:
        """Per-link dropped-chunk counters (summed over that link's faults)
        — the loss telemetry mitigation trigger loops poll."""
        return {
            name: sum(f.drops for f in faults)
            for name, faults in self.link_faults.items()
        }

    # -- core transfer -----------------------------------------------------------

    def transfer(
        self,
        src: str,
        dst: str,
        nbytes: int,
        meta: Optional[Dict] = None,
        on_delivered: Optional[Callable[[int], None]] = None,
        chunk_id: Optional[str] = None,
        quiet: bool = False,
    ) -> str:
        """Send nbytes src->dst along the static route; calls on_delivered(t)."""
        cid = chunk_id or f"c{next(self._chunk_ids)}"
        route = self.topo.route_links(src, dst)
        self._hop(_Transfer(self, cid, route, nbytes, meta or {}, on_delivered, quiet))
        return cid

    def _hop(self, t: _Transfer) -> None:
        link = t.route[t.i]
        kernel = self._kernel
        port = self.sim
        now = kernel.now
        link_name = link.name
        t.link_name = link_name
        quiet = t.quiet
        nbytes = t.nbytes
        if not quiet:
            self._emit((now, "+", link_name, t.cid, nbytes, t.meta))
        start = link.busy_until
        if start < now:
            start = now
        t.start = start
        tx_ps = int(nbytes / (link.bw / PS_PER_S))
        link.busy_until = start + tx_ps
        link.bytes_tx += nbytes

        if not quiet:
            # the wire event fires exactly at ``t.start``, strictly before
            # the next hop can overwrite the per-hop fields
            kernel.call_at(start, t.wire_cb, port)
        arrive = start + tx_ps + link.latency_ps
        if self.link_faults:
            for fault in self.link_faults.get(link_name, ()):
                if not fault.active(now):
                    continue
                p = (fault.loss_prob if fault.loss_trace is None
                     else fault.loss_trace(now))
                if p and fault.rng.random() < p:
                    fault.drops += 1
                    self.chunks_dropped += 1
                    retrans = fault.retransmit_ps or 2 * (tx_ps + link.latency_ps)
                    if self._retransmit_cb is not None:
                        retrans = self._retransmit_cb(
                            link_name, t.cid, start, retrans
                        )
                    if not quiet:
                        # ns3-style 'd' mark: the wire copy is lost at tx
                        # time; the link layer retransmits, delaying arrival
                        kernel.call_at(start, t.drop, port)
                    arrive += retrans
                if fault.jitter_ps:
                    arrive += fault.rng.randrange(fault.jitter_ps)
        t.arrive = arrive
        kernel.call_at(arrive, t.rx_cb, port)

    def _log_mark(self, mark: str, link: Link, cid: str, nbytes: int, meta: Dict) -> None:
        # the sink owns the format: text (ns3 ascii flavour) on the
        # compatibility path, a zero-format record capture on the fast path
        self._emit((self.sim.now, mark, link.name, cid, nbytes, meta))

    # -- background traffic (BulkSend analogue) -----------------------------------

    def start_bulk_flow(
        self,
        src: str,
        dst: str,
        rate_bytes_per_s: float,
        segment_bytes: int = 65536,
        start_ps: int = 0,
        stop_ps: Optional[int] = None,
        flow_id: str = "bg0",
    ) -> PeriodicTask:
        """BulkSend analogue: back-to-back ``segment_bytes`` transfers at
        ``rate_bytes_per_s``, as a cancellable kernel :class:`PeriodicTask`
        (no wake-ups survive past :meth:`stop_all_flows`)."""
        interval_ps = int(segment_bytes / (rate_bytes_per_s / PS_PER_S))

        def _send(i: int) -> None:
            self.transfer(
                src,
                dst,
                segment_bytes,
                meta={"flow": flow_id, "seq": i},
                quiet=False,
            )

        task = self.sim.every(interval_ps, _send, first_at=start_ps, stop_ps=stop_ps)
        self._flow_tasks.append(task)
        if self.flows_stopped:
            # flows were already stopped (workload drained): a late-started
            # flow must not outlive them
            task.cancel()
        return task

    def stop_all_flows(self) -> None:
        """Cancel every background flow's pending event (lets training sims
        drain and terminate once the workload completes)."""
        self.flows_stopped = True
        for task in self._flow_tasks:
            task.cancel()
