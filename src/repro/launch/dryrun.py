import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 placeholder CPU devices.

Per cell this produces (results/dryrun/<arch>.<shape>.<mesh>[.tag].json):

* memory_analysis()            — proof the program fits per-device HBM
* cost_analysis()              — HLO FLOPs / bytes (per device)
* collective_stats()           — per-kind collective operand bytes, parsed
                                 from the optimized (post-SPMD) HLO text
* cost-mode (--mode cost)      — depth-1-period and depth-2-period compiles
                                 with layers AND inner scans unrolled, from
                                 which exact per-layer costs are derived
                                 (XLA's cost_analysis does not multiply
                                 while-loop bodies by trip count; see
                                 DESIGN.md / EXPERIMENTS.md §Methodology)

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --mode cost
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from ..configs import ARCHS, SHAPES, get_arch
from ..models.sharding import sharding_context
from ..xla.hlo_stats import collective_stats, cost_summary, memory_stats, tpu_adjusted_bytes
from .mesh import make_production_mesh
from .specs import build_cell
from .steps import make_step_fn

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _compile_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    fsdp: bool = True,
    zero1: bool = False,
    parallel_mode: str = "tp",
    cfg_overrides: Optional[Dict[str, Any]] = None,
    verbose: bool = True,
) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape, mesh, fsdp=fsdp, zero1=zero1,
                      parallel_mode=parallel_mode, cfg_overrides=cfg_overrides)
    fn = make_step_fn(cell)
    t0 = time.time()
    with mesh:
        with sharding_context(mesh, cell.rules):
            kw = {}
            if cell.out_shardings is not None:
                kw["out_shardings"] = cell.out_shardings
            jitted = jax.jit(
                fn, in_shardings=cell.in_shardings, donate_argnums=cell.donate, **kw
            )
            lowered = jitted.lower(*cell.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    mem = memory_stats(compiled)
    cost = cost_summary(compiled)
    colls = collective_stats(compiled.as_text())
    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": cost,
        "collectives": {
            "total_bytes": colls["total_bytes"],
            "wire_bytes": colls["wire_bytes"],
            "per_kind": colls["per_kind"],
        },
        "sharding_fallbacks": {f"{k[0]}[{k[1]}]": v for k, v in cell.rules.fallbacks.items()},
        "microbatches": cell.microbatches if cell.kind == "train" else None,
        "kind": cell.kind,
        "model": {
            "n_params": cell.cfg.n_params,
            "n_active_params": cell.cfg.n_active_params,
        },
    }
    if verbose:
        print(
            f"[dryrun] {arch:24s} {shape:12s} {rec['mesh']:8s} OK "
            f"mem/dev={mem['total_bytes'] / 2**30:6.2f}GiB "
            f"flops/dev={cost['flops']:.3e} coll={colls['total_bytes']:.3e}B "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
            flush=True,
        )
    return rec


def _cost_mode_cell(arch: str, shape: str, fsdp: bool = True, zero1: bool = False,
                    parallel_mode: str = "tp",
                    cfg_overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Depth-extrapolated exact costs: compile depth=P and depth=2P unrolled."""
    spec = get_arch(arch)
    cfg = spec.config_for(shape)
    P_ = cfg.pattern_period
    out: Dict[str, Any] = {"arch": arch, "shape": shape, "mode": "cost", "ok": True}
    variants = {}
    for depth_periods in (1, 2):
        overrides = dict(
            n_layers=depth_periods * P_,
            scan_layers=False,
            unroll_inner=True,
            attn_block_q=2048,
            scan_chunk=2048,
        )
        if cfg_overrides:
            ov = dict(cfg_overrides)
            ov.pop("n_layers", None)
            overrides.update(ov)
        mesh = make_production_mesh(multi_pod=False)
        cell = build_cell(arch, shape, mesh, fsdp=fsdp, zero1=zero1,
                          parallel_mode=parallel_mode, cfg_overrides=overrides)
        # cost-equivalent: grad-accum is linear in microbatches, but scan
        # bodies are counted once by cost_analysis -> measure with mb=1
        cell.microbatches = 1
        fn = make_step_fn(cell)
        t0 = time.time()
        with mesh:
            with sharding_context(mesh, cell.rules):
                kw = {}
                if cell.out_shardings is not None and cell.kind == "train":
                    kw["out_shardings"] = cell.out_shardings
                compiled = (
                    jax.jit(fn, in_shardings=cell.in_shardings,
                            donate_argnums=cell.donate, **kw)
                    .lower(*cell.abstract_args)
                    .compile()
                )
        cost = cost_summary(compiled)
        text = compiled.as_text()
        colls = collective_stats(text)
        adj = tpu_adjusted_bytes(text)
        variants[depth_periods] = {
            "flops": cost["flops"],
            "bytes": cost["bytes_accessed"],
            "tpu_bytes": adj["total"],
            "coll_bytes": colls["total_bytes"],
            "wire_bytes": colls["wire_bytes"],
            "coll_per_kind": {k: v["bytes"] for k, v in colls["per_kind"].items()},
            "compile_s": round(time.time() - t0, 1),
        }
        print(
            f"[cost] {arch} {shape} depth={depth_periods}P flops={cost['flops']:.3e} "
            f"coll={colls['total_bytes']:.3e} ({variants[depth_periods]['compile_s']}s)",
            flush=True,
        )
    c1, c2 = variants[1], variants[2]
    n_periods = cfg.n_layers / P_   # fractional part covers remainder layers
    extrap = {}
    for key in ("flops", "bytes", "tpu_bytes", "coll_bytes", "wire_bytes"):
        per_period = c2[key] - c1[key]
        outside = c1[key] - per_period
        extrap[key] = outside + n_periods * per_period
        extrap[f"{key}_per_period"] = per_period
        extrap[f"{key}_outside"] = outside
    extrap["coll_per_kind"] = {
        k: (c1["coll_per_kind"][k] - (c2["coll_per_kind"][k] - c1["coll_per_kind"][k]))
        + n_periods * (c2["coll_per_kind"][k] - c1["coll_per_kind"][k])
        for k in c1["coll_per_kind"]
    }
    out["variants"] = variants
    out["extrapolated"] = extrap
    out["n_periods"] = n_periods
    out["microbatches"] = spec.microbatches.get(shape, 1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="full", choices=["full", "cost"])
    ap.add_argument("--outdir", default=RESULTS_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1: replicate params over data, shard optimizer")
    ap.add_argument("--parallel-mode", default="tp", choices=["tp", "fsdp_all"])
    ap.add_argument("--override", default="", help="cfg overrides k=v,k=v (ints/bools)")
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")

    overrides: Dict[str, Any] = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        overrides[k] = {"true": True, "false": False}.get(v.lower(), None)
        if overrides[k] is None:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    failures = 0
    for arch in archs:
        spec = get_arch(arch)
        shapes = spec.shape_names() if args.shape == "all" else args.shape.split(",")
        for shape in shapes:
            if shape not in spec.shape_names():
                print(f"[dryrun] {arch} {shape} SKIPPED (not applicable)", flush=True)
                continue
            meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
            if args.mode == "cost":
                meshes = [False]
            for multi_pod in meshes:
                tag = f".{args.tag}" if args.tag else ""
                mesh_name = "2x16x16" if multi_pod else "16x16"
                suffix = "cost" if args.mode == "cost" else mesh_name
                path = os.path.join(args.outdir, f"{arch}.{shape}.{suffix}{tag}.json")
                try:
                    if args.mode == "cost":
                        rec = _cost_mode_cell(arch, shape, fsdp=not args.no_fsdp,
                                              zero1=args.zero1,
                                              parallel_mode=args.parallel_mode,
                                              cfg_overrides=overrides or None)
                    else:
                        rec = _compile_cell(arch, shape, multi_pod,
                                            fsdp=not args.no_fsdp,
                                            zero1=args.zero1,
                                            parallel_mode=args.parallel_mode,
                                            cfg_overrides=overrides or None)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures += 1
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "ok": False, "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"[dryrun] {arch} {shape} {mesh_name} FAILED: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"[dryrun] done, failures={failures}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
