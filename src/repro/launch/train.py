"""Training launcher: ``python -m repro.launch.train --arch olmo-1b ...``

Runs real training (synthetic data) on whatever devices exist.  With
``--devices N`` it forces N host platform devices (must be first, before
jax initializes) and builds a (data, model) mesh — the same code path the
production mesh uses.
"""
import argparse
import dataclasses
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="", help="e.g. 4x2 -> (data=4, model=2)")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--preemption-file", default=None)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    import jax

    from ..configs import get_arch
    from ..training.optimizer import AdamWConfig
    from ..training.train_step import TrainConfig
    from ..training.trainer import Trainer, TrainerConfig
    from .mesh import make_mesh

    cfg = get_arch(args.arch).config
    if args.smoke:
        cfg = cfg.reduced()

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    tc = TrainConfig(
        adamw=AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 10, 1)),
        microbatches=args.microbatches,
    )
    trainer = Trainer(
        cfg,
        tc,
        TrainerConfig(
            total_steps=args.steps,
            ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir,
            preemption_file=args.preemption_file,
        ),
        mesh=mesh,
    )
    state = trainer.run()
    final = trainer.metrics_log[-1] if trainer.metrics_log else {}
    print(f"done at step {int(jax.device_get(state['step']))}: {final}")


if __name__ == "__main__":
    main()
