"""Mixture-of-Experts block: GShard/Switch-style token-choice top-k routing
with per-group capacity, einsum dispatch (TPU/GSPMD-friendly: the expert
dimension shards over "model"/EP and XLA inserts the all-to-alls).

granite-moe-1b: 32 experts, top-8, expert d_ff 512.
llama4-scout:   16 experts, top-1, expert d_ff 8192 + always-on shared expert.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import mlp, mlp_pspecs
from .params import PSpec

Params = Dict[str, Any]


def moe_pspecs(cfg: ModelConfig) -> Params:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    p: Params = {
        "router": PSpec((d, E), ("embed", None), init="lecun"),
        "w_gate": PSpec((E, d, f), ("expert", "embed", None), init="lecun"),
        "w_up": PSpec((E, d, f), ("expert", "embed", None), init="lecun"),
        "w_down": PSpec((E, f, d), ("expert", None, "embed"), init="lecun"),
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = mlp_pspecs(cfg, d_ff=cfg.shared_expert_d_ff)
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_block(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                      # (B, S, d)
    group_size: int = 1024,
    train: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss).

    Capacity-based token dropping only applies when ``train=True``: drops
    depend on the other tokens in the group, so a capacity-bound forward()
    diverges from incremental decode (which sees one token per call and can
    never overflow).  Inference is dropless — C = Tg covers the worst case
    exactly, because top_k yields distinct experts per token, so an expert
    receives at most Tg assignments.
    """
    B, S, d = x.shape
    dt = x.dtype
    E, k = cfg.n_experts, cfg.top_k
    Tg = min(group_size, S)
    G = (B * S) // Tg
    xg = x.reshape(G, Tg, d)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, Tg, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # (G, Tg, k)
    # renormalize the selected gates
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = _capacity(cfg, Tg) if train else Tg
    counts = jnp.zeros((G, E), jnp.float32)
    dispatch = jnp.zeros((G, Tg, E, C), dtype=dt)
    combine = jnp.zeros((G, Tg, E, C), dtype=jnp.float32)
    for j in range(k):
        m = jax.nn.one_hot(gate_idx[..., j], E, dtype=jnp.float32)      # (G,Tg,E)
        pos = jnp.cumsum(m, axis=1) - m + counts[:, None, :]            # slot index
        keep = (pos < C) * m                                            # (G,Tg,E)
        counts = counts + keep.sum(axis=1)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
        dd = keep[..., None] * pos_oh                                   # (G,Tg,E,C)
        dispatch = dispatch + dd.astype(dt)
        combine = combine + gate_vals[..., j, None, None] * dd

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)              # (G,E,C,d)
    h_gate = jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"].astype(dt))
    if cfg.mlp_act in ("swiglu", "geglu"):
        h_up = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"].astype(dt))
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        h = act(h_gate) * h_up
    else:
        h = jax.nn.gelu(h_gate)
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(dt), expert_out)
    y = y.reshape(B, S, d)

    if cfg.shared_expert_d_ff:
        y = y + mlp(cfg, p["shared"], x)

    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    f_e = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e)
    return y, aux
