"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,            # (B, H, S, D)
    k: jax.Array,            # (B, K, S, D)
    v: jax.Array,            # (B, K, S, D)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, S, D = q.shape
    K = k.shape[1]
    g = H // K
    scale = scale if scale is not None else D ** -0.5
    qh = q.reshape(B, K, g, S, D).astype(jnp.float32) * scale
    s = jnp.einsum("bkgqd,bktd->bkgqt", qh, k.astype(jnp.float32))
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,            # (B, H, D) single query per sequence
    k: jax.Array,            # (B, K, S, D)
    v: jax.Array,            # (B, K, S, D)
    valid_len: jax.Array,    # scalar or (B,): number of valid cache slots
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, D = q.shape
    K, S = k.shape[1], k.shape[2]
    g = H // K
    scale = scale if scale is not None else D ** -0.5
    qh = q.reshape(B, K, g, D).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bktd->bkgt", qh, k.astype(jnp.float32))
    t = jnp.arange(S)
    vl = jnp.asarray(valid_len)
    valid = t[None, :] < (vl[:, None] if vl.ndim else vl[None, None])
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def rglru_scan_ref(
    a: jax.Array,            # (B, L, W) decay in (0,1], f32
    x: jax.Array,            # (B, L, W) gated input, f32
    h0: jax.Array,           # (B, W)
) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + x_t.  Returns (h_all (B,L,W), h_final)."""

    def step(h, ax):
        at, xt = ax
        h = at * h + xt
        return h, h

    hT, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), x.swapaxes(0, 1)))
    return hs.swapaxes(0, 1), hT


def ssm_scan_ref(
    a: jax.Array,            # (B, L, Di, N) decay
    bx: jax.Array,           # (B, L, Di, N) input
    c: jax.Array,            # (B, L, N) output projection
    h0: jax.Array,           # (B, Di, N)
) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t*h + bx_t; y_t = sum_N h_t * c_t.  Returns (y (B,L,Di), h_T)."""

    def step(h, inp):
        at, bxt, ct = inp
        h = at * h + bxt
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0, (a.swapaxes(0, 1), bx.swapaxes(0, 1), c.swapaxes(0, 1))
    )
    return ys.swapaxes(0, 1), hT


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    n = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (n * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
