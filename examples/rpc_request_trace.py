"""Trace one RPC request end to end — the per-request payoff of
full-system simulation.

    PYTHONPATH=src python examples/rpc_request_trace.py

Serves an open-loop request stream against a 2-pod testbed whose frontend
pod has one degraded ICI link (the ``rpc_tail_latency`` library scenario),
then answers the on-call question aggregate dashboards can't: *why was the
slowest request slow?* — by walking that single request's span tree (host
-> device -> interconnect) and running ``diagnose()`` on its trace alone.
"""
import os

from repro.core import (
    ChromeTraceExporter,
    assemble_traces,
    diagnose,
    request_latency_stats,
    request_report,
    rpc_requests,
    slowest_request,
)
from repro.sim import get_scenario


def main() -> None:
    outdir = os.environ.get("RPC_TRACE_OUT", "results/rpc_trace")
    os.makedirs(outdir, exist_ok=True)

    # 1. simulate serving under a fault: open-loop arrivals, fan-out across
    #    pods, one degraded ICI link in the frontend pod (structured fast
    #    path — no text logs; byte-identical spans either way)
    run = get_scenario("rpc_tail_latency").run(
        exporters=(ChromeTraceExporter(os.path.join(outdir, "rpc.chrome.json")),),
        structured=True,
    )
    print(run.report())

    # 2. the serving view: end-to-end request latency percentiles
    stats = request_latency_stats(run.spans)
    print(f"\n{stats['n']:.0f} requests: p50={stats['p50']:.0f}us "
          f"p90={stats['p90']:.0f}us p99={stats['p99']:.0f}us "
          f"max={stats['max']:.0f}us")

    # 3. drill into the slowest request: its whole span tree is one trace
    trace = slowest_request(run.spans)
    root = rpc_requests(trace.spans)[0]
    print(f"\nslowest request {root.attrs['rid']!r} "
          f"({root.duration / 1e6:.0f}us) touches "
          f"{len(trace.spans)} spans across "
          f"{sorted({s.sim_type for s in trace.spans})}")

    # 4. attribute it: diagnose() over just this request's spans names the
    #    degraded link — per-request root-cause, not a fleet-wide average
    for f in diagnose(trace.spans).findings:
        print(f"  {f}")

    # 5. or let the one-call report do 2-4 (what the CLI prints)
    print("\n" + request_report(run.spans))

    n_req_traces = len({s.context.trace_id for s in rpc_requests(run.spans)})
    n_traces = len(assemble_traces(run.spans))
    print(f"\n{n_req_traces} request traces (of {n_traces} total); "
          f"Chrome trace for Perfetto: {outdir}/rpc.chrome.json")


if __name__ == "__main__":
    main()
