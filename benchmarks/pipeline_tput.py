"""Columbo processing throughput (§3.5 'large amounts of data').

Measures end-to-end log-line -> span throughput of a single pipeline
(parse + weave) and of the parser alone, on a synthetic gem5-flavoured
device log — plus the structured fast path over the *same* events (no
text round-trip), so the format/parse tax is visible as a ratio.  The
paper's concern is 100s of GB of logs; events/s here sets the
single-core processing rate.
"""
import os
import tempfile
import time


def _gen_device_log(path: str, n_ops: int) -> int:
    lines = 0
    with open(path, "w") as f:
        f.write("0: system.pod0.chip00: ProgramStart: program=train_step step=0\n")
        lines += 1
        for i in range(n_ops):
            t = 1000 + i * 2000
            f.write(
                f"{t}: system.pod0.chip00: OpBegin: op=op{i} name=seg{i} flops=1000000 bytes=5000 step=0\n"
            )
            f.write(f"{t+100}: system.pod0.chip00: HbmRead: op=op{i} bytes=3000\n")
            f.write(f"{t+1500}: system.pod0.chip00: OpEnd: op=op{i} name=seg{i} step=0\n")
            lines += 3
        f.write(f"{1000 + n_ops * 2000}: system.pod0.chip00: ProgramEnd: program=train_step step=0\n")
        lines += 1
    return lines


def run():
    from repro.core import LogFileProducer, Pipeline, SimType, TraceSession, parser_for

    rows = []
    n_ops = 100_000
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "device.log")
        n_lines = _gen_device_log(path, n_ops)
        size_mb = os.path.getsize(path) / 2**20

        # parse-only
        class _Null:
            def consume(self, ev):
                pass

            def on_finish(self):
                pass

        t0 = time.perf_counter()
        p = Pipeline(LogFileProducer(path, parser_for(SimType.DEVICE)), (), _Null())
        p.run_sync()
        dt = time.perf_counter() - t0
        rows.append(
            ("pipeline.parse_only", dt * 1e6,
             f"{p.events_in/dt:,.0f} ev/s {size_mb/dt:.1f} MB/s lines={n_lines}")
        )

        # parse + weave + finalize
        t0 = time.perf_counter()
        spans = TraceSession().add_log(path, SimType.DEVICE).run()
        dt_text = time.perf_counter() - t0
        rows.append(
            ("pipeline.parse_weave", dt_text * 1e6,
             f"{(3*n_ops+2)/dt_text:,.0f} ev/s {len(spans):,} spans {size_mb/dt_text:.1f} MB/s")
        )

        # structured fast path: weave the same events with no text
        # round-trip (what a StructuredLogWriter feeds the session)
        events = list(LogFileProducer(path, parser_for(SimType.DEVICE)).events())
        t0 = time.perf_counter()
        spans_fast = TraceSession().add_events(events, SimType.DEVICE).run()
        dt_fast = time.perf_counter() - t0
        rows.append(
            ("pipeline.weave_structured", dt_fast * 1e6,
             f"{(3*n_ops+2)/dt_fast:,.0f} ev/s {len(spans_fast):,} spans "
             f"{dt_text/dt_fast:.1f}x vs parse_weave")
        )

        # sharded: the same log split into 4 contiguous shards, merged back
        # into one weaver (the multipod-scale input path)
        shard_paths = [os.path.join(d, f"device.shard{i}.log") for i in range(4)]
        with open(path) as f:
            all_lines = f.readlines()
        per = (len(all_lines) + 3) // 4
        for i, sp in enumerate(shard_paths):
            with open(sp, "w") as f:
                f.writelines(all_lines[i * per:(i + 1) * per])
        t0 = time.perf_counter()
        sharded = TraceSession().add_shards(shard_paths, SimType.DEVICE).run()
        dt = time.perf_counter() - t0
        rows.append(
            ("pipeline.parse_weave_sharded4", dt * 1e6,
             f"{(3*n_ops+2)/dt:,.0f} ev/s {len(sharded):,} spans "
             f"match={'yes' if len(sharded) == len(spans) else 'NO'}")
        )
    return rows
