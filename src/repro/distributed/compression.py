"""Gradient compression: int8 quantization with error feedback.

Used for the cross-pod (DCN) gradient reduction, where bandwidth — not
compute — bounds step time.  ``compressed_psum`` is the shard_map building
block; ``ef_compress_tree``/``ef_decompress_tree`` implement error-feedback
(the quantization residual is carried to the next step, which keeps SGD
convergence — tested in tests/test_compression.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8: returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def ef_compress(
    g: jax.Array, err: Optional[jax.Array] = None, block: int = 256
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback compression: returns (q, scales, new_err)."""
    g32 = g.astype(jnp.float32)
    if err is not None:
        g32 = g32 + err
    q, s = quantize_int8(g32, block)
    deq = dequantize_int8(q, s, g.shape)
    return q, s, (g32 - deq)


def compressed_psum(x: jax.Array, axis_name: str, block: int = 256) -> jax.Array:
    """int8-on-the-wire psum for shard_map code paths.

    Quantizes, all-gathers the int8 payload + scales over ``axis_name``,
    and sums dequantized shards locally: wire traffic is ~4x smaller than a
    f32 psum (int8 + 1 scale per block).
    """
    q, s = quantize_int8(x, block)
    qs = jax.lax.all_gather(q, axis_name)        # (N, blocks, block) int8
    ss = jax.lax.all_gather(s, axis_name)
    deq = qs.astype(jnp.float32) * ss
    total = deq.sum(axis=0).reshape(-1)
    n = 1
    for d in x.shape:
        n *= d
    return total[:n].reshape(x.shape)


def tree_ef_state(tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def tree_compressed_psum(
    grads: Any, err: Any, axis_name: str, block: int = 256
) -> Tuple[Any, Any]:
    """Error-feedback compressed psum over a gradient pytree (per leaf)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, new_e = ef_compress(g, e, block)
        qs = jax.lax.all_gather(q, axis_name)
        ss = jax.lax.all_gather(s, axis_name)
        total = (qs.astype(jnp.float32) * ss).sum(axis=0).reshape(-1)
        n = 1
        for d in g.shape:
            n *= d
        out_g.append(total[:n].reshape(g.shape))
        out_e.append(new_e)
    return (
        jax.tree_util.tree_unflatten(treedef, out_g),
        jax.tree_util.tree_unflatten(treedef, out_e),
    )
