"""Composable, deterministic fault injection for the full-system testbed.

The paper's payoff is diagnosing *anomalous* requests that aggregate tools
miss; this module is the injection half of that loop.  Each fault is a small
frozen dataclass that knows how to schedule itself onto a running
:class:`~repro.sim.cluster.ClusterOrchestrator`:

* :class:`LinkDegradation` / :class:`LinkLoss` / :class:`ChunkReorder` —
  interconnect faults (netsim): bandwidth collapse, lossy wire with
  link-layer retransmission, in-flight reordering via propagation jitter.
* :class:`HostPause` / :class:`ClockDrift` / :class:`ClockStep` — host
  runtime faults (hostsim + clock): GC-style stalls, oscillator drift,
  hard clock steps.
* :class:`DeviceSlowdown` / :class:`StragglerPod` — accelerator faults
  (devicesim / cluster): thermal throttling of one chip, a uniformly slow
  pod.

A :class:`FaultPlan` bundles faults with one integer seed.  Every random
draw a fault makes comes from a ``random.Random`` derived deterministically
from ``(seed, fault index)``, and the DES kernel executes events in a fixed
order — so one seed reproduces the *byte-identical* simulator logs (and
therefore byte-identical woven traces).

Each fault class carries a ``fault_class`` tag; ``core.analysis.diagnose``
emits findings tagged with the same names, closing the loop from injection
to detection (asserted per scenario in ``tests/test_scenarios.py``).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field, replace
from typing import ClassVar, List, Optional, Sequence, Tuple, TYPE_CHECKING

from .netsim import LinkFault

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .cluster import ClusterOrchestrator

# Fault classes diagnose() knows how to attribute.  Kept as module constants
# so rules and faults cannot drift apart silently.
LINK_DEGRADATION = "link_degradation"
LINK_LOSS = "link_loss"
LINK_REORDER = "link_reorder"
HOST_PAUSE = "host_pause"
CLOCK_FAULT = "clock_fault"
DEVICE_SLOWDOWN = "device_slowdown"
STRAGGLER_POD = "straggler_pod"

FAULT_CLASSES = (
    LINK_DEGRADATION, LINK_LOSS, LINK_REORDER, HOST_PAUSE, CLOCK_FAULT,
    DEVICE_SLOWDOWN, STRAGGLER_POD,
)


class FaultSpec:
    """Base class: a declarative fault that schedules itself on a cluster.

    Subclasses are frozen dataclasses (inert, hashable, diffable — same
    philosophy as :class:`~repro.core.session.TraceSpec`) and implement
    ``schedule(cluster, rng)``; ``rng`` is this fault's private seeded
    stream, supplied by the owning :class:`FaultPlan`.

    Two hooks close the loop with the scored diagnosis benchmark
    (``core.evaluation`` / ``benchmarks/diag_bench.py``):

    * :attr:`target` — the component name ``diagnose()`` is expected to pin
      the fault on (the link / host / chip / pod the fault degrades), used
      for component-naming accuracy scoring;
    * :meth:`scaled` — the same fault at a different intensity, used by the
      sweep's fault-magnitude axis to trace detection-sensitivity curves.
    """

    fault_class: ClassVar[str]

    def schedule(self, cluster: "ClusterOrchestrator", rng: random.Random) -> None:
        raise NotImplementedError

    @property
    def target(self) -> str:
        """The component a correct diagnosis names for this fault."""
        raise NotImplementedError

    def scaled(self, magnitude: float) -> "FaultSpec":
        """This fault at ``magnitude`` times its specified intensity.

        The contract every subclass honors: ``magnitude == 1.0`` returns
        ``self`` unchanged (so default sweeps stay byte-identical),
        ``magnitude == 0.0`` is a no-op fault (healthy behavior — the
        sensitivity curve's left edge), and intensity varies monotonically
        in between.  Timing knobs (``start_ps`` / ``at_ps`` / windows) are
        never scaled — only the degradation magnitude moves.
        """
        raise NotImplementedError

    def describe(self) -> str:
        return f"{type(self).__name__}({self.fault_class})"


# ---------------------------------------------------------------------------
# Interconnect faults (netsim)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkDegradation(FaultSpec):
    """Collapse one link's bandwidth by ``bw_factor`` for a time window."""

    fault_class: ClassVar[str] = LINK_DEGRADATION

    link: str
    bw_factor: float = 0.1
    start_ps: int = 0
    stop_ps: Optional[int] = None

    def schedule(self, cluster: "ClusterOrchestrator", rng: random.Random) -> None:
        net = cluster.net
        if self.link not in cluster.topo.links:
            raise KeyError(f"unknown link {self.link!r}")
        cluster.sim.at(self.start_ps, lambda: net.scale_link_bw(self.link, self.bw_factor))
        if self.stop_ps is not None:
            cluster.sim.at(self.stop_ps, lambda: net.scale_link_bw(self.link, 1 / self.bw_factor))

    @property
    def target(self) -> str:
        """The degraded link."""
        return self.link

    def scaled(self, magnitude: float) -> "LinkDegradation":
        """Exponential interpolation: ``bw_factor ** magnitude``.

        Magnitude 0 gives factor 1.0 (no degradation); magnitude 1 gives the
        specified collapse; the curve is monotone in between and extrapolates
        smoothly past 1.
        """
        if magnitude == 1.0:
            return self
        return replace(self, bw_factor=self.bw_factor ** magnitude)

    def describe(self) -> str:
        return f"link {self.link} bandwidth x{self.bw_factor}"


_TRACE_PROFILES = ("constant", "step", "ramp", "burst")


@dataclass(frozen=True)
class LossRateTrace:
    """Time-varying loss-intensity profile for :class:`LinkLoss`.

    Replaces the constant ``drop_prob`` knob with a deterministic function
    of simulated time (pure arithmetic — no random draws of its own, so the
    fault's seeded rng stream is untouched):

    * ``constant`` — ``peak`` everywhere (byte-identical to a trace-less
      ``LinkLoss`` whose ``drop_prob == peak``);
    * ``step``     — ``base`` before ``at_ps``, ``peak`` from then on;
    * ``ramp``     — ``base`` before ``at_ps``, then linear to ``peak``
      over ``ramp_ps``, holding ``peak`` afterwards;
    * ``burst``    — ``peak`` inside ``[at_ps, at_ps + ramp_ps)``, ``base``
      outside (a corruption burst).
    """

    profile: str = "constant"
    peak: float = 0.25
    base: float = 0.0
    at_ps: int = 0
    ramp_ps: int = 1_000_000_000        # 1 ms ramp / burst width

    def __post_init__(self) -> None:
        if self.profile not in _TRACE_PROFILES:
            raise ValueError(
                f"profile must be one of {_TRACE_PROFILES}, got {self.profile!r}"
            )

    def rate(self, now: int) -> float:
        """The instantaneous per-chunk drop probability at time ``now``."""
        if self.profile == "constant":
            return self.peak
        if self.profile == "step":
            return self.peak if now >= self.at_ps else self.base
        if self.profile == "ramp":
            if now < self.at_ps:
                return self.base
            frac = min(1.0, (now - self.at_ps) / max(self.ramp_ps, 1))
            return self.base + (self.peak - self.base) * frac
        # burst
        if self.at_ps <= now < self.at_ps + self.ramp_ps:
            return self.peak
        return self.base

    def scaled(self, magnitude: float) -> "LossRateTrace":
        """The same profile with ``peak``/``base`` intensities scaled.

        Probabilities clamp to 1.0; the time shape (``at_ps``/``ramp_ps``)
        is untouched, per the :meth:`FaultSpec.scaled` contract.
        """
        if magnitude == 1.0:
            return self
        return replace(
            self,
            peak=min(1.0, self.peak * magnitude),
            base=min(1.0, self.base * magnitude),
        )

    def describe(self) -> str:
        """Human-readable profile summary (used by LinkLoss.describe)."""
        if self.profile == "constant":
            return f"constant p={self.peak}"
        return (f"{self.profile} p={self.base}->{self.peak} "
                f"@{self.at_ps}ps/{self.ramp_ps}ps")


@dataclass(frozen=True)
class LinkLoss(FaultSpec):
    """Drop chunks on one link with probability ``drop_prob``; the link
    layer retransmits after ``retransmit_ps`` (delivery delayed, not lost,
    so collectives still terminate).  A :class:`LossRateTrace` makes the
    drop probability time-varying (``drop_prob`` is then ignored)."""

    fault_class: ClassVar[str] = LINK_LOSS

    link: str
    drop_prob: float = 0.25
    retransmit_ps: int = 0          # 0 -> 2x the chunk's wire time
    start_ps: int = 0
    stop_ps: Optional[int] = None
    trace: Optional[LossRateTrace] = None

    def schedule(self, cluster: "ClusterOrchestrator", rng: random.Random) -> None:
        cluster.net.install_link_fault(
            self.link,
            LinkFault(
                loss_prob=self.drop_prob,
                retransmit_ps=self.retransmit_ps,
                start_ps=self.start_ps,
                stop_ps=self.stop_ps,
                rng=rng,
                loss_trace=None if self.trace is None else self.trace.rate,
            ),
        )

    @property
    def target(self) -> str:
        """The lossy link."""
        return self.link

    def scaled(self, magnitude: float) -> "LinkLoss":
        """Scale the drop probability (and any trace's intensities) linearly,
        clamped to 1.0.  At magnitude 0 nothing ever drops."""
        if magnitude == 1.0:
            return self
        return replace(
            self,
            drop_prob=min(1.0, self.drop_prob * magnitude),
            trace=None if self.trace is None else self.trace.scaled(magnitude),
        )

    def describe(self) -> str:
        if self.trace is not None:
            return f"link {self.link} loss {self.trace.describe()}"
        return f"link {self.link} loss p={self.drop_prob}"


@dataclass(frozen=True)
class ChunkReorder(FaultSpec):
    """In-flight reordering: uniform propagation jitter in [0, jitter_ps)
    per chunk breaks the link's natural FIFO arrival order."""

    fault_class: ClassVar[str] = LINK_REORDER

    link: str
    jitter_ps: int = 1_000_000_000      # 1 ms
    start_ps: int = 0
    stop_ps: Optional[int] = None

    def schedule(self, cluster: "ClusterOrchestrator", rng: random.Random) -> None:
        cluster.net.install_link_fault(
            self.link,
            LinkFault(
                jitter_ps=self.jitter_ps,
                start_ps=self.start_ps,
                stop_ps=self.stop_ps,
                rng=rng,
            ),
        )

    @property
    def target(self) -> str:
        """The reordering link."""
        return self.link

    def scaled(self, magnitude: float) -> "ChunkReorder":
        """Scale the jitter window linearly (0 ps of jitter == healthy)."""
        if magnitude == 1.0:
            return self
        return replace(self, jitter_ps=int(round(self.jitter_ps * magnitude)))

    def describe(self) -> str:
        return f"link {self.link} jitter<{self.jitter_ps}ps"


# ---------------------------------------------------------------------------
# Host runtime faults (hostsim + clock)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HostPause(FaultSpec):
    """GC-style runtime stall: the host freezes for ``pause_ps`` at its next
    step boundary after ``at_ps`` (logged as a ``gc_stall`` event)."""

    fault_class: ClassVar[str] = HOST_PAUSE

    host: str
    pause_ps: int
    at_ps: int = 0
    kind: str = "gc"

    def schedule(self, cluster: "ClusterOrchestrator", rng: random.Random) -> None:
        h = cluster.hosts[self.host]
        cluster.sim.at(self.at_ps, lambda: h.inject_stall(self.pause_ps, self.kind))

    @property
    def target(self) -> str:
        """The stalled host."""
        return self.host

    def scaled(self, magnitude: float) -> "HostPause":
        """Scale the stall duration linearly (a 0 ps stall logs nothing)."""
        if magnitude == 1.0:
            return self
        return replace(self, pause_ps=int(round(self.pause_ps * magnitude)))

    def describe(self) -> str:
        return f"{self.host} pauses {self.pause_ps}ps ({self.kind})"


@dataclass(frozen=True)
class ClockDrift(FaultSpec):
    """The host's oscillator starts drifting at ``drift_ppm`` from ``at_ps``
    (continuous in local time — no step at the switch point)."""

    fault_class: ClassVar[str] = CLOCK_FAULT

    host: str
    drift_ppm: float
    at_ps: int = 0

    def schedule(self, cluster: "ClusterOrchestrator", rng: random.Random) -> None:
        clk = cluster.hosts[self.host].clock
        cluster.sim.at(self.at_ps, lambda: clk.set_drift(self.drift_ppm, cluster.sim.now))

    @property
    def target(self) -> str:
        """The drifting host."""
        return self.host

    def scaled(self, magnitude: float) -> "ClockDrift":
        """Scale the drift rate linearly (0 ppm == a true oscillator)."""
        if magnitude == 1.0:
            return self
        return replace(self, drift_ppm=self.drift_ppm * magnitude)

    def describe(self) -> str:
        return f"{self.host} clock drifts {self.drift_ppm}ppm"


@dataclass(frozen=True)
class ClockStep(FaultSpec):
    """A hard clock step of ``step_ps`` at ``at_ps`` (bad NTP step, VM
    migration, firmware hiccup)."""

    fault_class: ClassVar[str] = CLOCK_FAULT

    host: str
    step_ps: int
    at_ps: int = 0

    def schedule(self, cluster: "ClusterOrchestrator", rng: random.Random) -> None:
        clk = cluster.hosts[self.host].clock
        cluster.sim.at(self.at_ps, lambda: clk.step(self.step_ps))

    @property
    def target(self) -> str:
        """The stepped host."""
        return self.host

    def scaled(self, magnitude: float) -> "ClockStep":
        """Scale the step size linearly (a 0 ps step is a no-op)."""
        if magnitude == 1.0:
            return self
        return replace(self, step_ps=int(round(self.step_ps * magnitude)))

    def describe(self) -> str:
        return f"{self.host} clock steps {self.step_ps}ps"


# ---------------------------------------------------------------------------
# Accelerator faults (devicesim / cluster)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DeviceSlowdown(FaultSpec):
    """Thermal throttle: one chip's compute slows by ``factor`` for a
    window (multiplies any pre-existing compute scale)."""

    fault_class: ClassVar[str] = DEVICE_SLOWDOWN

    chip: str
    factor: float = 3.0
    start_ps: int = 0
    stop_ps: Optional[int] = None

    def schedule(self, cluster: "ClusterOrchestrator", rng: random.Random) -> None:
        dev = cluster.device_sim_for(self.chip)

        def _throttle() -> None:
            dev.compute_scale[self.chip] = dev.compute_scale.get(self.chip, 1.0) * self.factor

        def _restore() -> None:
            dev.compute_scale[self.chip] = dev.compute_scale.get(self.chip, 1.0) / self.factor

        cluster.sim.at(self.start_ps, _throttle)
        if self.stop_ps is not None:
            cluster.sim.at(self.stop_ps, _restore)

    @property
    def target(self) -> str:
        """The throttled chip."""
        return self.chip

    def scaled(self, magnitude: float) -> "DeviceSlowdown":
        """Interpolate the slowdown: ``1 + (factor - 1) * magnitude``, so
        magnitude 0 is full speed and magnitude 1 the specified throttle."""
        if magnitude == 1.0:
            return self
        return replace(self, factor=1.0 + (self.factor - 1.0) * magnitude)

    def describe(self) -> str:
        return f"chip {self.chip} compute x{self.factor}"


@dataclass(frozen=True)
class StragglerPod(FaultSpec):
    """Every chip of one pod runs ``factor`` slower (bad rack: shared
    cooling or power fabric)."""

    fault_class: ClassVar[str] = STRAGGLER_POD

    pod: int
    factor: float = 2.5
    start_ps: int = 0
    stop_ps: Optional[int] = None

    def schedule(self, cluster: "ClusterOrchestrator", rng: random.Random) -> None:
        for chip in cluster.topo.pods[self.pod]:
            DeviceSlowdown(chip, self.factor, self.start_ps, self.stop_ps).schedule(cluster, rng)

    @property
    def target(self) -> str:
        """The straggling pod, as ``pod<N>``."""
        return f"pod{self.pod}"

    def scaled(self, magnitude: float) -> "StragglerPod":
        """Interpolate the pod-wide slowdown exactly like
        :meth:`DeviceSlowdown.scaled`."""
        if magnitude == 1.0:
            return self
        return replace(self, factor=1.0 + (self.factor - 1.0) * magnitude)

    def describe(self) -> str:
        return f"pod{self.pod} compute x{self.factor}"


# ---------------------------------------------------------------------------
# The plan: faults + one seed = a reproducible run
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultPlan:
    """An ordered set of faults plus the seed that makes them reproducible.

    Each fault draws from its own ``random.Random`` keyed by
    ``(seed, index)``, so adding or removing one fault does not perturb the
    random streams of the others, and the same plan + seed reproduces
    byte-identical simulator logs.
    """

    faults: Tuple[FaultSpec, ...] = ()
    seed: int = 0

    def schedule(self, cluster: "ClusterOrchestrator") -> None:
        for i, f in enumerate(self.faults):
            f.schedule(cluster, self.rng_for(i))

    def rng_for(self, index: int) -> random.Random:
        # int seeds hash stably across processes (unlike PYTHONHASHSEED-ed
        # strings), so derive per-fault streams arithmetically
        return random.Random(self.seed * 1_000_003 + index * 7_919 + 17)

    def with_seed(self, seed: int) -> "FaultPlan":
        return FaultPlan(self.faults, seed)

    def scaled(self, magnitude: float) -> "FaultPlan":
        """Every fault at ``magnitude`` times its intensity (same seed).

        Magnitude 1.0 returns ``self`` — the unscaled plan stays
        byte-identical to pre-magnitude-axis runs.
        """
        if magnitude < 0.0:
            raise ValueError(f"fault magnitude must be >= 0, got {magnitude}")
        if magnitude == 1.0:
            return self
        return FaultPlan(tuple(f.scaled(magnitude) for f in self.faults), self.seed)

    def targets(self) -> List[str]:
        """Unique faulted components, in injection order."""
        out: List[str] = []
        for f in self.faults:
            if f.target not in out:
                out.append(f.target)
        return out

    def fault_classes(self) -> List[str]:
        """Unique injected fault classes, in injection order."""
        out: List[str] = []
        for f in self.faults:
            if f.fault_class not in out:
                out.append(f.fault_class)
        return out

    def describe(self) -> List[str]:
        return [f.describe() for f in self.faults]
