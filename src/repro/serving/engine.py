"""Batched serving engine: request queue -> prefill -> lockstep batched
decode with greedy/temperature sampling, EOS + max-length termination.

The engine serves fixed-size batch waves (static batching): requests are
grouped into waves of ``batch_size``, each wave shares one KV cache and
decodes in lockstep — the pattern the decode_32k dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.transformer import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0           # 0 = greedy
    eos_id: Optional[int] = None
    # filled by the engine:
    output: Optional[np.ndarray] = None
    latency_s: float = 0.0


@dataclasses.dataclass
class EngineStats:
    waves: int = 0
    requests: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return (self.prefill_tokens + self.decode_tokens) / max(self.wall_s, 1e-9)


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        batch_size: int = 4,
        max_seq: int = 512,
        rng_seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self._key = jax.random.PRNGKey(rng_seed)
        self.stats = EngineStats()

        self._prefill = jax.jit(
            lambda p, toks: prefill(cfg, p, tokens=toks, max_seq=max_seq)
        )

        def _decode(p, toks, cache, pos, key, temps):
            logits, cache = decode_step(cfg, p, toks, cache, pos)
            logits = logits[:, 0, :]
            greedy = jnp.argmax(logits, axis=-1)
            sampled = jax.random.categorical(key, logits / jnp.maximum(temps, 1e-6)[:, None])
            nxt = jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
            return nxt[:, None], cache

        self._decode = jax.jit(_decode, donate_argnums=(2,))

    # -- wave execution -------------------------------------------------------

    def _run_wave(self, wave: List[Request]) -> None:
        t0 = time.time()
        B = self.batch_size
        S = max(len(r.prompt) for r in wave)
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(wave):
            prompts[i, S - len(r.prompt):] = r.prompt  # left-pad into lockstep
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        self.stats.prefill_tokens += B * S

        temps = jnp.asarray(
            [r.temperature for r in wave] + [0.0] * (B - len(wave)), jnp.float32
        )
        max_new = max(r.max_new_tokens for r in wave)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outputs = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        first = np.asarray(toks)
        for i, r in enumerate(wave):
            outputs[i].append(int(first[i, 0]))
            if (r.eos_id is not None and first[i, 0] == r.eos_id) or r.max_new_tokens <= 1:
                done[i] = True

        for step in range(1, max_new):
            if all(done[: len(wave)]):
                break
            self._key, sub = jax.random.split(self._key)
            toks, cache = self._decode(
                self.params, toks, cache, jnp.int32(S + step - 1), sub, temps
            )
            self.stats.decode_tokens += int(B)
            host = np.asarray(toks)[:, 0]
            for i, r in enumerate(wave):
                if done[i]:
                    continue
                outputs[i].append(int(host[i]))
                if (r.eos_id is not None and host[i] == r.eos_id) or len(outputs[i]) >= r.max_new_tokens:
                    done[i] = True
            if all(done[: len(wave)]):
                break

        dt = time.time() - t0
        for i, r in enumerate(wave):
            r.output = np.asarray(outputs[i][: r.max_new_tokens], np.int32)
            r.latency_s = dt
        self.stats.waves += 1
        self.stats.requests += len(wave)
        self.stats.wall_s += dt

    def serve(self, requests: List[Request]) -> List[Request]:
        for i in range(0, len(requests), self.batch_size):
            self._run_wave(requests[i : i + self.batch_size])
        return requests
