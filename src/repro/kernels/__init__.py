"""Pallas TPU kernels (validated on CPU via interpret=True) + jnp oracles.

kernels:
  flash_attention  — online-softmax attention (causal/local, GQA), fwd
  decode_attention — flash-decode single-token attention over long KV
  rglru_scan       — RG-LRU diagonal linear recurrence
  ssm_scan         — Mamba-1 selective scan
  rmsnorm          — fused RMSNorm

Each has a pure-jnp oracle in ref.py; ops.py exposes jit-ready wrappers
with impl="pallas"|"reference" dispatch.
"""
from . import ops, ref
from .ops import decode_attention, flash_attention, rglru_scan, rmsnorm, ssm_scan

__all__ = [
    "decode_attention",
    "flash_attention",
    "ops",
    "ref",
    "rglru_scan",
    "rmsnorm",
    "ssm_scan",
]
