"""Engine performance benchmark — the repo's perf baseline (BENCH_engine.json).

Six measurements, smallest to largest scope:

* ``kernel``    — raw DES dispatch rate: events/sec through a bare
                  :class:`repro.sim.engine.EventKernel` (256 interleaved
                  self-rescheduling timers, no simulator work).
* ``topology``  — full-system simulation events/sec at 8/64/256-pod
                  fat-tree testbeds (``scale(pods=N)``): one training step
                  with a cross-pod DCN all-reduce, in-memory text logs
                  (the compatibility path — directly comparable to the
                  PR 3 baseline rows).
* ``pipeline``  — the kernel-to-trace gap, per stage: simulate / format /
                  parse / weave / export / analyze walls at each testbed
                  size, and the **text vs structured vs inline** events/sec
                  comparison they compose into.  ``full_sim`` is simulation
                  + log sink only (what ``topology`` measures);
                  ``end_to_end`` also weaves, exports SpanJSONL and runs
                  the aggregate analytics.  ``inline_weave`` is the fused
                  simulate+weave+finish wall of the streaming weaver (one
                  pass, no format/parse stage at all); its own breakdown
                  is in ``inline_stages_s`` and its ``end_to_end`` rate
                  swaps in the columnar ``RunStats.from_columns`` analyze.
                  ``columnar_weave`` goes one further: the weaver appends
                  span fields straight into builder arrays at emit (no
                  ``Span`` objects for net rows at all), renders SpanJSONL
                  from the arrays and feeds ``SpanColumns`` without a Span
                  round-trip; its breakdown is in ``columnar_stages_s``.
* ``workloads`` — per-workload-type throughput at 8/64/256-pod testbeds:
                  events/sec plus the workload's own unit rate (requests/s
                  for ``rpc``, steps/s, checkpoint rounds/s, microbatches/s)
                  — the perf trajectory of the pluggable workload layer's
                  hot paths (``sim/workload.py`` + ``sim/workloads/``).
* ``mitigations`` — per-policy kernel overhead on the shared mitigation
                  scenario (``link_loss_rpc``): events/sec with each
                  registered remediation policy attached vs an
                  ``unmitigated`` reference that skips the attach
                  entirely; ``do_nothing`` is asserted to stay within 10%
                  of the unmitigated rate (the subsystem must be free when
                  nothing fires).
* ``saturation`` — the rpc serving engine at scale: open-loop Poisson
                  arrivals at 2M req/s into a 256-pod fleet (12,000
                  requests), one row per registered load-balancing policy
                  (``sim/workloads/lb.py``) plus a bounded row
                  (``queue_depth`` + timeout + retries exercising the
                  drop/retry machinery).  Every row asserts exact request
                  conservation (issued == completed + dropped +
                  timed_out) and the unbounded rows assert the fleet
                  sustains >= 10,000 concurrent in-flight span trees;
                  reported: goodput, requests/s, events/s and the
                  completed-request latency tail (p50/p99/p99.9).
* ``sweep``     — end-to-end ``(scenario, seed)`` sweep wall-time at
                  ``--jobs 1/4/8`` (simulate + weave + diagnose + shards),
                  now served by the persistent warm worker pool.

Results land in ``BENCH_engine.json`` (schema ``columbo.engine_bench/v7``,
validated in ``tests/test_sweep.py``); the recorded baseline and the exact
reproduction commands live in ``docs/performance.md``.

    python -m benchmarks.engine_bench                 # full baseline (~5 min)
    python -m benchmarks.engine_bench --smoke         # tier-1 pre-flight (~15 s)
    python -m benchmarks.engine_bench --out my.json --jobs 1,2
"""
from __future__ import annotations

import argparse
import gc
import json
import platform
import sys
import tempfile
import time

SCHEMA = "columbo.engine_bench/v7"

SMOKE_TOPOLOGY_PODS = (4, 8)
FULL_TOPOLOGY_PODS = (8, 64, 256)
SMOKE_PIPELINE_PODS = (8,)
FULL_PIPELINE_PODS = (8, 64, 256)
SMOKE_WORKLOAD_PODS = (8,)
FULL_WORKLOAD_PODS = (8, 64, 256)
SMOKE_MITIGATION_PODS = 4
FULL_MITIGATION_PODS = 128
MITIGATION_SCENARIO = "link_loss_rpc"
SMOKE_SATURATION = dict(pods=8, n_requests=200, rate_rps=200_000.0,
                        min_in_flight=0)
FULL_SATURATION = dict(pods=256, n_requests=12_000, rate_rps=2_000_000.0,
                       min_in_flight=10_000)

STAGES = ("simulate", "format", "parse", "weave", "inline_weave",
          "columnar_weave", "export", "analyze")


def bench_kernel(n_events: int = 200_000, n_timers: int = 256) -> dict:
    """Raw kernel dispatch rate: ``n_timers`` interleaved self-rescheduling
    timers with co-prime-ish intervals (a worst-ish-case heap mix), run
    until ``n_events`` have executed."""
    from repro.sim.engine import EventKernel

    k = EventKernel()
    done = [0]

    def make(i: int):
        interval = 1_000 + 7 * i

        def fire() -> None:
            done[0] += 1
            if done[0] < n_events:
                k.after(interval, fire)

        return fire

    timers = [make(i) for i in range(n_timers)]
    t0 = time.perf_counter()
    for i, fire in enumerate(timers):
        k.after(1_000 + 7 * i, fire)
    k.run(max_events=n_events)
    wall = time.perf_counter() - t0
    return {
        "n_events": k.events_executed,
        "n_timers": n_timers,
        "wall_s": round(wall, 4),
        "events_per_sec": round(k.events_executed / wall) if wall else 0,
    }


def bench_topology(pods_list=FULL_TOPOLOGY_PODS, chips_per_pod: int = 2,
                   n_steps: int = 1) -> list:
    """Full-system simulation throughput per fat-tree size: one training
    step (per-layer ICI all-gather + cross-pod DCN gradient all-reduce),
    logs kept in memory so disk I/O stays out of the measurement."""
    from repro.sim.cluster import ClusterOrchestrator, drive_training_hosts
    from repro.sim.topology import scale
    from repro.sim.workload import synthetic_program

    rows = []
    for pods in pods_list:
        program = synthetic_program(
            n_layers=1, layer_flops=5e11, layer_bytes=2e8, grad_bytes=1e8
        )
        t0 = time.perf_counter()
        topo = scale(pods=pods, chips_per_pod=chips_per_pod)
        cluster = ClusterOrchestrator(topo)
        drive_training_hosts(cluster, program, n_steps)
        cluster.run()
        wall = time.perf_counter() - t0
        ev = cluster.sim.events_executed
        rows.append({
            "pods": pods,
            "chips": pods * chips_per_pod,
            "links": len(topo.links),
            "events": ev,
            "wall_s": round(wall, 3),
            "events_per_sec": round(ev / wall) if wall else 0,
            "virtual_s": round(cluster.sim.now / 1e12, 4),
        })
    return rows


def _pipeline_cluster(pods: int, chips_per_pod: int, n_steps: int,
                      structured: bool = False, sink=None):
    """One full-system simulation with the chosen log sink; returns
    ``(cluster, wall_s)``."""
    from repro.sim.cluster import ClusterOrchestrator, drive_training_hosts
    from repro.sim.topology import scale
    from repro.sim.workload import synthetic_program

    program = synthetic_program(
        n_layers=1, layer_flops=5e11, layer_bytes=2e8, grad_bytes=1e8
    )
    t0 = time.perf_counter()
    topo = scale(pods=pods, chips_per_pod=chips_per_pod)
    cluster = ClusterOrchestrator(topo, structured=structured, sink=sink)
    drive_training_hosts(cluster, program, n_steps)
    cluster.run()
    return cluster, time.perf_counter() - t0


def bench_pipeline(pods_list=FULL_PIPELINE_PODS, chips_per_pod: int = 2,
                   n_steps: int = 1, trials: int = 3) -> list:
    """The kernel-to-trace gap, stage by stage, text vs structured.

    Stages are measured independently so the gap stays attributable:

    * ``simulate`` — DES run with the structured (zero-format) sink;
    * ``format``   — rendering the captured records into the ad-hoc text
                     lines (what the text path pays *inside* simulate);
    * ``parse``    — re-parsing those lines into Events (text path only);
    * ``weave``    — materialize + weave the event streams into spans;
    * ``export``   — stream the spans through SpanJSONLExporter;
    * ``analyze``  — RunStats reduction + aggregate() percentile rollup.

    ``full_sim`` events/sec = events / simulate wall (text: with inline
    formatting — the PR 3 baseline's definition; structured: record
    capture).  ``end_to_end`` = events / (simulate + [format+parse] +
    weave + export + analyze), the whole simulate→trace→analytics path.

    Simulate walls are **best-of-``trials``** (timeit's ``min`` rule): a
    DES run is deterministic CPU-bound work, so the minimum is the
    machine's actual cost and everything above it is scheduler noise —
    on shared CI hosts single shots were observed swinging ±40%.
    """
    import io

    from repro.core import SourceSpec, SpanJSONLExporter, TraceSpec, reset_ids
    from repro.core.analysis import RunStats, SpanColumns, aggregate
    from repro.core.pipeline import LineIterProducer, Pipeline
    from repro.core.registry import DEFAULT_REGISTRY
    from repro.core.session import stream_to
    from repro.core.streaming import StreamingWeaver

    rows = []
    for pods in pods_list:
        # text-path simulate: in-memory ad-hoc text lines (inline f-strings)
        events = 0
        t_sim_text = None
        for _ in range(trials):
            gc.collect()   # earlier rows' allocator debris must not bill here
            cluster_text, wall = _pipeline_cluster(
                pods, chips_per_pod, n_steps, structured=False
            )
            events = cluster_text.sim.events_executed
            del cluster_text
            t_sim_text = wall if t_sim_text is None else min(t_sim_text, wall)
        # structured simulate: record capture, no formatting (runs are
        # deterministic, so any trial's captured records feed the stages)
        cluster = None
        t_sim_fast = None
        for _ in range(trials):
            del cluster
            gc.collect()
            cluster, wall = _pipeline_cluster(
                pods, chips_per_pod, n_steps, structured=True
            )
            t_sim_fast = wall if t_sim_fast is None else min(t_sim_fast, wall)

        # format: records -> ad-hoc text lines (pure function of capture)
        t0 = time.perf_counter()
        lines_per_writer = [lw.render_lines() for lw in cluster._logs]
        t_format = time.perf_counter() - t0
        n_lines = sum(len(ls) for ls in lines_per_writer)

        # parse: text lines -> Events (what the text path pays per line)
        class _Null:
            def consume(self, ev):
                pass

            def consume_many(self, evs):
                n = 0
                for _ in evs:
                    n += 1
                return n

            def on_finish(self):
                pass

        t0 = time.perf_counter()
        parsed = 0
        for lw, lines in zip(cluster._logs, lines_per_writer):
            p = Pipeline(
                LineIterProducer(lines, DEFAULT_REGISTRY.make_parser(lw.sim_type)),
                (), _Null(),
            )
            p.run_sync()
            parsed += p.events_in
        t_parse = time.perf_counter() - t0
        del lines_per_writer

        # weave: structured streams -> finalized spans (the fast path's
        # only trace-side cost besides export)
        reset_ids()
        buf = io.StringIO()
        exporter = SpanJSONLExporter(buf)
        t0 = time.perf_counter()
        spec = TraceSpec(
            sources=[
                SourceSpec(sim_type=st, events=evs)
                for st, evs in cluster.structured_sources()
            ],
        )
        session = spec.run()
        spans = session.spans
        t_weave = time.perf_counter() - t0

        # export: spans -> SpanJSONL (buffered single-write batches)
        t0 = time.perf_counter()
        session.export(exporter)
        t_export = time.perf_counter() - t0

        # analyze: per-run reduction + fleet-style aggregate rollup
        t0 = time.perf_counter()
        stats = RunStats.from_spans(spans, scenario="bench", detected=())
        report = aggregate([stats])
        t_analyze = time.perf_counter() - t0
        assert report.n_runs == 1

        e2e_fast = t_sim_fast + t_weave + t_export + t_analyze
        e2e_text = t_sim_text + t_parse + t_weave + t_export + t_analyze
        n_spans_structured = len(spans)
        # release the structured capture and its span graph before the
        # inline pass holds a second full one
        del cluster, session, spans, stats, report, buf, exporter

        # inline: simulate+weave fused — the streaming weaver assembles the
        # span trees while the kernel runs (no format, no parse, no
        # second pass over records); finish = flush + resolve + renumber +
        # sort, the steps that make the spans byte-identical to the
        # post-hoc weave (asserted in tests/test_streaming_weave.py)
        t_inline = t_inline_run = t_inline_finish = None
        spans_inline = None
        for _ in range(trials):
            spans_inline = None
            gc.collect()
            sw = StreamingWeaver()
            cluster_i, run_wall = _pipeline_cluster(
                pods, chips_per_pod, n_steps, sink=sw
            )
            t0 = time.perf_counter()
            spans_inline = sw.finish()
            fin_wall = time.perf_counter() - t0
            del cluster_i, sw
            total = run_wall + fin_wall
            if t_inline is None or total < t_inline:
                t_inline, t_inline_run, t_inline_finish = total, run_wall, fin_wall
        assert len(spans_inline) == n_spans_structured, (
            f"inline wove {len(spans_inline)} spans vs "
            f"{n_spans_structured} post-hoc — the paths must agree"
        )
        buf_i = io.StringIO()
        t0 = time.perf_counter()
        stream_to(spans_inline, (SpanJSONLExporter(buf_i),))
        t_export_i = time.perf_counter() - t0

        # inline analyze: the columnar reduction (struct-of-arrays encode
        # + numpy pools) instead of the per-span python loop
        t0 = time.perf_counter()
        cols = SpanColumns(spans_inline)
        stats_i = RunStats.from_columns(
            cols, spans=spans_inline, scenario="bench", detected=()
        )
        report_i = aggregate([stats_i])
        t_analyze_i = time.perf_counter() - t0
        assert report_i.n_runs == 1
        del spans_inline, cols, stats_i, report_i, buf_i

        e2e_inline = t_inline + t_export_i + t_analyze_i

        # columnar: emit straight into builder arrays — net spans never
        # exist as objects; finish_columns resolves/renumbers/sorts on the
        # arrays, render_jsonl writes SpanJSONL from them (byte-identical
        # to SpanJSONLExporter, asserted in tests/test_streaming_weave.py)
        # and span_columns() feeds RunStats.from_columns with no Span
        # round-trip anywhere on the path
        t_col = t_col_run = t_col_finish = None
        woven = None
        for _ in range(trials):
            woven = None
            gc.collect()
            sw = StreamingWeaver(columnar=True)
            cluster_c, run_wall = _pipeline_cluster(
                pods, chips_per_pod, n_steps, sink=sw
            )
            t0 = time.perf_counter()
            woven = sw.finish_columns()
            fin_wall = time.perf_counter() - t0
            del cluster_c, sw
            total = run_wall + fin_wall
            if t_col is None or total < t_col:
                t_col, t_col_run, t_col_finish = total, run_wall, fin_wall
        assert woven.n_spans == n_spans_structured, (
            f"columnar wove {woven.n_spans} spans vs "
            f"{n_spans_structured} post-hoc — the paths must agree"
        )
        buf_c = io.StringIO()
        t0 = time.perf_counter()
        woven.render_jsonl(buf_c)
        t_export_c = time.perf_counter() - t0

        # columnar analyze: SpanColumns built from the woven arrays
        # (object spans encoded once, net rows vectorized), no spans list
        t0 = time.perf_counter()
        cols_c = woven.span_columns()
        stats_c = RunStats.from_columns(
            cols_c, spans=None, scenario="bench", detected=()
        )
        report_c = aggregate([stats_c])
        t_analyze_c = time.perf_counter() - t0
        assert report_c.n_runs == 1
        del woven, cols_c, stats_c, report_c, buf_c

        e2e_col = t_col + t_export_c + t_analyze_c
        rows.append({
            "pods": pods,
            "chips": pods * chips_per_pod,
            "events": events,
            "log_lines": n_lines,
            "parsed_events": parsed,
            "spans": n_spans_structured,
            "stages_s": {
                "simulate": round(t_sim_fast, 3),
                "format": round(t_format, 3),
                "parse": round(t_parse, 3),
                "weave": round(t_weave, 3),
                "inline_weave": round(t_inline, 3),
                "columnar_weave": round(t_col, 3),
                "export": round(t_export, 3),
                "analyze": round(t_analyze, 3),
            },
            "inline_stages_s": {
                "sim_weave": round(t_inline_run, 3),
                "finish": round(t_inline_finish, 3),
                "export": round(t_export_i, 3),
                "analyze": round(t_analyze_i, 3),
            },
            "columnar_stages_s": {
                "sim_weave": round(t_col_run, 3),
                "finish": round(t_col_finish, 3),
                "export": round(t_export_c, 3),
                "analyze": round(t_analyze_c, 3),
            },
            "full_sim_events_per_sec": {
                "text": round(events / t_sim_text) if t_sim_text else 0,
                "structured": round(events / t_sim_fast) if t_sim_fast else 0,
            },
            "end_to_end_events_per_sec": {
                "text": round(events / e2e_text) if e2e_text else 0,
                "structured": round(events / e2e_fast) if e2e_fast else 0,
                "inline": round(events / e2e_inline) if e2e_inline else 0,
                "columnar": round(events / e2e_col) if e2e_col else 0,
            },
            "full_sim_speedup": round(t_sim_text / t_sim_fast, 2) if t_sim_fast else 0,
            "end_to_end_speedup": round(e2e_text / e2e_fast, 2) if e2e_fast else 0,
            "inline_speedup": round(e2e_text / e2e_inline, 2) if e2e_inline else 0,
            "columnar_speedup": round(e2e_text / e2e_col, 2) if e2e_col else 0,
        })
    return rows


def bench_workloads(pods_list=FULL_WORKLOAD_PODS, chips_per_pod: int = 2) -> list:
    """Per-workload-type full-system throughput at each testbed size.

    Each row drives one registered workload (``sim/workload.py`` registry)
    on a fat-tree testbed with in-memory text logs (the same sink as
    ``topology_scaling``, so rows are comparable) and reports events/sec
    plus the workload's own unit rate — requests/s for ``rpc``, steps/s,
    checkpoint rounds/s, microbatches/s.  The *knobs* are fixed per type,
    but absolute work still grows with the testbed (ring collectives span
    all pods; storage rounds run per writer host — ``units`` counts the
    system total, ``2 × (pods - 1)`` rounds, not the per-writer knob), so
    read the pods axis as scaling cost, not constant work on a bigger
    fabric.
    """
    from repro.sim.cluster import ClusterOrchestrator
    from repro.sim.topology import scale
    from repro.sim.workload import make_workload, synthetic_program
    from repro.sim.workloads.rpc import rpc_handler_program

    program = synthetic_program(
        n_layers=1, layer_flops=5e11, layer_bytes=2e8, grad_bytes=1e8
    )
    cases = [
        ("collective", "step", dict(program=program, n_steps=1),
         lambda wl, pods: wl.n_steps),          # globally synchronized steps
        ("rpc", "request",
         dict(program=rpc_handler_program(), n_requests=8, arrival="open",
              rate_rps=4000.0),
         lambda wl, pods: wl.total_requests),
        ("storage", "round", dict(program=program, n_steps=1, rounds=2, shards=2),
         lambda wl, pods: wl.total_rounds * max(pods - 1, 0)),  # per writer
        ("pipeline", "microbatch", dict(program=program, n_microbatches=4),
         lambda wl, pods: wl.total_microbatches),
    ]
    rows = []
    for pods in pods_list:
        for name, unit, params, units_of in cases:
            wl = make_workload(name, clock_reads=4, **params)
            gc.collect()   # isolate rows from each other's allocator debris
            t0 = time.perf_counter()
            cluster = ClusterOrchestrator(scale(pods=pods, chips_per_pod=chips_per_pod))
            wl.drive(cluster)
            cluster.run()
            wall = time.perf_counter() - t0
            ev = cluster.sim.events_executed
            units = units_of(wl, pods)
            rows.append({
                "workload": name,
                "pods": pods,
                "chips": pods * chips_per_pod,
                "unit": unit,
                "units": units,
                "events": ev,
                "wall_s": round(wall, 3),
                "events_per_sec": round(ev / wall) if wall else 0,
                "units_per_sec": round(units / wall, 2) if wall else 0,
                "virtual_s": round(cluster.sim.now / 1e12, 4),
            })
            del cluster
    return rows


def bench_mitigations(pods: int = FULL_MITIGATION_PODS, trials: int = 5) -> dict:
    """Per-policy kernel overhead on the shared mitigation scenario.

    One row per registered remediation policy: full-system events/sec on
    ``link_loss_rpc`` (structured sink, in-memory) with that policy
    attached, plus an ``unmitigated`` reference that runs the same faults
    and workload with no policy attached at all (pre-subsystem behavior).
    ``do_nothing`` must execute exactly the unmitigated event count and
    stay within 10% of its wall — the subsystem's cost when nothing fires
    has to be noise.  Walls are best-of-``trials`` with the configurations
    *interleaved* (round-robin: every config once per round), so a
    transient load spike hits all rows alike instead of skewing one
    overhead ratio (same minimum-is-the-real-cost rule as
    ``bench_pipeline``)."""
    from dataclasses import replace

    from repro.sim.cluster import ClusterOrchestrator
    from repro.sim.mitigation import list_mitigations
    from repro.sim.scenarios import get_scenario
    from repro.sim.topology import scale as scale_topo

    spec = replace(get_scenario(MITIGATION_SCENARIO), n_pods=pods)

    def _sim(policy):
        gc.collect()
        t0 = time.perf_counter()
        topo = scale_topo(pods=spec.n_pods, chips_per_pod=spec.chips_per_pod,
                          fabric=spec.fabric)
        cluster = ClusterOrchestrator(topo, outdir=None, structured=True)
        spec.fault_plan(0).schedule(cluster)
        if policy is not None:
            replace(spec, mitigation=policy,
                    mitigation_params=()).make_mitigation(seed=0).attach(cluster)
        spec.make_workload(seed=0).drive(cluster)
        cluster.run()
        return cluster.sim.events_executed, time.perf_counter() - t0

    configs = [None] + list(list_mitigations())
    best = {c: (0, None) for c in configs}
    for _ in range(trials):
        for cfg in configs:
            events, wall = _sim(cfg)
            prev = best[cfg][1]
            best[cfg] = (events, wall if prev is None else min(prev, wall))

    ref_events, ref_wall = best[None]
    rows = [{
        "policy": "unmitigated",
        "events": ref_events,
        "wall_s": round(ref_wall, 4),
        "events_per_sec": round(ref_events / ref_wall) if ref_wall else 0,
        "overhead_vs_unmitigated": 1.0,
    }]
    for name in configs[1:]:
        events, wall = best[name]
        overhead = round(wall / ref_wall, 3) if ref_wall else 0
        rows.append({
            "policy": name,
            "events": events,
            "wall_s": round(wall, 4),
            "events_per_sec": round(events / wall) if wall else 0,
            "overhead_vs_unmitigated": overhead,
        })
        if name == "do_nothing":
            assert events == ref_events, (
                f"do_nothing executed {events} kernel events vs "
                f"{ref_events} unmitigated — the baseline must be inert"
            )
            assert wall <= 1.10 * ref_wall, (
                f"do_nothing wall {wall:.4f}s exceeds 110% of the "
                f"unmitigated {ref_wall:.4f}s"
            )
    return {"scenario": MITIGATION_SCENARIO, "pods": pods, "rows": rows}


def bench_saturation(pods: int = 256, chips_per_pod: int = 2,
                     n_requests: int = 12_000, rate_rps: float = 2_000_000.0,
                     min_in_flight: int = 10_000) -> dict:
    """The rpc serving engine under open-loop saturation at fleet scale.

    One row per registered load-balancing policy with unbounded backend
    queues (pure saturation: the Poisson arrival rate far outruns service,
    so in-flight request count climbs toward ``n_requests`` — the row
    asserts the fleet sustains at least ``min_in_flight`` concurrent
    in-flight span trees), plus one *bounded* row (``queue_depth`` +
    per-request timeout + retries) exercising the drop/retry machinery at
    the same scale.  Every row asserts exact request conservation —
    ``issued == completed + dropped + timed_out`` with every request
    reaching exactly one terminal outcome — and reports goodput,
    requests/s, events/s and the completed-request latency tail straight
    off the workload's outcome accounting (no weave on the timed path)."""
    from repro.core.analysis import percentiles
    from repro.sim.cluster import ClusterOrchestrator
    from repro.sim.topology import scale
    from repro.sim.workload import make_workload
    from repro.sim.workloads.rpc import rpc_handler_program

    configs = [
        dict(lb=name, queue_depth=None, timeout_ps=None, max_retries=0)
        for name in ("round_robin", "least_loaded", "power_of_two_choices")
    ]
    configs.append(dict(lb="least_loaded", queue_depth=4,
                        timeout_ps=20_000_000_000, max_retries=2))
    rows = []
    for cfg in configs:
        bounded = cfg["queue_depth"] is not None
        wl = make_workload(
            "rpc", program=rpc_handler_program(), clock_reads=2, seed=0,
            n_requests=n_requests, arrival="open", rate_rps=rate_rps, **cfg,
        )
        gc.collect()
        t0 = time.perf_counter()
        cluster = ClusterOrchestrator(scale(pods=pods, chips_per_pod=chips_per_pod))
        wl.drive(cluster)
        cluster.run()
        wall = time.perf_counter() - t0
        out = wl.outcomes
        issued = out["issued"]
        terminal = out["completed"] + out["dropped"] + out["timed_out"]
        assert issued == terminal == n_requests, (
            f"lb={cfg['lb']} bounded={bounded}: conservation violated — "
            f"issued={issued} vs completed+dropped+timed_out={terminal} "
            f"(expected {n_requests})"
        )
        if not bounded and out["max_in_flight"] < min_in_flight:
            raise AssertionError(
                f"lb={cfg['lb']}: peak in-flight {out['max_in_flight']} "
                f"< required {min_in_flight} — the open-loop saturation "
                f"regime did not materialize"
            )
        lat = sorted(out["lat_ps"])
        p50, p99, p999 = percentiles(lat, (50.0, 99.0, 99.9))
        ev = cluster.sim.events_executed
        rows.append({
            "lb": cfg["lb"],
            "queue_depth": cfg["queue_depth"],
            "timeout_us": (cfg["timeout_ps"] / 1e6
                           if cfg["timeout_ps"] is not None else None),
            "max_retries": cfg["max_retries"],
            "issued": issued,
            "completed": out["completed"],
            "dropped": out["dropped"],
            "timed_out": out["timed_out"],
            "retries": out["retries"],
            "max_in_flight": out["max_in_flight"],
            "goodput": round(out["completed"] / issued, 4) if issued else 0.0,
            "events": ev,
            "wall_s": round(wall, 3),
            "events_per_sec": round(ev / wall) if wall else 0,
            "requests_per_sec": round(issued / wall) if wall else 0,
            "latency_us": {
                "p50": round(p50 / 1e6, 1),
                "p99": round(p99 / 1e6, 1),
                "p99.9": round(p999 / 1e6, 1),
                "max": round(lat[-1] / 1e6, 1) if lat else 0.0,
            },
        })
        del cluster, wl
    return {
        "pods": pods,
        "chips": pods * chips_per_pod,
        "n_requests": n_requests,
        "rate_rps": rate_rps,
        "min_in_flight": min_in_flight,
        "rows": rows,
    }


def bench_sweep(jobs_list=(1, 4, 8), scenarios=None, seeds=(0, 1, 2, 3),
                **overrides) -> dict:
    """End-to-end sweep wall-time per ``--jobs`` setting (same grid each
    time; cells are seed-pinned so outputs are identical modulo shard
    order — only the wall clock moves).  The full grid runs the curated
    library at 4 pods x 3 steps so each cell carries enough simulation to
    amortize worker startup (tiny cells measure pool overhead, not the
    engine)."""
    from repro.sim.sweep import SweepSpec, run_sweep

    if scenarios is None:
        spec = SweepSpec.library(seeds=tuple(seeds), **overrides)
    else:
        spec = SweepSpec(scenarios=tuple(scenarios), seeds=tuple(seeds), **overrides)
    cells = len(spec.cells())
    by_jobs = {}
    events = spans = 0
    for jobs in jobs_list:
        with tempfile.TemporaryDirectory(prefix="engine-bench-sweep-") as d:
            t0 = time.perf_counter()
            result = run_sweep(spec, d, jobs=jobs)
            by_jobs[str(jobs)] = round(time.perf_counter() - t0, 3)
            events = sum(c.stats.events for c in result.cells)
            spans = sum(c.stats.n_spans for c in result.cells)
    return {
        "cells": cells,
        "scenarios": list(spec.scenarios),
        "seeds": list(spec.seeds),
        "events_total": events,
        "spans_total": spans,
        "wall_s_by_jobs": by_jobs,
    }


def collect(smoke: bool = False, jobs_list=(1, 4, 8)) -> dict:
    """Run all four benches and assemble the BENCH_engine.json payload."""
    if smoke:
        kernel = bench_kernel(n_events=20_000)
        topo = bench_topology(SMOKE_TOPOLOGY_PODS)
        pipeline = bench_pipeline(SMOKE_PIPELINE_PODS)
        workloads = bench_workloads(SMOKE_WORKLOAD_PODS)
        # 3 trials, not 1: the do_nothing<=110%-of-unmitigated assertion
        # runs on sub-10ms walls here, where a single-shot measurement
        # flakes on any scheduler blip; best-of-3 keeps the bound honest
        mitigations = bench_mitigations(SMOKE_MITIGATION_PODS, trials=3)
        saturation = bench_saturation(**SMOKE_SATURATION)
        sweep = bench_sweep(jobs_list=(1, 2),
                            scenarios=("healthy_baseline", "throttled_chip"),
                            seeds=(0,))
    else:
        kernel = bench_kernel()
        gc.collect()
        topo = bench_topology()
        gc.collect()
        pipeline = bench_pipeline()
        gc.collect()
        workloads = bench_workloads()
        gc.collect()
        mitigations = bench_mitigations()
        gc.collect()
        saturation = bench_saturation(**FULL_SATURATION)
        gc.collect()
        sweep = bench_sweep(jobs_list=jobs_list, n_pods=4, n_steps=3)
    return {
        "schema": SCHEMA,
        "smoke": smoke,
        "host": {
            "python": sys.version.split()[0],
            "platform": platform.platform(),
        },
        "kernel": kernel,
        "topology_scaling": topo,
        "pipeline": pipeline,
        "workloads": workloads,
        "mitigations": mitigations,
        "saturation": saturation,
        "sweep": sweep,
    }


def run():
    """``benchmarks.run`` harness hook: smoke-sized rows (name, us, derived)."""
    payload = collect(smoke=True)
    yield ("engine.kernel", 1e6 / max(payload["kernel"]["events_per_sec"], 1),
           f"{payload['kernel']['events_per_sec']}ev/s")
    for row in payload["topology_scaling"]:
        yield (f"engine.sim.pods{row['pods']}",
               row["wall_s"] * 1e6, f"{row['events_per_sec']}ev/s")
    for row in payload["pipeline"]:
        fs = row["full_sim_events_per_sec"]
        ee = row["end_to_end_events_per_sec"]
        yield (f"engine.pipeline.pods{row['pods']}",
               sum(row["stages_s"].values()) * 1e6,
               f"text={fs['text']} structured={fs['structured']}ev/s "
               f"({row['full_sim_speedup']}x)")
        yield (f"engine.pipeline.inline.pods{row['pods']}",
               sum(row["inline_stages_s"].values()) * 1e6,
               f"e2e inline={ee['inline']} vs structured={ee['structured']}"
               f"ev/s ({row['inline_speedup']}x text)")
        yield (f"engine.pipeline.columnar.pods{row['pods']}",
               sum(row["columnar_stages_s"].values()) * 1e6,
               f"e2e columnar={ee['columnar']} vs inline={ee['inline']}"
               f"ev/s ({row['columnar_speedup']}x text)")
    for row in payload["workloads"]:
        yield (f"engine.workload.{row['workload']}.pods{row['pods']}",
               row["wall_s"] * 1e6,
               f"{row['events_per_sec']}ev/s "
               f"{row['units_per_sec']}{row['unit']}/s")
    for row in payload["mitigations"]["rows"]:
        yield (f"engine.mitigation.{row['policy']}",
               row["wall_s"] * 1e6,
               f"{row['events_per_sec']}ev/s "
               f"{row['overhead_vs_unmitigated']}x")
    for row in payload["saturation"]["rows"]:
        kind = "bounded" if row["queue_depth"] is not None else "open"
        yield (f"engine.saturation.{row['lb']}.{kind}",
               row["wall_s"] * 1e6,
               f"{row['requests_per_sec']}req/s "
               f"goodput={row['goodput']} "
               f"inflight<={row['max_in_flight']}")
    for jobs, wall in payload["sweep"]["wall_s_by_jobs"].items():
        yield (f"engine.sweep.jobs{jobs}", wall * 1e6,
               f"{payload['sweep']['cells']}cells")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI pre-flight (~10s)")
    ap.add_argument("--out", default="BENCH_engine.json",
                    help="where to write the JSON payload")
    ap.add_argument("--jobs", default="1,4,8",
                    help="comma list of sweep --jobs settings to time")
    args = ap.parse_args()
    jobs_list = tuple(int(j) for j in args.jobs.split(",") if j.strip())
    payload = collect(smoke=args.smoke, jobs_list=jobs_list)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    k = payload["kernel"]
    print(f"[engine_bench] kernel: {k['events_per_sec']:,} events/s "
          f"({k['n_events']} events in {k['wall_s']}s)")
    for row in payload["topology_scaling"]:
        print(f"[engine_bench] sim pods={row['pods']:<4d} links={row['links']:<6d} "
              f"{row['events']:>9,} events in {row['wall_s']:>7.3f}s "
              f"-> {row['events_per_sec']:,} events/s")
    for row in payload["pipeline"]:
        st = row["stages_s"]
        fs = row["full_sim_events_per_sec"]
        ee = row["end_to_end_events_per_sec"]
        print(f"[engine_bench] pipeline pods={row['pods']:<4d} "
              + " ".join(f"{k}={st[k]}s" for k in STAGES))
        print(f"[engine_bench]   full-sim   text {fs['text']:,} -> structured "
              f"{fs['structured']:,} ev/s ({row['full_sim_speedup']}x)")
        print(f"[engine_bench]   end-to-end text {ee['text']:,} -> structured "
              f"{ee['structured']:,} -> inline {ee['inline']:,} -> columnar "
              f"{ee['columnar']:,} ev/s ({row['end_to_end_speedup']}x / "
              f"{row['inline_speedup']}x / {row['columnar_speedup']}x)")
    for row in payload["workloads"]:
        print(f"[engine_bench] workload {row['workload']:<10s} pods={row['pods']:<4d} "
              f"{row['events']:>9,} events in {row['wall_s']:>7.3f}s "
              f"-> {row['events_per_sec']:,} ev/s, "
              f"{row['units_per_sec']} {row['unit']}/s")
    mit = payload["mitigations"]
    for row in mit["rows"]:
        print(f"[engine_bench] mitigation {row['policy']:<20s} "
              f"({mit['scenario']}, pods={mit['pods']}) "
              f"{row['events']:>8,} events in {row['wall_s']:>7.4f}s "
              f"-> {row['events_per_sec']:,} ev/s "
              f"({row['overhead_vs_unmitigated']}x unmitigated)")
    sat = payload["saturation"]
    for row in sat["rows"]:
        q = (f"q={row['queue_depth']}" if row["queue_depth"] is not None
             else "unbounded")
        lt = row["latency_us"]
        print(f"[engine_bench] saturation lb={row['lb']:<22s} {q:<10s} "
              f"({sat['pods']} pods, {sat['rate_rps']:.0f} rps) "
              f"{row['completed']}/{row['issued']} ok "
              f"drop={row['dropped']} timeout={row['timed_out']} "
              f"inflight<={row['max_in_flight']} "
              f"p50={lt['p50']}us p99.9={lt['p99.9']}us "
              f"-> {row['requests_per_sec']:,} req/s")
    for jobs, wall in payload["sweep"]["wall_s_by_jobs"].items():
        print(f"[engine_bench] sweep jobs={jobs}: {wall}s "
              f"({payload['sweep']['cells']} cells)")
    print(f"[engine_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
