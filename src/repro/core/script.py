"""Columbo Scripts (§4): user-composed trace-creation programs.

The paper's Columbo Scripts are small C++ programs composing simulator-
specific pipelines from predefined building blocks (parsers, actors,
SpanWeavers, exporters).  Here the same composition is a small Python
program against :class:`ColumboScript`:

    script = ColumboScript()
    script.add_log(dev_log_path, SimType.DEVICE, actors=[SymbolizeActor(syms)])
    script.add_log(host_log_path, SimType.HOST)
    script.add_log(net_log_path, SimType.NET)
    spans = script.run()                       # sync
    script.export(JaegerJSONExporter("trace.json"))

Online mode (§3.8): pass ``online=True`` paths that are named pipes and call
``run(threaded=True)`` while the simulation is writing.
"""
from __future__ import annotations

import os
from typing import Dict, Iterable, List, Optional, Sequence, Union

from .context import ContextRegistry
from .events import Event, SimType
from .exporters import Exporter
from .parsers import parser_for
from .pipeline import Actor, IterableProducer, LogFileProducer, Pipeline, Producer
from .span import Span
from .weaver import (
    DeviceSpanWeaver,
    HostSpanWeaver,
    NetSpanWeaver,
    SpanWeaver,
    WEAVERS,
    finalize_spans,
)

# Sync execution must honor causal pushes before polls where possible;
# deferred resolution covers the rest, but running host -> device -> net
# maximizes eager hits.
_SYNC_ORDER = {SimType.HOST: 0, SimType.DEVICE: 1, SimType.NET: 2}


class ColumboScript:
    def __init__(self, poll_timeout: float = 0.0) -> None:
        self.registry = ContextRegistry()
        self.pipelines: List[Pipeline] = []
        self.weavers: List[SpanWeaver] = []
        self.poll_timeout = poll_timeout
        self._spans: Optional[List[Span]] = None
        self.finalize_stats: Dict[str, int] = {}

    # -- composition ------------------------------------------------------------

    def add_log(
        self,
        path: Union[str, os.PathLike],
        sim_type: SimType,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_kwargs,
    ) -> Pipeline:
        producer = LogFileProducer(path, parser_for(sim_type))
        return self.add_pipeline(producer, sim_type, actors, weaver, **weaver_kwargs)

    def add_events(
        self,
        events: Iterable[Event],
        sim_type: SimType,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_kwargs,
    ) -> Pipeline:
        return self.add_pipeline(IterableProducer(events), sim_type, actors, weaver, **weaver_kwargs)

    def add_pipeline(
        self,
        producer: Producer,
        sim_type: SimType,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_kwargs,
    ) -> Pipeline:
        if weaver is None:
            weaver = WEAVERS[sim_type](
                self.registry, poll_timeout=self.poll_timeout, **weaver_kwargs
            )
        self.weavers.append(weaver)
        p = Pipeline(producer, actors, weaver, name=f"{sim_type.value}-{len(self.pipelines)}")
        # annotate for sync ordering
        p.sim_type = sim_type  # type: ignore[attr-defined]
        self.pipelines.append(p)
        return p

    # -- execution ---------------------------------------------------------------

    def run(self, threaded: bool = False) -> List[Span]:
        if threaded:
            # online mode: pipelines run in parallel with the simulation; FIFO
            # producers block until writers appear.  Weavers use blocking polls.
            for p in self.pipelines:
                p.start()
            for p in self.pipelines:
                p.join()
        else:
            for p in sorted(self.pipelines, key=lambda p: _SYNC_ORDER[p.sim_type]):
                p.run_sync()
        spans: List[Span] = []
        for w in self.weavers:
            spans.extend(w.spans)
        self.finalize_stats = finalize_spans(spans, self.registry)
        spans.sort(key=lambda s: (s.context.trace_id, s.start, s.context.span_id))
        self._spans = spans
        return spans

    @property
    def spans(self) -> List[Span]:
        assert self._spans is not None, "run() first"
        return self._spans

    def export(self, *exporters: Exporter) -> None:
        for e in exporters:
            e.export(self.spans)

    # -- stats --------------------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        return {
            "pipelines": {
                p.name: {"events_in": p.events_in, "events_out": p.events_out}
                for p in self.pipelines
            },
            "context": self.registry.stats(),
            "finalize": self.finalize_stats,
            "spans": sum(len(w.spans) for w in self.weavers),
            "span_types": {
                w.sim_type.value: dict(w.span_type_counts) for w in self.weavers
            },
        }
