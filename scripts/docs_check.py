"""Documentation checks (run via scripts/docs_check.sh; part of tier-1).

Four failure classes, all cheap and deterministic:

1. **Broken intra-repo references** in README.md and docs/*.md:
   - markdown links ``[text](path)`` whose target is a repo path that does
     not exist (external http(s)/mailto links and pure #anchors are skipped);
   - ``[[file:line]]`` code anchors whose file is missing or whose line
     number exceeds the file's length.

2. **Stale code anchors**: a ``[[file:line]]`` anchor is normally preceded
   in the prose by the backtick-quoted symbol it points at (e.g.
   "`NetSim` in [[src/repro/sim/netsim.py:64]]"); the anchored line must
   still *contain* one of the nearby quoted symbols, so anchors rot loudly
   when code moves instead of silently pointing mid-function.

3. **Code blocks that don't import**: every ```python fenced block must
   compile, and its top-level ``import``/``from`` statements must execute
   (doctest-style smoke with PYTHONPATH=src) — so the docs can't drift
   ahead of the API they document.  Full blocks are not executed: examples
   legitimately reference runtime artifacts (log files, clusters).

4. **Docstring coverage**: every public top-level function and class in
   ``src/repro/sim`` (including the ``sim/workloads`` and
   ``sim/mitigations`` packages) and ``src/repro/core`` (the documented
   API surface) must carry a docstring.
"""
from __future__ import annotations

import ast
import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
CODE_ANCHOR = re.compile(r"\[\[([^\]\s:]+):(\d+)\]\]")
FENCE = re.compile(r"^```(\w*)\s*$")
QUOTED_SYMBOL = re.compile(r"`([A-Za-z_][A-Za-z0-9_.]*)`")

# symbol-search window: how far back from an anchor to look for the
# backtick-quoted names it belongs to (roughly one doc bullet/sentence)
ANCHOR_CONTEXT_CHARS = 250

DOCSTRING_DIRS = ("src/repro/sim", "src/repro/sim/workloads",
                  "src/repro/sim/mitigations", "src/repro/core")


def _doc_files():
    out = [os.path.join(REPO, "README.md")]
    out.extend(sorted(glob.glob(os.path.join(REPO, "docs", "*.md"))))
    return [p for p in out if os.path.exists(p)]


def _strip_code_blocks(text: str) -> str:
    """Remove fenced blocks so link checks don't trip on code."""
    out, in_fence = [], False
    for line in text.splitlines():
        if FENCE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_links(path: str, text: str):
    errors = []
    base = os.path.dirname(path)
    prose = _strip_code_blocks(text)
    file_lines: dict = {}   # anchored file -> its lines (read once per doc)
    for target in MD_LINK.findall(prose):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        # resolve relative to the doc, then to the repo root
        if not (
            os.path.exists(os.path.join(base, rel))
            or os.path.exists(os.path.join(REPO, rel))
        ):
            errors.append(f"{os.path.relpath(path, REPO)}: broken link -> {target}")
    for m in CODE_ANCHOR.finditer(text):
        fname, line_s = m.group(1), m.group(2)
        fpath = os.path.join(REPO, fname)
        if not os.path.exists(fpath):
            errors.append(
                f"{os.path.relpath(path, REPO)}: anchor [[{fname}:{line_s}]] "
                f"-> file missing"
            )
            continue
        lines = file_lines.get(fname)
        if lines is None:
            lines = file_lines[fname] = open(fpath).read().splitlines()
        if int(line_s) > len(lines):
            errors.append(
                f"{os.path.relpath(path, REPO)}: anchor [[{fname}:{line_s}]] "
                f"-> only {len(lines)} lines"
            )
            continue
        # stale-anchor check: the anchored line must contain one of the
        # backtick-quoted symbols in the prose just before the anchor
        window = text[max(0, m.start() - ANCHOR_CONTEXT_CHARS):m.start()]
        symbols = QUOTED_SYMBOL.findall(window)
        if symbols:
            target = lines[int(line_s) - 1]
            if not any(sym.rsplit(".", 1)[-1] in target for sym in symbols):
                errors.append(
                    f"{os.path.relpath(path, REPO)}: stale anchor "
                    f"[[{fname}:{line_s}]] -> line does not mention any of "
                    f"{sorted(set(symbols))} (is: {target.strip()[:60]!r})"
                )
    return errors


def _python_blocks(text: str):
    blocks, cur, lang, start = [], None, None, 0
    for i, line in enumerate(text.splitlines(), 1):
        m = FENCE.match(line)
        if m and cur is None:
            lang, cur, start = m.group(1).lower(), [], i
        elif m:
            if lang == "python":
                blocks.append((start, "\n".join(cur)))
            cur, lang = None, None
        elif cur is not None:
            cur.append(line)
    return blocks


def check_code_blocks(path: str, text: str):
    import ast

    errors = []
    rel = os.path.relpath(path, REPO)
    for start, block in _python_blocks(text):
        try:
            tree = ast.parse(block, filename=f"{rel}:{start}")
        except SyntaxError as e:
            errors.append(f"{rel}:{start}: python block does not compile: {e}")
            continue
        imports = [
            node for node in tree.body if isinstance(node, (ast.Import, ast.ImportFrom))
        ]
        if not imports:
            continue
        src = "\n".join(ast.unparse(node) for node in imports)
        try:
            exec(compile(src, f"{rel}:{start}<imports>", "exec"),
                 {"__name__": f"docs_check_{start}"})
        except Exception as e:  # noqa: BLE001 - any import failure is a doc bug
            errors.append(f"{rel}:{start}: doc imports fail: {type(e).__name__}: {e}")
    return errors


def check_docstrings():
    """Public top-level functions/classes in the API dirs need docstrings."""
    errors = []
    for d in DOCSTRING_DIRS:
        for path in sorted(glob.glob(os.path.join(REPO, d, "*.py"))):
            rel = os.path.relpath(path, REPO)
            try:
                tree = ast.parse(open(path).read(), filename=rel)
            except SyntaxError as e:
                errors.append(f"{rel}: does not parse: {e}")
                continue
            for node in tree.body:
                if not isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
                ):
                    continue
                if node.name.startswith("_"):
                    continue
                if ast.get_docstring(node) is None:
                    kind = "class" if isinstance(node, ast.ClassDef) else "function"
                    errors.append(
                        f"{rel}:{node.lineno}: public {kind} "
                        f"{node.name!r} has no docstring"
                    )
    return errors


def main() -> int:
    """Run every check over README.md + docs/*.md; non-zero on failure."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    errors = []
    for path in _doc_files():
        text = open(path).read()
        errors.extend(check_links(path, text))
        errors.extend(check_code_blocks(path, text))
    errors.extend(check_docstrings())
    if errors:
        print("docs_check: FAILED")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs_check: OK ({len(_doc_files())} docs checked)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
