"""``disable_and_reroute``: pull a lossy link out of the route tables."""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar, TYPE_CHECKING

from ..mitigation import MitigationPolicy, register_mitigation

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..cluster import ClusterOrchestrator


@register_mitigation
@dataclass
class DisableAndReroute(MitigationPolicy):
    """Fleet response to a degraded link: take it out of service.

    The trigger loop polls per-link drop counters
    (:meth:`~repro.sim.netsim.NetSim.link_drop_counts`); when one link
    accumulates ``trigger_drops``, the policy disables it
    (:meth:`~repro.sim.topology.Topology.disable_link`) so every *new*
    transfer routes around it (in-flight chunks keep their pre-resolved
    paths, as real switches drain).  If the fabric has no alternate path
    the link is restored and the loop keeps watching.  The removed
    bandwidth fraction of the link's fabric family is recorded as the
    ``penalty`` attr on the Mitigation span — the cost
    ``score_mitigations`` charges against the latency win.
    """

    mitigation_name: ClassVar[str] = "disable_and_reroute"

    #: per-link drops observed before that link is taken out
    trigger_drops: int = 3

    def attach(self, cluster: "ClusterOrchestrator") -> None:
        """Watch per-link drop counters; disable the worst offender."""
        net, topo = cluster.net, cluster.topo
        tried = set()

        def _probe(i: int) -> bool:
            counts = net.link_drop_counts()
            worst = None
            for name in sorted(counts):
                if name in tried or counts[name] < self.trigger_drops:
                    continue
                if worst is None or counts[name] > counts[worst]:
                    worst = name
            if worst is None:
                return False
            tried.add(worst)
            link = topo.links[worst]
            topo.disable_link(worst)
            try:
                topo.route(link.a, link.b)
            except ValueError:
                # no alternate path (e.g. a 2-pod mesh): losing the link
                # would partition the fabric, so put it back and keep
                # watching for a remediable one
                topo.restore_link(worst)
                return False
            fam = worst.split(".", 1)[0]
            fam_bw = sum(
                l.bw for n, l in topo.links.items() if n.split(".", 1)[0] == fam
            )
            penalty = round(link.bw / fam_bw, 4) if fam_bw else 1.0
            self.log_trigger(cluster, link=worst, drops=counts[worst])
            self.log_action(
                cluster, action="disable_link", target=worst, penalty=penalty,
            )
            self.log_done(cluster, link=worst)
            return True

        self.watch(cluster, _probe)
