"""Per-arch smoke tests (reduced configs) + decode/prefill equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

# minutes of jit compiles across every arch: excluded from the tier-1
# profile (pyproject addopts -m "not slow"); run with pytest -m ""
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, get_arch
from repro.models import (
    ModelConfig,
    forward,
    init_cache,
    init_params,
    model_pspecs,
)
from repro.models.transformer import decode_step, prefill
from repro.training import AdamWConfig, TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(arch, rng):
    """Reduced same-family config: one forward + one train step on CPU,
    asserting output shapes and no NaNs (assignment requirement)."""
    cfg = get_arch(arch).config.reduced()
    params = init_params(rng, model_pspecs(cfg))
    B, S = 2, 4 * cfg.window if cfg.window < 16 else 64
    S = min(S, 64)
    batch = {"labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        batch["embeds"] = 0.02 * jax.random.normal(rng, (B, S, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)

    logits, aux = jax.jit(
        lambda p, b: forward(cfg, p, tokens=b.get("tokens"), embeds=b.get("embeds"))
    )(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaNs in logits"

    tc = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    state = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma3-27b", "recurrentgemma-2b",
                                  "falcon-mamba-7b", "granite-moe-1b-a400m"])
def test_arch_decode_matches_forward(arch, rng):
    cfg = dataclasses.replace(
        get_arch(arch).config.reduced(),
        dtype="float32", kv_cache_dtype="float32", logits_f32=True,
    )
    params = init_params(rng, model_pspecs(cfg))
    B, S = 2, 16
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full, _ = jax.jit(lambda p, t: forward(cfg, p, t))(params, toks)
    cache = init_cache(cfg, B, S)
    dec = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))
    for i in range(S):
        lg, cache = dec(params, toks[:, i : i + 1], cache, jnp.int32(i))
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, i])))
        assert err < 5e-3, f"{arch} step {i}: {err}"


@pytest.mark.parametrize("arch", ["gemma3-27b", "recurrentgemma-2b", "falcon-mamba-7b"])
def test_arch_prefill_then_decode(arch, rng):
    cfg = dataclasses.replace(
        get_arch(arch).config.reduced(),
        dtype="float32", kv_cache_dtype="float32", logits_f32=True,
    )
    params = init_params(rng, model_pspecs(cfg))
    B, S, P = 2, 16, 12
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full, _ = jax.jit(lambda p, t: forward(cfg, p, t))(params, toks)
    lg_pf, cache = jax.jit(lambda p, t: prefill(cfg, p, t, max_seq=S))(params, toks[:, :P])
    assert float(jnp.max(jnp.abs(lg_pf - full[:, :P]))) < 5e-3
    dec = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))
    for i in range(P, S):
        lg, cache = dec(params, toks[:, i : i + 1], cache, jnp.int32(i))
        assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))) < 5e-3


def test_int8_kv_cache_close_to_bf16(rng):
    cfg = get_arch("qwen3-8b").config.reduced()
    cfg_f = dataclasses.replace(cfg, dtype="float32", kv_cache_dtype="float32")
    cfg_q = dataclasses.replace(cfg, dtype="float32", kv_cache_dtype="int8")
    params = init_params(rng, model_pspecs(cfg_f))
    B, S = 2, 16
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    caches = {k: init_cache(c, B, S) for k, c in [("f", cfg_f), ("q", cfg_q)]}
    outs = {}
    for key, c in [("f", cfg_f), ("q", cfg_q)]:
        dec = jax.jit(lambda p, t, ca, pos, c=c: decode_step(c, p, t, ca, pos))
        cache = caches[key]
        for i in range(8):
            lg, cache = dec(params, toks[:, i : i + 1], cache, jnp.int32(i))
        outs[key] = lg
    # int8 cache tracks the exact cache closely (top-1 agreement)
    assert jnp.argmax(outs["f"][:, 0], -1).tolist() == jnp.argmax(outs["q"][:, 0], -1).tolist()


def test_param_count_estimates_match_declared_tree():
    """cfg.n_params (analytic, used for MODEL_FLOPS) ~ actual tree size."""
    from repro.models.params import count_params

    for arch in ARCHS:
        cfg = get_arch(arch).config
        declared = count_params(model_pspecs(cfg))
        analytic = cfg.n_params
        ratio = declared / analytic
        assert 0.9 < ratio < 1.12, f"{arch}: declared={declared:.3e} analytic={analytic:.3e}"
