"""Inline (in-sim) weave: golden byte-identity harness + late-event fix.

The contract under test: ``ScenarioSpec.run(weave="inline")`` — spans woven
*during* the simulation by ``core/streaming.StreamingWeaver`` — produces
SpanJSONL byte-identical to the post-hoc paths (text and structured), on
the committed goldens and across the scenario x workload x mitigation
matrix.  The sharded path must additionally be jobs-invariant.

Also here: the reproducing test for the late-event silent drop
(``SpanWeaver`` dropped events arriving after a trace's root span closed —
late retransmit/mitigation children); they now raise ``LateEventWarning``
and are counted in ``RunStats.late_events``.
"""
import gzip
import io
import os
import warnings
from dataclasses import replace

import pytest

from _hypothesis_compat import HealthCheck, given, settings, st
from repro.core.analysis import RunStats, SpanColumns
from repro.core.context import ContextRegistry
from repro.core.events import (
    ChunkEnqueue,
    ChunkRx,
    MitigationDone,
    MitigationTrigger,
    RetransmitBegin,
    RetransmitEnd,
)
from repro.core.exporters import SpanJSONLExporter, merge_span_jsonl
from repro.core.session import stream_to
from repro.core.streaming import StreamingWeaver
from repro.core.weaver import HostSpanWeaver, LateEventWarning, NetSpanWeaver
from repro.sim.scenarios import SCENARIOS, get_scenario

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden")
GOLDENS = sorted(
    f for f in os.listdir(GOLDEN_DIR) if f.endswith(".spans.jsonl.gz")
) if os.path.isdir(GOLDEN_DIR) else []

# the equivalence matrix: fault diversity x every workload type x every
# registered mitigation policy (axis cells bypass the masking assertion by
# construction, exactly like sweep axis cells)
MATRIX_SCENARIOS = (
    "healthy_baseline", "degraded_ici_link", "lossy_dcn", "reordered_ici",
    "gc_pause_host0", "throttled_chip", "straggler_pod2",
    "rpc_tail_latency", "link_loss_rpc",
)
MATRIX_WORKLOADS = ("collective", "rpc", "storage", "pipeline")
MATRIX_MITIGATIONS = ("do_nothing", "retransmit", "disable_and_reroute",
                      "evict_straggler", "checkpoint_restore")


def _axis_spec(scenario: str, workload: str = None, mitigation: str = None):
    """A ScenarioSpec with sweep-style axis overrides (no masking check:
    the matrix scores byte-equivalence, not diagnosis)."""
    spec = get_scenario(scenario)
    kw = {}
    if workload is not None and workload != spec.workload:
        kw.update(workload=workload, workload_params=())
    if mitigation is not None and mitigation != spec.mitigation:
        kw.update(mitigation=mitigation, mitigation_params=())
    return replace(spec, **kw) if kw else spec


def _inline_equals_post(spec, seed: int) -> None:
    post = spec.run(seed=seed, structured=True).span_jsonl
    inline = spec.run(seed=seed, weave="inline").span_jsonl
    assert inline == post, (
        f"{spec.name} seed={seed}: inline SpanJSONL differs from post-hoc "
        f"({len(inline)} vs {len(post)} bytes)"
    )


# ---------------------------------------------------------------------------
# Late-event fix (the reproducing tests — written before the fix)
# ---------------------------------------------------------------------------


def test_late_net_event_warns_and_counts():
    """A chunk_rx for an already-closed LinkTransfer used to vanish
    silently; it must now warn (typed) and be counted."""
    w = NetSpanWeaver(ContextRegistry())
    w.consume(ChunkEnqueue(ts=0, source="ici.pod0.l0", attrs={"chunk": "c1", "size": 64}))
    w.consume(ChunkRx(ts=10, source="ici.pod0.l0", attrs={"chunk": "c1"}))
    assert len(w.spans) == 1 and w.late_events == 0
    with pytest.warns(LateEventWarning, match="chunk_rx"):
        w.consume(ChunkRx(ts=20, source="ici.pod0.l0", attrs={"chunk": "c1"}))
    assert w.late_events == 1
    assert len(w.spans) == 1  # the late event produced no span


def test_late_mitigation_children_warn_and_count():
    """The ISSUE's motivating case: retransmit/mitigation children landing
    after the policy's root span closed."""
    w = HostSpanWeaver(ContextRegistry())
    w.consume(MitigationTrigger(ts=0, source="host0", attrs={"policy": "retransmit"}))
    w.consume(MitigationDone(ts=100, source="host0", attrs={"policy": "retransmit"}))
    # a second done for the same (host, policy): nothing open anymore
    with pytest.warns(LateEventWarning, match="mitigation_done"):
        w.consume(MitigationDone(ts=110, source="host0", attrs={"policy": "retransmit"}))
    # retransmit_end with no matching begin (its begin was consumed by a
    # closed span in the buggy trace that motivated the fix)
    w.consume(RetransmitBegin(ts=120, source="host0",
                              attrs={"policy": "retransmit", "chunk": "c9"}))
    w.consume(RetransmitEnd(ts=130, source="host0",
                            attrs={"policy": "retransmit", "chunk": "c9"}))
    with pytest.warns(LateEventWarning, match="retransmit_end"):
        w.consume(RetransmitEnd(ts=140, source="host0",
                                attrs={"policy": "retransmit", "chunk": "c9"}))
    assert w.late_events == 2


def test_late_event_warning_once_per_site_but_counted_every_time():
    w = NetSpanWeaver(ContextRegistry())
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for ts in (5, 6, 7):
            w.consume(ChunkRx(ts=ts, source="ici.pod0.l0", attrs={"chunk": "zzz"}))
    assert w.late_events == 3
    assert sum(1 for r in rec if issubclass(r.category, LateEventWarning)) == 1


def test_late_events_surface_in_run_stats():
    stats = RunStats.from_spans([], scenario="x", detected=(), late_events=7)
    assert stats.late_events == 7
    assert RunStats.from_dict(stats.to_dict()).late_events == 7
    # pre-v5 payloads (no key) default to zero
    d = stats.to_dict()
    del d["late_events"]
    assert RunStats.from_dict(d).late_events == 0


# ---------------------------------------------------------------------------
# Golden byte-identity: inline == committed goldens == post-hoc
# ---------------------------------------------------------------------------


def _parse_golden_name(fname):
    # scenario.<name>.seed<N>.spans.jsonl.gz
    parts = fname.split(".")
    return parts[1], int(parts[2][len("seed"):])


@pytest.mark.parametrize("fname", GOLDENS)
def test_inline_weave_matches_committed_golden(fname):
    """The tentpole contract: spans woven *during* the simulation render to
    SpanJSONL byte-identical to the committed golden artifact."""
    scenario, seed = _parse_golden_name(fname)
    with gzip.open(os.path.join(GOLDEN_DIR, fname), "rt") as f:
        golden = f.read()
    got = get_scenario(scenario).run(seed=seed, weave="inline").span_jsonl
    assert got == golden, f"inline weave diverged from golden {fname}"


@pytest.mark.parametrize("fname", GOLDENS)
def test_post_hoc_weave_matches_committed_golden(fname):
    """The goldens stay anchored to the canonical path too — if both this
    and the inline test fail together, the *format* changed (regenerate the
    goldens deliberately); if only the inline one fails, the streaming
    weaver broke."""
    scenario, seed = _parse_golden_name(fname)
    with gzip.open(os.path.join(GOLDEN_DIR, fname), "rt") as f:
        golden = f.read()
    got = get_scenario(scenario).run(seed=seed, structured=True).span_jsonl
    assert got == golden, f"post-hoc weave diverged from golden {fname}"


def test_goldens_are_committed():
    assert len(GOLDENS) >= 2, (
        f"expected at least two committed goldens in {GOLDEN_DIR}, "
        f"found {GOLDENS}"
    )


# ---------------------------------------------------------------------------
# Inline == post-hoc across the library and the full axis matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_inline_matches_post_hoc_per_scenario(scenario):
    """Every curated scenario, pinned workload/mitigation, seed 0."""
    _inline_equals_post(get_scenario(scenario), seed=0)


@pytest.mark.slow
@pytest.mark.parametrize("scenario", MATRIX_SCENARIOS)
@pytest.mark.parametrize("workload", MATRIX_WORKLOADS)
def test_matrix_inline_equals_post(scenario, workload):
    """The full equivalence matrix: 9 scenarios x 4 workloads x 5
    mitigation policies, inline == post-hoc on every cell."""
    for mitigation in MATRIX_MITIGATIONS:
        _inline_equals_post(_axis_spec(scenario, workload, mitigation), seed=0)


def test_matrix_smoke_diagonal():
    """A fast cross-section of the matrix (one cell per workload type with
    a non-default mitigation) so the axis plumbing is covered in tier-1."""
    cells = [
        ("lossy_dcn", "rpc", "retransmit"),
        ("throttled_chip", "storage", "evict_straggler"),
        ("gc_pause_host0", "pipeline", "checkpoint_restore"),
        ("degraded_ici_link", "collective", "disable_and_reroute"),
    ]
    for scenario, workload, mitigation in cells:
        _inline_equals_post(_axis_spec(scenario, workload, mitigation), seed=0)


# ---------------------------------------------------------------------------
# Sharded parallel export: jobs-invariant bytes
# ---------------------------------------------------------------------------


def test_sharded_export_matches_inline_serial():
    spec = get_scenario("lossy_dcn")
    serial = spec.run(seed=2, weave="inline").span_jsonl
    assert spec.run(seed=2, weave="sharded", jobs=1).span_jsonl == serial
    assert spec.run(seed=2, weave="sharded", jobs=2).span_jsonl == serial


@pytest.mark.slow
@given(seed=st.integers(min_value=0, max_value=2**16 - 1))
@settings(max_examples=3, deadline=None,
          suppress_health_check=[HealthCheck.too_slow]
          if hasattr(HealthCheck, "too_slow") else [])
def test_property_sharded_jobs_invariant(seed):
    """For any seed, the sharded export is byte-identical at jobs 1/4/8."""
    spec = get_scenario("degraded_ici_link")
    serial = spec.run(seed=seed, weave="inline").span_jsonl
    for jobs in (1, 4, 8):
        sharded = spec.run(seed=seed, weave="sharded", jobs=jobs).span_jsonl
        assert sharded == serial, f"jobs={jobs} diverged at seed={seed}"


# ---------------------------------------------------------------------------
# Property tests: any seed, structural invariants
# ---------------------------------------------------------------------------


@given(seed=st.integers(min_value=0, max_value=2**16 - 1))
@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.too_slow]
          if hasattr(HealthCheck, "too_slow") else [])
def test_property_inline_equals_post_any_seed(seed):
    _inline_equals_post(get_scenario("degraded_ici_link"), seed=seed)


@given(seed=st.integers(min_value=0, max_value=2**16 - 1))
@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.too_slow]
          if hasattr(HealthCheck, "too_slow") else [])
def test_property_one_root_span_per_request(seed):
    """Inline-woven rpc runs keep the request-tree invariant: every request
    id owns exactly one RpcRequest span, and that span is a trace root."""
    run = get_scenario("link_loss_rpc").run(seed=seed, weave="inline")
    roots = {}
    for s in run.spans:
        if s.name == "RpcRequest":
            rid = s.attrs["rid"]
            assert rid not in roots, f"duplicate RpcRequest root for {rid}"
            roots[rid] = s
            assert s.parent is None, f"RpcRequest {rid} has a parent"
    assert roots, "rpc scenario wove no RpcRequest spans"
    # every span of a request trace hangs off that request's trace id
    by_trace = {s.context.trace_id for s in roots.values()}
    assert len(by_trace) == len(roots), "RpcRequest roots share a trace id"


# ---------------------------------------------------------------------------
# Mid-run exporter failure under inline weaving
# ---------------------------------------------------------------------------


class _RecordingExporter:
    def __init__(self):
        self.began = self.finished = False
        self.spans = []

    def begin(self):
        self.began = True

    def consume(self, span):
        self.spans.append(span)

    def finish(self):
        self.finished = True


class _BoomExporter(_RecordingExporter):
    def __init__(self, fail_after):
        super().__init__()
        self.fail_after = fail_after

    def consume(self, span):
        if len(self.spans) >= self.fail_after:
            raise RuntimeError("boom: exporter failed mid-stream")
        super().consume(span)


def test_live_exporter_failure_mid_run_is_isolated():
    """Regression: a live exporter dying *mid-simulation* must not take
    down the run, the healthy exporter, or the span artifact — and the
    typed error surfaces exactly once, from finish()."""
    spec = get_scenario("lossy_dcn")
    sw = StreamingWeaver()
    good, boom = _RecordingExporter(), _BoomExporter(fail_after=5)
    sw.add_live_exporter(boom)
    sw.add_live_exporter(good)
    spec.simulate(None, seed=0, sink=sw)
    with pytest.raises(RuntimeError, match="boom"):
        sw.finish()
    spans = sw.spans
    assert spans, "finish() must still weave and cache the spans"
    # the failing exporter was disabled at the failure point (no retries,
    # no double-feed), but its finish() ran so partial output can flush
    assert len(boom.spans) == 5 and boom.finished
    # the healthy exporter saw every span exactly once and finished
    assert len(good.spans) == len(spans) and good.finished
    assert len({id(s) for s in good.spans}) == len(spans), "double-fed span"
    # the woven artifact is intact: identical bytes to a clean run
    buf = io.StringIO()
    stream_to(spans, (SpanJSONLExporter(buf),))
    assert buf.getvalue() == spec.run(seed=0, structured=True).span_jsonl
    # finish() is terminal: a second call returns the spans, no re-raise
    assert sw.finish() is spans


def test_inline_export_fan_out_isolates_failures():
    """stream_to over inline-woven spans: one exporter raising must not
    starve the others, and the first error re-raises typed."""
    run = get_scenario("healthy_baseline").run(seed=0, weave="inline")
    good, boom = _RecordingExporter(), _BoomExporter(fail_after=3)
    with pytest.raises(RuntimeError, match="boom"):
        stream_to(run.spans, (boom, good))
    assert len(good.spans) == len(run.spans) and good.finished
    assert len(boom.spans) == 3


# ---------------------------------------------------------------------------
# Fast SpanJSONL encoder == executable reference spec
# ---------------------------------------------------------------------------


def test_fast_consume_byte_identical_to_reference():
    """SpanJSONLExporter.consume hand-assembles each line; it must match
    the original json.dumps encoding (kept as _consume_reference) byte for
    byte on real woven spans — including float repr, int-attr fast path,
    links, and missing parents."""
    run = get_scenario("link_loss_rpc").run(seed=1, weave="inline")
    fast_buf, ref_buf = io.StringIO(), io.StringIO()
    fast = SpanJSONLExporter(fast_buf)
    ref = SpanJSONLExporter(ref_buf)
    fast.begin()
    ref.begin()
    for s in run.spans:
        fast.consume(s)
        ref._consume_reference(s)
    fast.finish()
    ref.finish()
    assert fast_buf.getvalue() == ref_buf.getvalue()
    assert fast_buf.getvalue()  # non-empty: the comparison meant something


def test_fast_consume_edge_values_match_reference():
    """Attr edge cases the fast path special-cases: bools (NOT ints here),
    negative/zero ints, floats, strings needing escapes."""
    from repro.core.span import Span, SpanContext

    spans = [
        Span(name="X", start=0, end=0, context=SpanContext(1, 2),
             component='we"ird\\name', sim_type="net",
             attrs={"b": True, "n": -7, "z": 0, "f": 0.1, "s": 'quote"\n',
                    "big": 2**63}),
        Span(name="Y", start=3, end=9, context=SpanContext(1, 3),
             attrs={}, component="", sim_type="host"),
    ]
    spans[1].parent = spans[0].context
    spans[1].links.append(spans[0].context)
    fast_buf, ref_buf = io.StringIO(), io.StringIO()
    fast, ref = SpanJSONLExporter(fast_buf), SpanJSONLExporter(ref_buf)
    fast.begin()
    ref.begin()
    for s in spans:
        fast.consume(s)
        ref._consume_reference(s)
    fast.finish()
    ref.finish()
    assert fast_buf.getvalue() == ref_buf.getvalue()


# ---------------------------------------------------------------------------
# Columnar span records: from_columns == from_spans
# ---------------------------------------------------------------------------


def test_columns_reduction_identical_to_from_spans():
    """The struct-of-arrays reduction must reproduce from_spans exactly —
    same float bits, same dict ordering — on a mitigated run (exercising
    the Mitigation penalty accumulation and request pools)."""
    spec = get_scenario("link_loss_rpc")
    run = spec.run(seed=1, weave="inline")
    kw = dict(scenario=spec.name, seed=1, expected=spec.expected_classes,
              detected=run.detected, findings=run.diagnosis.findings,
              late_events=run.session.late_events)
    a = RunStats.from_spans(run.spans, **kw)
    b = RunStats.from_columns(run.session.columns(), spans=run.spans, **kw)
    assert a == b
    assert list(a.component_us) == list(b.component_us)  # dict order too
    for k in a.component_us:
        assert a.component_us[k] == b.component_us[k]


def test_columns_small_and_empty_inputs():
    cols = SpanColumns([])
    assert cols.n_spans == 0
    assert cols.component_us() == {}
    assert cols.request_us() == []
    stats = RunStats.from_columns(cols, spans=[], detected=())
    assert stats.n_spans == 0 and stats.component_us == {}


def test_from_columns_requires_detected_or_spans():
    with pytest.raises(ValueError, match="detected"):
        RunStats.from_columns(SpanColumns([]))


# ---------------------------------------------------------------------------
# Columnar emit: builder arrays end to end, byte-identical everywhere
# ---------------------------------------------------------------------------


def _columnar_equals_post(spec, seed: int) -> None:
    post = spec.run(seed=seed, structured=True).span_jsonl
    col = spec.run(seed=seed, weave="columnar").span_jsonl
    assert col == post, (
        f"{spec.name} seed={seed}: columnar SpanJSONL differs from post-hoc "
        f"({len(col)} vs {len(post)} bytes)"
    )


@pytest.mark.parametrize("fname", GOLDENS)
def test_columnar_weave_matches_committed_golden(fname):
    """The columnar tentpole contract: span fields appended straight into
    builder arrays at emit, JSONL rendered from the arrays — and the bytes
    still match the committed golden artifact."""
    scenario, seed = _parse_golden_name(fname)
    with gzip.open(os.path.join(GOLDEN_DIR, fname), "rt") as f:
        golden = f.read()
    got = get_scenario(scenario).run(seed=seed, weave="columnar").span_jsonl
    assert got == golden, f"columnar weave diverged from golden {fname}"


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_columnar_matches_post_hoc_per_scenario(scenario):
    """Every curated scenario, pinned workload/mitigation, seed 0."""
    _columnar_equals_post(get_scenario(scenario), seed=0)


@given(seed=st.integers(min_value=0, max_value=2**16 - 1))
@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.too_slow]
          if hasattr(HealthCheck, "too_slow") else [])
def test_property_columnar_equals_inline_equals_post_any_seed(seed):
    """For any seed and every workload type, the three weave paths render
    the same bytes: columnar == inline == post-hoc."""
    for workload in MATRIX_WORKLOADS:
        spec = _axis_spec("degraded_ici_link", workload)
        post = spec.run(seed=seed, structured=True).span_jsonl
        inline = spec.run(seed=seed, weave="inline").span_jsonl
        col = spec.run(seed=seed, weave="columnar").span_jsonl
        assert inline == post, f"{workload} seed={seed}: inline != post"
        assert col == post, f"{workload} seed={seed}: columnar != post"


def test_columnar_spans_identical_to_inline():
    """to_spans() materialization reproduces the inline object path's Span
    list exactly — contexts, parents, attrs, events, merged order."""
    spec = get_scenario("link_loss_rpc")
    inline = spec.run(seed=1, weave="inline").spans
    col = spec.run(seed=1, weave="columnar").spans
    assert col == inline


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_columnar_span_columns_bitwise_matches_object_build(scenario):
    """SpanColumns built from the woven arrays (no Span round-trip) must be
    bit-identical to the object-loop build over the materialized spans:
    same codes, same pools, same float bits."""
    import struct

    run = get_scenario(scenario).run(seed=0, weave="columnar")
    a = run.session.columns()      # array-native: SpanColumns.from_woven
    b = SpanColumns(run.spans)     # reference: per-span python loop
    assert a.n_spans == b.n_spans
    assert a.keys == b.keys
    assert list(a.key_codes) == list(b.key_codes)
    assert list(a.dur_ps) == list(b.dur_ps)
    assert list(a.request_idx) == list(b.request_idx)
    pack = struct.Struct("<d").pack
    assert [pack(v) for v in a.mitigation_us] == [pack(v) for v in b.mitigation_us]
    assert pack(a.mitigation_penalty) == pack(b.mitigation_penalty)


def test_columnar_run_stats_identical_to_from_spans():
    """RunStats.from_columns over the columnar-emit SpanColumns reproduces
    from_spans exactly — same float bits, same dict ordering — on a
    mitigated run (penalty accumulation + request pools exercised)."""
    spec = get_scenario("link_loss_rpc")
    run = spec.run(seed=1, weave="columnar")
    kw = dict(scenario=spec.name, seed=1, expected=spec.expected_classes,
              detected=run.detected, findings=run.diagnosis.findings,
              late_events=run.session.late_events)
    a = RunStats.from_spans(run.spans, **kw)
    b = RunStats.from_columns(run.session.columns(), spans=run.spans, **kw)
    assert a == b
    assert list(a.component_us) == list(b.component_us)  # dict order too


def test_columnar_mode_rejects_live_exporters():
    sw = StreamingWeaver(columnar=True)
    with pytest.raises(RuntimeError, match="columnar"):
        sw.add_live_exporter(_RecordingExporter())


def test_finish_columns_requires_columnar_mode():
    with pytest.raises(RuntimeError, match="columnar=True"):
        StreamingWeaver().finish_columns()


def test_unknown_weave_mode_raises_typed():
    with pytest.raises(ValueError, match="post.*inline.*sharded.*columnar"):
        get_scenario("healthy_baseline").run(seed=0, weave="zigzag")


# ---------------------------------------------------------------------------
# Shard merge: streaming, bytes invariant to shard count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 3, 5, 8])
def test_merge_span_jsonl_bytes_invariant_to_shard_count(n_shards, tmp_path):
    """Splitting one export into any number of shards and streaming-merging
    them back must reproduce the serial bytes exactly (ids already share
    one space, so no disambiguation)."""
    serial = get_scenario("lossy_dcn").run(seed=2, weave="inline").span_jsonl
    lines = serial.splitlines()
    paths = []
    for i in range(n_shards):
        p = tmp_path / f"shard{i}.jsonl"
        p.write_text("".join(ln + "\n" for ln in lines[i::n_shards]))
        paths.append(str(p))
    out = tmp_path / "merged.jsonl"
    n = merge_span_jsonl(paths, str(out), disambiguate=False)
    assert n == len(lines)
    assert out.read_text() == serial


def test_merge_span_jsonl_disambiguates_colliding_id_spaces(tmp_path):
    """Two shards carrying the *same* run (the sweep case: every cell
    resets the id counters) must come out with disjoint id spaces —
    shard index in the top 8 hex digits, parents and links rewritten to
    match — and still parse as JSON."""
    import json as _json

    serial = get_scenario("lossy_dcn").run(seed=2, weave="inline").span_jsonl
    paths = []
    for i in range(2):
        p = tmp_path / f"cell{i}.jsonl"
        p.write_text(serial)
        paths.append(str(p))
    out = tmp_path / "merged.jsonl"
    n_lines = len(serial.splitlines())
    assert merge_span_jsonl(paths, str(out)) == 2 * n_lines
    seen = set()
    spans_by_trace_prefix = {0: 0, 1: 0}
    with open(out) as f:
        for line in f:
            r = _json.loads(line)
            seen.add((r["trace_id"], r["span_id"]))
            shard = int(r["trace_id"][:8], 16)
            spans_by_trace_prefix[shard] += 1
            if r["parent_id"] is not None:
                assert int(r["parent_id"][:8], 16) == shard  # rewritten too
    assert len(seen) == 2 * n_lines, "ids still collide after disambiguation"
    assert spans_by_trace_prefix[0] == spans_by_trace_prefix[1] == n_lines
