"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536, early fusion over VQ image tokens, qk-norm.
[arXiv:2405.09818; unverified].  Patch/VQ frontend is a STUB:
input_specs() provides precomputed token embeddings.
"""
from ..models.config import ModelConfig
from . import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,
        qk_norm=True,
        mlp_act="swiglu",
        frontend="vision",
        rope_theta=10_000.0,
        param_dtype="bfloat16",
    ),
    microbatches={"train_4k": 16},
    kv_cache_dtype={"decode_32k": "int8"},
)
