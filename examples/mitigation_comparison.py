"""Compete remediation policies on the same fault trace and score them.

The mitigation subsystem's end-to-end story in one script:

1. take ``link_loss_rpc`` — RPC serving over a DCN link that drops 35% of
   chunks, each drop costing a 4 ms default re-send;
2. sweep it under three policies (``--mitigations`` axis): the
   ``do_nothing`` baseline, ``retransmit`` (cap the re-send delay once
   drops are seen), and ``disable_and_reroute`` (take the lossy link out
   of service and detour, paying a capacity penalty);
3. print the ``score_mitigations()`` scoreboard — p50/p99/p99.9 request
   latency per policy, detection-to-mitigation latency, capacity penalty,
   and which policies beat the baseline on p99.9;
4. show one mitigated run's ``Mitigation`` span subtree — the policy's
   trigger/action/done trail woven into the same trace as the requests it
   rescued.

Run from the repo root:

    PYTHONPATH=src python examples/mitigation_comparison.py
    PYTHONPATH=src python examples/mitigation_comparison.py --seeds 4 --jobs 4
"""
import argparse
import tempfile

from repro.sim import SweepSpec, get_scenario, run_sweep, shutdown_pool


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default="link_loss_rpc")
    ap.add_argument("--mitigations",
                    default="do_nothing,retransmit,disable_and_reroute")
    ap.add_argument("--seeds", type=int, default=2,
                    help="number of seeds (0..N-1) per policy")
    ap.add_argument("--jobs", type=int, default=1)
    args = ap.parse_args()

    policies = tuple(m.strip() for m in args.mitigations.split(",") if m.strip())
    spec = SweepSpec(
        scenarios=(args.scenario,),
        seeds=tuple(range(args.seeds)),
        mitigations=policies,
    )
    print(f"sweeping {args.scenario} x {policies} x {args.seeds} seeds ...")
    with tempfile.TemporaryDirectory(prefix="mitigation-comparison-") as d:
        result = run_sweep(spec, d, jobs=args.jobs)
        board = result.score_mitigations()
    print()
    print(board.report())

    # -- one mitigated run's span subtree --------------------------------
    active = [p for p in policies if p != "do_nothing"]
    if not active:
        return
    shown = active[0]
    run = get_scenario(args.scenario).run(seed=0, mitigation=shown)
    print()
    print(f"Mitigation spans woven into the {args.scenario} trace "
          f"(policy={shown}, seed=0):")
    mitigation_roots = [s for s in run.spans if s.name == "Mitigation"]
    for root in mitigation_roots:
        attrs = " ".join(f"{k}={v}" for k, v in sorted(root.attrs.items()))
        print(f"  Mitigation [{root.duration / 1e6:.1f}us] {attrs}")
        for ts, name, ev_attrs in root.events:
            print(f"    event {name} @ {ts / 1e6:.1f}us "
                  + " ".join(f"{k}={v}" for k, v in sorted(ev_attrs.items())))
        children = [
            s for s in run.spans
            if s.parent is not None
            and s.parent.span_id == root.context.span_id
        ]
        for child in children:
            cattrs = " ".join(f"{k}={v}" for k, v in sorted(child.attrs.items()))
            print(f"    {child.name} [{child.duration / 1e6:.1f}us] {cattrs}")
    shutdown_pool()


if __name__ == "__main__":
    main()
