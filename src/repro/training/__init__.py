from .optimizer import AdamWConfig, adamw_update, global_norm, init_opt_state, lr_at
from .train_step import (
    TrainConfig,
    abstract_train_state,
    cross_entropy,
    init_train_state,
    make_loss_fn,
    make_train_step,
)

__all__ = [k for k in dir() if not k.startswith("_")]
