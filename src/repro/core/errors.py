"""Typed exceptions for the Columbo core.

The original ``ColumboScript`` surfaced misuse as bare ``assert`` failures
and ``KeyError`` lookups.  The ``TraceSession`` API raises structured
exceptions instead so callers can distinguish composition errors (an
unregistered simulator type) from lifecycle errors (reading spans before
``run()``).

``UnknownSimTypeError`` deliberately subclasses ``KeyError`` so code that
guarded the old ``WEAVERS[sim_type]`` / ``_SYNC_ORDER[sim_type]`` lookups
with ``except KeyError`` keeps working.
"""
from __future__ import annotations


class ColumboError(Exception):
    """Base class for all Columbo core errors."""


class TraceSpecError(ColumboError):
    """A declarative TraceSpec / SourceSpec is malformed."""


class SessionStateError(ColumboError):
    """An operation was attempted in the wrong session lifecycle state
    (e.g. adding sources after ``run()``, or running twice)."""


class SessionNotRunError(SessionStateError):
    """Results were requested before ``run()`` completed."""


class UnknownSimTypeError(ColumboError, KeyError):
    """A simulator type has no registration in the SimulatorRegistry."""

    def __init__(self, sim_type: object, registered: object = None) -> None:
        self.sim_type = sim_type
        self.registered = registered
        msg = f"unknown simulator type {sim_type!r}"
        if registered:
            msg += f"; registered: {sorted(registered)}"
        super().__init__(msg)

    def __str__(self) -> str:  # KeyError quotes its arg; keep the message
        return self.args[0]
