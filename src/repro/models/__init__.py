"""Composable pure-JAX model stack for the 10 assigned architectures."""
from .config import ModelConfig
from .params import (
    PSpec,
    Rules,
    abstract_params,
    count_params,
    init_params,
    partition_specs,
)
from .sharding import constrain, make_rules, sharding_context
from .transformer import (
    cache_specs,
    decode_step,
    forward,
    init_cache,
    model_pspecs,
)

__all__ = [k for k in dir() if not k.startswith("_")]
