"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Per (arch × shape) on the single-pod 16x16 mesh:

  compute term    = HLO_FLOPs / peak_FLOPs            (per-chip quantities:
  memory term     = HLO_bytes / HBM_bw                 an SPMD module is the
  collective term = wire_bytes / ICI_link_bw           per-device program)

HLO_FLOPs/bytes come from the depth-extrapolated cost compiles
(--mode cost: layers + inner scans unrolled, exact trip counts — XLA's
cost_analysis does not multiply while-loop bodies), falling back to the
scanned compile (flagged) when no cost artifact exists.  wire_bytes models
ring algorithms (AR 2(N-1)/N etc.) parsed from the optimized HLO.

Headline score (roofline_fraction):
  train/prefill — MFU-style: MODEL_FLOPS_time / max(term)
  decode        — MBU-style: MIN_BYTES_time / max(term), where MIN_BYTES is
                  the unavoidable HBM traffic (active params + KV/state
                  cache read once per token).
"""
from __future__ import annotations

import csv
import glob
import json
import os
import time
from typing import Any, Dict, List, Optional

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN_DIR = os.path.join(REPO, "results", "dryrun")
OUT_CSV = os.path.join(REPO, "results", "roofline.csv")

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link
CHIPS = 256


def _load(path: str) -> Optional[Dict[str, Any]]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _model_flops_per_dev(rec: Dict[str, Any], shape: str, kind: str) -> float:
    n_active = rec["model"]["n_active_params"]
    from repro.configs import SHAPES

    s = SHAPES[shape]
    if kind == "train":
        tokens = s.global_batch * s.seq
        total = 6.0 * n_active * tokens
    elif kind == "prefill":
        tokens = s.global_batch * s.seq
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * s.global_batch
    return total / CHIPS


def analyze_cell(arch: str, shape: str) -> Optional[Dict[str, Any]]:
    full = _load(os.path.join(DRYRUN_DIR, f"{arch}.{shape}.16x16.json"))
    cost = _load(os.path.join(DRYRUN_DIR, f"{arch}.{shape}.cost.json"))
    if full is None or not full.get("ok"):
        return None
    if cost is not None and cost.get("ok"):
        flops = cost["extrapolated"]["flops"]
        # TPU-fusion-adjusted bytes (raw cost_analysis bytes kept as the
        # pessimistic bound in the csv)
        bytes_ = cost["extrapolated"].get("tpu_bytes") or cost["extrapolated"]["bytes"]
        bytes_raw = cost["extrapolated"]["bytes"]
        mb = cost.get("microbatches") or 1
        # cost compiles run mb=1; real cells run `mb` accumulation sweeps.
        # Activation all-reduces scale with TOKENS (constant per step);
        # param all-gathers/reduce-scatters repeat per microbatch.
        pk = cost["extrapolated"].get("coll_per_kind", {})
        ar_like = pk.get("all-reduce", 0) + pk.get("all-to-all", 0) + pk.get(
            "collective-permute", 0
        )
        ag_rs = pk.get("all-gather", 0) + pk.get("reduce-scatter", 0)
        # convert operand bytes to ring wire bytes approximately via the
        # measured wire/operand ratio
        total_op = max(sum(pk.values()), 1)
        wire_ratio = cost["extrapolated"]["wire_bytes"] / total_op
        wire = (ar_like + mb * ag_rs) * wire_ratio
        wire_low = wire_high = wire
        src = "cost-extrapolated"
    else:
        flops = full["cost"]["flops"]
        bytes_ = bytes_raw = full["cost"]["bytes_accessed"]
        wire_low = wire_high = full["collectives"].get("wire_bytes", 0)
        src = "scanned (UNDERCOUNTS loop bodies)"

    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_n = wire_high / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dominant = max(terms, key=terms.get)
    mf = _model_flops_per_dev(full, shape, full["kind"])
    if full["kind"] == "decode":
        # MBU: minimum HBM traffic per token = active params (bf16) + the
        # per-device share of cache/state reads; approximate the latter by
        # the cell's per-device argument bytes excluding params/opt — use
        # the memory_analysis argument size as the cache+params proxy.
        min_bytes = full["memory"].get("argument_size_in_bytes", 0.0)
        t_model = min_bytes / HBM_BW
    else:
        t_model = mf / PEAK_FLOPS
    frac = t_model / max(max(terms.values()), 1e-30)
    suggestions = {
        "compute": "reduce recompute (remat policy) / useless FLOPs — compute-bound is the good case",
        "memory": "increase arithmetic intensity: fuse, larger microbatches, bf16 IO, avoid re-materialized gathers",
        "collective": "reshard to cut gathered bytes (FSDP axis, TP extent), overlap collectives with compute, compress",
    }
    return {
        "arch": arch,
        "shape": shape,
        "kind": full["kind"],
        "flops_per_dev": flops,
        "bytes_per_dev": bytes_,
        "bytes_raw_per_dev": bytes_raw,
        "wire_bytes_per_dev": wire_high,
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_n,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": mf / max(flops, 1e-30),
        "roofline_fraction": frac,
        "mem_gib_per_dev": full["memory"]["total_bytes"] / 2**30,
        "source": src,
        "suggestion": suggestions[dominant],
    }


def analyze_all() -> List[Dict[str, Any]]:
    from repro.configs import all_cells

    out = []
    for arch, shape in all_cells():
        r = analyze_cell(arch, shape)
        if r:
            out.append(r)
    return out


def write_csv(rows: List[Dict[str, Any]], path: str = OUT_CSV) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if not rows:
        return
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)


def markdown_table(rows: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "MODEL/HLO | roofline_frac | GiB/dev |\n|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['mem_gib_per_dev']:.1f} |\n"
        )
    return hdr + body


def run():
    t0 = time.perf_counter()
    rows = analyze_all()
    write_csv(rows)
    us = (time.perf_counter() - t0) * 1e6
    out = []
    n_cost = sum(1 for r in rows if r["source"].startswith("cost"))
    out.append(
        ("roofline.cells", us,
         f"{len(rows)} cells analyzed ({n_cost} cost-extrapolated) -> results/roofline.csv")
    )
    for r in rows:
        out.append(
            (f"roofline.{r['arch']}.{r['shape']}", 0.0,
             f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
             f"c/m/n={r['compute_s']:.2e}/{r['memory_s']:.2e}/{r['collective_s']:.2e}")
        )
    return out


if __name__ == "__main__":
    rows = analyze_all()
    write_csv(rows)
    print(markdown_table(rows))
