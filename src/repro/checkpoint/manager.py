"""Sharded checkpointing without external deps.

Layout:  <dir>/step_<N>/
            manifest.json      — tree structure, shapes, dtypes, step, extras
            <leaf-path>.npy    — one file per pytree leaf (host-local values)

Writes are atomic (tmp dir + rename), retention keeps the last K steps,
``save_async`` runs serialization on a background thread (the training loop
continues), and ``restore`` reshards onto any mesh/sharding — the basis of
elastic restart (checkpoint from a 256-chip mesh restores onto whatever
survives).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "__"


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(_key_str(k) for k in path) or "root"
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None
        self._async_err: Optional[BaseException] = None

    # -- write -----------------------------------------------------------------

    def save(self, step: int, tree: Any, extras: Optional[Dict[str, Any]] = None) -> str:
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host_tree, extras)

    def save_async(self, step: int, tree: Any, extras: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # snapshot to host memory synchronously (cheap), write on a thread
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _run() -> None:
            try:
                self._write(step, host_tree, extras)
            except BaseException as e:  # surfaced on next wait()
                self._async_err = e

        self._async_thread = threading.Thread(target=_run, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err is not None:
            err, self._async_err = self._async_err, None
            raise err

    def _write(self, step: int, host_tree: Any, extras: Optional[Dict[str, Any]]) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=self.dir)
        leaves = _flatten_with_paths(host_tree)
        manifest = {
            "step": step,
            "extras": extras or {},
            "leaves": {},
        }
        for name, arr in leaves:
            arr = np.asarray(arr)
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"][name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)           # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- read ------------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        like: Any,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``like``; optionally placing each
        leaf with the given shardings (any mesh — elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        names = [n for n, _ in _flatten_with_paths(like)]
        leaves = []
        for name in names:
            arr = np.load(os.path.join(d, name + ".npy"))
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            flat_s = treedef.flatten_up_to(shardings)
            flat_t = jax.tree_util.tree_leaves(tree)
            tree = jax.tree_util.tree_unflatten(
                treedef,
                [jax.device_put(a, s) for a, s in zip(flat_t, flat_s)],
            )
        return tree, manifest["extras"]
