"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _qkv(B, H, K, S, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, K, S, D), dtype)
    v = jax.random.normal(ks[2], (B, K, S, D), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,D,g", [(128, 32, 1), (256, 64, 4), (128, 128, 2)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64), (False, None)])
def test_flash_attention_sweep(S, D, g, dtype, causal, window):
    B, K = 2, 2
    H = K * g
    q, k, v = _qkv(B, H, K, S, D, dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="pallas", block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_flash_attention_grad_matches_reference():
    B, H, K, S, D = 1, 4, 2, 128, 32
    q, k, v = _qkv(B, H, K, S, D, jnp.float32)

    gp = jax.grad(lambda q, k, v: ops.flash_attention(
        q, k, v, impl="pallas", block_q=64, block_k=64).sum(), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q, k, v: ref.flash_attention_ref(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,D,valid", [(256, 64, 256), (256, 64, 100), (128, 128, 1)])
def test_decode_attention_sweep(S, D, valid, dtype):
    B, H, K = 2, 4, 2
    q = jax.random.normal(KEY, (B, H, D), dtype)
    _, k, v = _qkv(B, H, K, S, D, dtype)
    out = ops.decode_attention(q, k, v, jnp.int32(valid), impl="pallas", block_s=64)
    exp = ref.decode_attention_ref(q, k, v, jnp.int32(valid))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("L,W,bw", [(32, 256, 128), (64, 512, 512), (17, 256, 256)])
def test_rglru_scan_sweep(L, W, bw):
    B = 2
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, L, W)))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, W))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (B, W))
    hp, hTp = ops.rglru_scan(a, x, h0, impl="pallas", block_w=bw)
    hr, hTr = ref.rglru_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hTp), np.asarray(hTr), atol=1e-5, rtol=1e-5)


@given(
    st.integers(min_value=1, max_value=24),
    st.integers(min_value=1, max_value=3).map(lambda i: 128 * i),
)
@settings(max_examples=12, deadline=None)
def test_rglru_scan_property(L, W):
    B = 1
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, L, W)))
    x = jax.random.normal(jax.random.PRNGKey(3), (B, L, W))
    h0 = jnp.zeros((B, W))
    hp, _ = ops.rglru_scan(a, x, h0, impl="pallas", block_w=128)
    hr, _ = ref.rglru_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("L,Di,N,bd", [(32, 128, 8, 64), (16, 256, 16, 128)])
def test_ssm_scan_sweep(L, Di, N, bd):
    B = 2
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, L, Di, N)))
    bx = jax.random.normal(jax.random.PRNGKey(1), (B, L, Di, N))
    c = jax.random.normal(jax.random.PRNGKey(2), (B, L, N))
    h0 = jax.random.normal(jax.random.PRNGKey(3), (B, Di, N))
    yp, hTp = ops.ssm_scan(a, bx, c, h0, impl="pallas", block_d=bd)
    yr, hTr = ref.ssm_scan_ref(a, bx, c, h0)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(hTp), np.asarray(hTr), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("R,D,dtype", [(64, 256, jnp.float32), (128, 512, jnp.bfloat16)])
def test_rmsnorm_sweep(R, D, dtype):
    x = jax.random.normal(KEY, (R, D), dtype)
    s = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (D,), jnp.float32)
    out = ops.rmsnorm(x, s, impl="pallas", block_r=32)
    exp = ref.rmsnorm_ref(x, s)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_pallas_attention_in_model_matches_reference_model():
    """attention_impl='pallas' end-to-end inside the transformer."""
    import dataclasses

    from repro.models import ModelConfig, forward, init_params, model_pspecs

    base = ModelConfig(
        name="t", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=128, head_dim=32, remat="none", dtype="float32",
        attn_block_q=64,
    )
    params = init_params(KEY, model_pspecs(base))
    toks = jax.random.randint(KEY, (2, 128), 0, 128)
    lg_ref, _ = jax.jit(lambda p, t: forward(base, p, t))(params, toks)
    cfg_pl = dataclasses.replace(base, attention_impl="pallas")
    lg_pl, _ = jax.jit(lambda p, t: forward(cfg_pl, p, t))(params, toks)
    np.testing.assert_allclose(np.asarray(lg_pl), np.asarray(lg_ref), atol=2e-4, rtol=2e-4)
