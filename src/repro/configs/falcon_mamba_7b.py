"""falcon-mamba-7b [ssm] — 64L d_model=4096, attention-free Mamba-1 blocks,
vocab=65024, ssm_state=16.  [arXiv:2410.05355; unverified].
d_inner = 2*d_model = 8192, dt_rank = ceil(4096/16) = 256, conv width 4.

Runs long_500k (recurrent state is O(1) in context length).
"""
from ..models.config import ModelConfig
from . import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=65024,
        head_dim=64,
        block_pattern=("mamba",),
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
    ),
    microbatches={"train_4k": 8},
)
