"""RG-LRU diagonal linear recurrence — Pallas TPU kernel.

h_t = a_t * h_{t-1} + x_t over time, elementwise in the width dim.  The
recurrence is sequential in t but embarrassingly parallel in (batch, width):
grid = (B, W/Bw); each program keeps its (L, Bw) tiles of a and x in VMEM
and a running (Bw,) state, emitting all L outputs.  VMEM budget =
2 * L * Bw * 4B (+ output), so Bw is chosen so tiles fit ~8 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, x_ref, h0_ref, h_ref, hT_ref, *, L: int):
    h = h0_ref[0].astype(jnp.float32)          # (Bw,)

    def body(t, h):
        h = a_ref[0, t].astype(jnp.float32) * h + x_ref[0, t].astype(jnp.float32)
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, L, body, h)
    hT_ref[0] = h.astype(hT_ref.dtype)


def rglru_scan_fwd(
    a: jax.Array,            # (B, L, W) f32
    x: jax.Array,            # (B, L, W) f32
    h0: jax.Array,           # (B, W) f32
    block_w: int = 512,
    interpret: bool = False,
):
    B, L, W = a.shape
    bw = min(block_w, W)
    assert W % bw == 0
    nw = W // bw
    kernel = functools.partial(_rglru_kernel, L=L)
    h_all, h_T = pl.pallas_call(
        kernel,
        grid=(B, nw),
        in_specs=[
            pl.BlockSpec((1, L, bw), lambda b, w: (b, 0, w)),
            pl.BlockSpec((1, L, bw), lambda b, w: (b, 0, w)),
            pl.BlockSpec((1, bw), lambda b, w: (b, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, bw), lambda b, w: (b, 0, w)),
            pl.BlockSpec((1, bw), lambda b, w: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), h0.dtype),
        ],
        interpret=interpret,
    )(a, x, h0)
    return h_all, h_T
