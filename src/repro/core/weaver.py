"""SpanWeavers (Columbo §3.5 consumers + §3.6 context propagation).

A SpanWeaver is the terminal stage of one simulator-specific pipeline.  It
coalesces the type-specific event stream into spans (units of work in that
simulator) and propagates trace context:

* **intra-weaver** — e.g. a host Step span parents the DataLoad / Dispatch /
  Checkpoint spans woven from the same stream;
* **inter-weaver** — across natural boundaries that exist in the real system
  (host→chip dispatch ≙ PCIe, chip→ICI chunk handoff ≙ Ethernet), via the
  shared ContextRegistry keyed by ids present in both simulators' logs
  (dispatch ids, DMA ids, collective ids, chunk ids).

Weavers poll eagerly and fall back to *deferred* resolution (resolved at
script finish), which makes weaving independent of pipeline scheduling —
a correctness improvement over strictly-ordered polling that the paper lists
under "Correct Context Propagation" challenges (§6).
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, ClassVar, Dict, List, Optional, Tuple

from .context import ContextRegistry, Key
from .events import Event, SimType, sim_type_value
from .pipeline import Consumer
from .span import Span, SpanBuilder, SpanContext, new_trace_id

# ---------------------------------------------------------------------------


class LateEventWarning(UserWarning):
    """An event referenced a span that already closed — e.g. a retransmit
    or mitigation child completing after its root span finished — so the
    weaver had to drop it.  Counted per-weaver in ``late_events`` and
    rolled up into ``RunStats.late_events``; previously these events were
    silently discarded."""


class SpanWeaver(Consumer):
    """Base consumer turning one simulator's event stream into spans,
    propagating context through the shared registry (§3.5–3.6)."""

    sim_type: ClassVar[SimType]
    span_types: ClassVar[Tuple[str, ...]] = ()

    #: When True, every :meth:`_parent_or_defer` skips the eager poll and
    #: defers straight to finish-time resolution.  The inline (in-sim)
    #: weave sets this: sequential post-hoc weaving drains whole simulator
    #: types in priority order, so its eager polls observe the pusher
    #: type's *final* registry state — which interleaved inline polls
    #: cannot (e.g. two hosts pushing the same ("dispatch", chip, step,
    #: program) key: post-hoc sees the last push, inline would see
    #: whichever came before the poll).  Finish-time resolution reads the
    #: same final store, restoring byte-identity.
    defer_polls = False

    def __init__(
        self,
        registry: ContextRegistry,
        poll_timeout: float = 0.0,
    ) -> None:
        self.registry = registry
        self.poll_timeout = poll_timeout
        self.spans: List[Span] = []
        self.span_type_counts: Dict[str, int] = {}
        self.unhandled_events = 0
        self.late_events = 0
        self._late_warned: set = set()
        self._handlers: Dict[str, Callable[[Event], None]] = {}
        for kind in type(self)._kinds():
            self._handlers[kind] = getattr(self, "_on_" + kind)

    @classmethod
    def _kinds(cls) -> List[str]:
        return [m[4:] for m in dir(cls) if m.startswith("_on_")]

    # -- pipeline Consumer interface ------------------------------------------

    def consume(self, ev: Event) -> None:
        h = self._handlers.get(ev.kind)
        if h is None:
            self.unhandled_events += 1
            return
        h(ev)

    def consume_many(self, events) -> int:
        """Batched consume: one dict-lookup-table dispatch loop with the
        handler table and counters hoisted into locals.  This is the
        pipeline fast path's entry point (``Pipeline.run_sync`` with no
        actors) — per event it costs one ``dict.get`` and the handler
        call, nothing else."""
        get = self._handlers.get
        n = 0
        unhandled = 0
        for ev in events:
            h = get(ev.kind)
            if h is not None:
                h(ev)
            else:
                unhandled += 1
            n += 1
        self.unhandled_events += unhandled
        return n

    def on_finish(self) -> None:
        pass

    # -- helpers ---------------------------------------------------------------

    def emit(self, span: Span) -> None:
        self.spans.append(span)
        self.span_type_counts[span.name] = self.span_type_counts.get(span.name, 0) + 1

    def _late(self, ev: Event) -> None:
        """An event whose span already closed (or never opened): count it
        and warn — never drop silently.  The warning fires once per
        (kind, source) per weaver (late chunks after a closed collective
        are legion at scale; the counter carries the full tally), and the
        message omits the timestamp so the warnings registry stays
        bounded."""
        self.late_events += 1
        key = (ev.kind, ev.source)
        if key not in self._late_warned:
            self._late_warned.add(key)
            warnings.warn(
                f"late {ev.kind!r} event on {ev.source!r}: its span already "
                f"closed; event dropped",
                LateEventWarning,
                stacklevel=3,
            )

    def _begin(
        self,
        name: str,
        ev: Event,
        trace_id: int,
        parent: Optional[SpanContext],
        attrs: Optional[Dict[str, Any]] = None,
    ) -> SpanBuilder:
        return SpanBuilder(
            name=name,
            start=ev.ts,
            trace_id=trace_id,
            parent=parent,
            component=ev.source,
            sim_type=sim_type_value(self.sim_type),
            attrs=attrs,
        )

    def _parent_or_defer(self, builder: SpanBuilder, key: Key) -> None:
        """Eager poll; if the upstream context is not yet in the registry,
        defer resolution to script-finish (order-independent weaving).
        With :attr:`defer_polls` set, defer unconditionally."""
        if not self.defer_polls:
            ctx = self.registry.poll(key, timeout=self.poll_timeout or None)
            if ctx is not None:
                builder.span.parent = ctx
                builder.span.context = SpanContext(ctx.trace_id, builder.span.context.span_id)
                return
        self.registry.defer(builder.span, key, mode="parent")


# ---------------------------------------------------------------------------
# HOST runtime weaver — 6 span types (paper Table 1: host = 6)
# ---------------------------------------------------------------------------


class HostSpanWeaver(SpanWeaver):
    """Weaves host-runtime events: steps, data loads, DMAs, dispatches,
    checkpoints, NTP exchanges; pushes dispatch/DMA contexts."""

    sim_type = SimType.HOST
    span_types = (
        "HostStep", "DataLoad", "H2DTransfer", "Dispatch", "Checkpoint",
        "NtpSync", "HostTimeline", "RpcRequest", "RpcCall", "RpcWork",
        "RpcDrop", "RpcRetry", "Mitigation", "Retransmit",
    )

    def __init__(self, registry: ContextRegistry, poll_timeout: float = 0.0) -> None:
        super().__init__(registry, poll_timeout)
        self._step: Dict[str, SpanBuilder] = {}       # host -> open HostStep
        self._load: Dict[str, SpanBuilder] = {}
        self._h2d: Dict[Any, SpanBuilder] = {}        # dma id -> open transfer
        self._dispatch: Dict[Any, SpanBuilder] = {}   # (host, chip, step, program)
        self._ckpt: Dict[str, SpanBuilder] = {}
        self._timeline: Dict[str, SpanBuilder] = {}   # host -> whole-run span
        self._rpc_req: Dict[Any, SpanBuilder] = {}    # (host, rid) -> RpcRequest
        self._rpc_call: Dict[Any, SpanBuilder] = {}   # (host, sub) -> RpcCall
        self._rpc_work: Dict[str, SpanBuilder] = {}   # host -> open RpcWork
        self._mitigation: Dict[Any, SpanBuilder] = {}   # (host, policy) -> open
        self._mitigation_ctx: Dict[Any, SpanContext] = {}  # last span per key
        self._retransmit: Dict[Any, SpanBuilder] = {}   # (host, chunk) -> open

    # one trace per training step, shared by all hosts: first host to begin
    # the step allocates, the rest adopt (atomic get-or-create on the registry)
    def _trace_for_step(self, step: Any) -> int:
        key: Key = ("trace", step)
        ctx = self.registry.poll(key)
        if ctx is not None:
            return ctx.trace_id
        tid = new_trace_id()
        self.registry.push(key, SpanContext(trace_id=tid, span_id=0))
        return tid

    def _cur(self, host: str) -> Optional[SpanBuilder]:
        # the host's current unit of work: its open training step, else the
        # RPC subrequest it is serving (hosts serve serially) — dispatches
        # and DMAs issued while serving parent under the RpcWork span
        return self._step.get(host) or self._rpc_work.get(host)

    def _cur_or_timeline(self, ev: Event) -> SpanBuilder:
        """Current unit of work (open step, else the RPC subrequest being
        served — so stalls/telemetry during serving land inside the
        request's trace), else a lazy per-host whole-run timeline span
        (hosts outside any work loop, e.g. the NTP testbed's client)."""
        cur = self._step.get(ev.source) or self._rpc_work.get(ev.source)
        if cur is not None:
            return cur
        tl = self._timeline.get(ev.source)
        if tl is None:
            tl = self._begin("HostTimeline", ev, new_trace_id(), None, {})
            self._timeline[ev.source] = tl
        return tl

    # -- handlers ---------------------------------------------------------------

    def _on_step_begin(self, ev: Event) -> None:
        tid = self._trace_for_step(ev.attrs.get("step"))
        b = self._begin("HostStep", ev, tid, None, attrs=dict(ev.attrs))
        self._step[ev.source] = b

    def _on_step_end(self, ev: Event) -> None:
        b = self._step.pop(ev.source, None)
        if b is not None:
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_data_load_begin(self, ev: Event) -> None:
        cur = self._cur(ev.source)
        tid = cur.context.trace_id if cur else new_trace_id()
        self._load[ev.source] = self._begin(
            "DataLoad", ev, tid, cur.context if cur else None, dict(ev.attrs)
        )

    def _on_data_load_end(self, ev: Event) -> None:
        b = self._load.pop(ev.source, None)
        if b is not None:
            b.span.attrs.update(ev.attrs)
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_dma_h2d_issue(self, ev: Event) -> None:
        cur = self._cur(ev.source)
        tid = cur.context.trace_id if cur else new_trace_id()
        b = self._begin("H2DTransfer", ev, tid, cur.context if cur else None, dict(ev.attrs))
        dma = ev.attrs.get("dma")
        self._h2d[dma] = b
        # natural boundary: the chip's DMA-landing event carries the same id
        self.registry.push(("h2d", dma), b.context)

    def _on_dma_h2d_complete(self, ev: Event) -> None:
        b = self._h2d.pop(ev.attrs.get("dma"), None)
        if b is not None:
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_dma_d2h_issue(self, ev: Event) -> None:
        self._on_dma_h2d_issue(ev)  # same span type, direction in attrs

    def _on_dma_d2h_complete(self, ev: Event) -> None:
        self._on_dma_h2d_complete(ev)

    def _on_program_enqueue(self, ev: Event) -> None:
        cur = self._cur(ev.source)
        tid = cur.context.trace_id if cur else new_trace_id()
        b = self._begin("Dispatch", ev, tid, cur.context if cur else None, dict(ev.attrs))
        key = (ev.attrs.get("chip"), ev.attrs.get("step"), ev.attrs.get("program"))
        # local state is host-qualified: chip ids are only unique within a
        # host, and one weaver may consume several hosts' merged streams
        self._dispatch[(ev.source,) + key] = b
        # natural boundary: PCIe-style dispatch — the chip's ProgramStart
        # event for (chip, step, program) is caused by this span
        self.registry.push(("dispatch",) + key, b.context)

    def _on_program_retire(self, ev: Event) -> None:
        key = (ev.attrs.get("chip"), ev.attrs.get("step"), ev.attrs.get("program"))
        b = self._dispatch.pop((ev.source,) + key, None)
        if b is not None:
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_ckpt_begin(self, ev: Event) -> None:
        cur = self._cur(ev.source)
        tid = cur.context.trace_id if cur else new_trace_id()
        self._ckpt[ev.source] = self._begin(
            "Checkpoint", ev, tid, cur.context if cur else None, dict(ev.attrs)
        )

    def _on_ckpt_shard_write(self, ev: Event) -> None:
        b = self._ckpt.get(ev.source)
        if b is not None:
            b.span.add_event(ev.ts, "shard_write", ev.attrs)
        else:
            self._late(ev)

    def _on_ckpt_shard_read(self, ev: Event) -> None:
        b = self._ckpt.get(ev.source)
        if b is not None:
            b.span.add_event(ev.ts, "shard_read", ev.attrs)
        else:
            self._late(ev)

    def _on_ckpt_end(self, ev: Event) -> None:
        b = self._ckpt.pop(ev.source, None)
        if b is not None:
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_ntp_exchange(self, ev: Event) -> None:
        # t1..t4 are local/remote timestamps in ps; span covers t1..t4
        cur = self._cur_or_timeline(ev)
        tid = cur.context.trace_id
        t1 = int(ev.attrs.get("t1", ev.ts))
        t4 = int(ev.attrs.get("t4", ev.ts))
        b = SpanBuilder(
            "NtpSync", t1, tid, cur.context, ev.source,
            sim_type_value(self.sim_type), dict(ev.attrs),
        )
        # the request/response packets in the net sim carry (peer, seq)
        self.registry.push(("ntp", ev.source, ev.attrs.get("seq")), b.context)
        self.emit(b.finish(t4))

    def _on_clock_read(self, ev: Event) -> None:
        self._cur_or_timeline(ev).span.add_event(ev.ts, "clock_read", ev.attrs)

    def _on_heartbeat(self, ev: Event) -> None:
        self._cur_or_timeline(ev).span.add_event(ev.ts, "heartbeat", ev.attrs)

    def _on_gc_stall(self, ev: Event) -> None:
        cur = self._cur_or_timeline(ev)
        cur.span.add_event(ev.ts, "gc_stall", ev.attrs)
        cur.span.attrs["stall_ps"] = int(cur.span.attrs.get("stall_ps", 0)) + int(
            ev.attrs.get("dur", 0)
        )

    def _on_host_failure(self, ev: Event) -> None:
        cur = self._cur_or_timeline(ev)
        cur.span.add_event(ev.ts, "host_failure", ev.attrs)
        cur.span.attrs["failed"] = True

    def _on_host_restart(self, ev: Event) -> None:
        self._cur_or_timeline(ev).span.add_event(ev.ts, "host_restart", ev.attrs)

    # -- RPC serving workload: one span tree per request ----------------------
    #
    # rpc_recv opens the per-request root span (its own trace), rpc_send
    # opens one RpcCall child per serving pod and pushes the subrequest
    # context so the request's wire transfers AND the backend's RpcWork
    # span parent under it; rpc_work_begin adopts that context across the
    # host boundary and pushes the reply-leg context; rpc_reply / rpc_done
    # close the fan-in.  The result: RpcRequest -> RpcCall -> {LinkTransfer,
    # RpcWork -> Dispatch -> DeviceProgram -> ...} -> reply LinkTransfer —
    # the end-to-end tree per request id the paper's request tracing needs.

    def _on_rpc_recv(self, ev: Event) -> None:
        b = self._begin("RpcRequest", ev, new_trace_id(), None, dict(ev.attrs))
        self._rpc_req[(ev.source, ev.attrs.get("rid"))] = b

    def _on_rpc_send(self, ev: Event) -> None:
        req = self._rpc_req.get((ev.source, ev.attrs.get("rid")))
        tid = req.context.trace_id if req else new_trace_id()
        b = self._begin("RpcCall", ev, tid, req.context if req else None, dict(ev.attrs))
        sub = ev.attrs.get("sub")
        self._rpc_call[(ev.source, sub)] = b
        # natural boundary: the subrequest's wire chunks and the serving
        # host's rpc_work_begin both carry the same sub id
        self.registry.push(("rpccall", sub), b.context)

    def _on_rpc_work_begin(self, ev: Event) -> None:
        b = self._begin("RpcWork", ev, new_trace_id(), None, dict(ev.attrs))
        sub = ev.attrs.get("sub")
        self._parent_or_defer(b, ("rpccall", sub))
        # the reply chunk carries "<sub>.r": parent it under this work span
        self.registry.push(("rpccall", f"{sub}.r"), b.context)
        self._rpc_work[ev.source] = b

    def _on_rpc_work_end(self, ev: Event) -> None:
        b = self._rpc_work.pop(ev.source, None)
        if b is not None:
            b.span.attrs.update(ev.attrs)
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_rpc_reply(self, ev: Event) -> None:
        b = self._rpc_call.pop((ev.source, ev.attrs.get("sub")), None)
        if b is not None:
            # legacy replies carry only rid/sub (already on the span: bytes
            # unchanged); saturation-mode drop NACKs add status="dropped"
            b.span.attrs.update(ev.attrs)
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_rpc_done(self, ev: Event) -> None:
        b = self._rpc_req.pop((ev.source, ev.attrs.get("rid")), None)
        if b is not None:
            b.span.attrs.update(ev.attrs)
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    # -- serving saturation: LB picks, bounded-queue drops, deadlines, retries
    #
    # rpc_lb_pick annotates the open RpcRequest root (the chosen backend and
    # the policy that chose it — what per-policy CDFs group by);
    # rpc_queue_drop emits an instant RpcDrop span under the dropped
    # attempt's RpcCall context; rpc_timeout closes the attempt's RpcCall in
    # place of the reply that never came; rpc_retry emits an RpcRetry span
    # (covering the backoff window) parented under the original RpcRequest —
    # one trace tells the whole drop/timeout/retry story.

    def _on_rpc_lb_pick(self, ev: Event) -> None:
        req = self._rpc_req.get((ev.source, ev.attrs.get("rid")))
        if req is None:
            self._late(ev)
            return
        req.span.add_event(ev.ts, "rpc_lb_pick", ev.attrs)
        if "policy" in ev.attrs:
            req.span.attrs.setdefault("lb", ev.attrs["policy"])

    def _on_rpc_queue_drop(self, ev: Event) -> None:
        b = self._begin("RpcDrop", ev, new_trace_id(), None, dict(ev.attrs))
        self._parent_or_defer(b, ("rpccall", ev.attrs.get("sub")))
        self.emit(b.finish(ev.ts))

    def _on_rpc_timeout(self, ev: Event) -> None:
        b = self._rpc_call.pop((ev.source, ev.attrs.get("sub")), None)
        if b is not None:
            b.span.attrs.update(ev.attrs)
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_rpc_retry(self, ev: Event) -> None:
        req = self._rpc_req.get((ev.source, ev.attrs.get("rid")))
        tid = req.context.trace_id if req else new_trace_id()
        b = self._begin("RpcRetry", ev, tid, req.context if req else None,
                        dict(ev.attrs))
        end = ev.ts + int(ev.attrs.get("backoff", 0))
        self.emit(b.finish(end))

    # -- mitigation engine: remediation subtrees ------------------------------
    #
    # mitigation_trigger opens a Mitigation span keyed (host, policy); it
    # roots its own trace (a remediation is its own unit of work — sweeps
    # compare them across runs).  mitigation_action lands inside it as a
    # span event and folds the action/penalty into the span attrs (what
    # score_mitigations reads); mitigation_done closes it — trigger→done is
    # the detection-to-mitigation latency.  retransmit_begin/_end weave
    # Retransmit child spans (the `retransmit` policy's per-chunk resends),
    # parented under the policy's Mitigation span even after it closed.

    def _on_mitigation_trigger(self, ev: Event) -> None:
        b = self._begin("Mitigation", ev, new_trace_id(), None, dict(ev.attrs))
        key = (ev.source, ev.attrs.get("policy"))
        self._mitigation[key] = b
        self._mitigation_ctx[key] = b.context

    def _on_mitigation_action(self, ev: Event) -> None:
        b = self._mitigation.get((ev.source, ev.attrs.get("policy")))
        if b is None:
            self._cur_or_timeline(ev).span.add_event(ev.ts, "mitigation_action", ev.attrs)
            return
        b.span.add_event(ev.ts, "mitigation_action", ev.attrs)
        for k in ("action", "target", "penalty"):
            if k in ev.attrs:
                b.span.attrs[k] = ev.attrs[k]

    def _on_mitigation_done(self, ev: Event) -> None:
        b = self._mitigation.pop((ev.source, ev.attrs.get("policy")), None)
        if b is not None:
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_retransmit_begin(self, ev: Event) -> None:
        ctx = self._mitigation_ctx.get((ev.source, ev.attrs.get("policy")))
        tid = ctx.trace_id if ctx else new_trace_id()
        b = self._begin("Retransmit", ev, tid, ctx, dict(ev.attrs))
        self._retransmit[(ev.source, ev.attrs.get("chunk"))] = b

    def _on_retransmit_end(self, ev: Event) -> None:
        b = self._retransmit.pop((ev.source, ev.attrs.get("chunk")), None)
        if b is not None:
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    # -- pipelined-training workload: inter-stage activation hand-off ---------

    def _on_pipe_send(self, ev: Event) -> None:
        cur = self._cur_or_timeline(ev)
        cur.span.add_event(ev.ts, "pipe_send", ev.attrs)
        # the activation transfer's chunk id parents under this stage's step
        self.registry.push(("chunk", ev.attrs.get("chunk")), cur.context)

    def _on_pipe_recv(self, ev: Event) -> None:
        self._cur_or_timeline(ev).span.add_event(ev.ts, "pipe_recv", ev.attrs)

    def on_finish(self) -> None:
        for host, b in self._timeline.items():
            last = max((ts for ts, _, _ in b.span.events), default=b.span.start)
            self.emit(b.finish(last))
        self._timeline.clear()
        for d in (self._step, self._load, self._ckpt, self._rpc_req,
                  self._rpc_call, self._rpc_work, self._mitigation,
                  self._retransmit):
            for b in d.values():
                b.span.attrs["unclosed"] = True
                self.emit(b.finish(b.span.start))
            d.clear()


# ---------------------------------------------------------------------------
# DEVICE (chip) weaver — 4 span types
# ---------------------------------------------------------------------------


class DeviceSpanWeaver(SpanWeaver):
    """Weaves chip events: programs, ops, collectives; adopts the host's
    dispatch context and pushes collective-chunk contexts to the net."""

    sim_type = SimType.DEVICE
    span_types = ("DeviceProgram", "Op", "Collective", "DmaRecv")

    def __init__(
        self,
        registry: ContextRegistry,
        poll_timeout: float = 0.0,
        op_spans: bool = True,
    ) -> None:
        super().__init__(registry, poll_timeout)
        self.op_spans = op_spans      # "arbitrarily detailed": ops as spans or as span-events
        self._prog: Dict[str, SpanBuilder] = {}      # chip -> program
        self._op: Dict[str, SpanBuilder] = {}        # chip -> open op span
        self._coll: Dict[Tuple[str, Any], SpanBuilder] = {}  # (chip, coll id)

    @staticmethod
    def _chip_of(source: str) -> str:
        # "pod0.chip03" -> "chip03" id as logged by host sims
        return source.rsplit(".", 1)[-1]

    def _on_program_start(self, ev: Event) -> None:
        b = self._begin("DeviceProgram", ev, new_trace_id(), None, dict(ev.attrs))
        key = (self._chip_of(ev.source), ev.attrs.get("step"), ev.attrs.get("program"))
        self._parent_or_defer(b, ("dispatch",) + key)
        self._prog[ev.source] = b

    def _on_program_end(self, ev: Event) -> None:
        b = self._prog.pop(ev.source, None)
        if b is not None:
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_op_begin(self, ev: Event) -> None:
        prog = self._prog.get(ev.source)
        if not self.op_spans:
            if prog is not None:
                prog.span.add_event(ev.ts, "op_begin", ev.attrs)
            return
        tid = prog.context.trace_id if prog else new_trace_id()
        self._op[ev.source] = self._begin(
            "Op", ev, tid, prog.context if prog else None, dict(ev.attrs)
        )

    def _on_op_end(self, ev: Event) -> None:
        if not self.op_spans:
            prog = self._prog.get(ev.source)
            if prog is not None:
                prog.span.add_event(ev.ts, "op_end", ev.attrs)
            return
        b = self._op.pop(ev.source, None)
        if b is not None:
            b.span.attrs.update(ev.attrs)
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _sub_event(self, ev: Event, name: str) -> None:
        tgt = self._op.get(ev.source) or self._prog.get(ev.source)
        if tgt is not None:
            tgt.span.add_event(ev.ts, name, ev.attrs)

    def _on_mxu_issue(self, ev: Event) -> None:
        self._sub_event(ev, "mxu_issue")

    def _on_hbm_read(self, ev: Event) -> None:
        self._sub_event(ev, "hbm_read")

    def _on_hbm_write(self, ev: Event) -> None:
        self._sub_event(ev, "hbm_write")

    def _on_collective_start(self, ev: Event) -> None:
        prog = self._prog.get(ev.source)
        tid = prog.context.trace_id if prog else new_trace_id()
        b = self._begin("Collective", ev, tid, prog.context if prog else None, dict(ev.attrs))
        cid = ev.attrs.get("coll")
        self._coll[(ev.source, cid)] = b
        # cross-chip causality: peers and the net weaver key on (coll, chip)
        self.registry.push(("coll", cid, self._chip_of(ev.source)), b.context)

    def _on_collective_chunk_tx(self, ev: Event) -> None:
        b = self._coll.get((ev.source, ev.attrs.get("coll")))
        if b is None:
            self._late(ev)
        else:
            b.span.add_event(ev.ts, "chunk_tx", ev.attrs)
            # natural boundary (Ethernet-style): the link transfer for this
            # chunk is caused by this collective span
            self.registry.push(("chunk", ev.attrs.get("chunk")), b.context)

    def _on_collective_chunk_rx(self, ev: Event) -> None:
        b = self._coll.get((ev.source, ev.attrs.get("coll")))
        if b is None:
            self._late(ev)
        else:
            b.span.add_event(ev.ts, "chunk_rx", ev.attrs)
            # causal link back to the wire transfer that delivered the chunk
            self.registry.defer(b.span, ("link_span", ev.attrs.get("chunk")), mode="link")

    def _on_collective_end(self, ev: Event) -> None:
        b = self._coll.pop((ev.source, ev.attrs.get("coll")), None)
        if b is not None:
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def _on_dma_recv(self, ev: Event) -> None:
        b = self._begin("DmaRecv", ev, new_trace_id(), None, dict(ev.attrs))
        self._parent_or_defer(b, ("h2d", ev.attrs.get("dma")))
        self.emit(b.finish(ev.ts + int(ev.attrs.get("dur", 0))))

    def on_finish(self) -> None:
        for d in (self._op, self._prog):
            for b in d.values():
                b.span.attrs["unclosed"] = True
                self.emit(b.finish(b.span.start))
            d.clear()
        for b in self._coll.values():
            b.span.attrs["unclosed"] = True
            self.emit(b.finish(b.span.start))
        self._coll.clear()


# ---------------------------------------------------------------------------
# NET (interconnect) weaver — 1 span type (paper Table 1: network = 1)
# ---------------------------------------------------------------------------


class NetSpanWeaver(SpanWeaver):
    """Weaves link transfers (enqueue -> wire -> receive) into
    LinkTransfer spans linked to their causing DMA / collective spans."""

    sim_type = SimType.NET
    span_types = ("LinkTransfer",)

    def __init__(self, registry: ContextRegistry, poll_timeout: float = 0.0) -> None:
        super().__init__(registry, poll_timeout)
        self._xfer: Dict[Tuple[str, Any], SpanBuilder] = {}  # (link, chunk)

    def _on_chunk_enqueue(self, ev: Event) -> None:
        ck = ev.attrs.get("chunk")
        b = self._begin("LinkTransfer", ev, new_trace_id(), None, dict(ev.attrs))
        # pick the natural-boundary key by what ids the chunk carries:
        # collective shard -> the sender chip's Collective span; H2D DMA ->
        # the host's H2DTransfer span; NTP packet -> the client's NtpSync
        # span; background flows have no cause and stay parentless.
        if "dma" in ev.attrs:
            self._parent_or_defer(b, ("h2d", ev.attrs["dma"]))
        elif ev.attrs.get("proto") == "ntp":
            self._parent_or_defer(b, ("ntp", ev.attrs.get("peer"), ev.attrs.get("seq")))
        elif "rpc" in ev.attrs:
            # RPC request/reply leg: the frontend's RpcCall span (request)
            # or the serving host's RpcWork span (reply, "<sub>.r") pushed
            # the context under this sub id
            self._parent_or_defer(b, ("rpccall", ev.attrs["rpc"]))
        elif "flow" not in ev.attrs:
            self._parent_or_defer(b, ("chunk", ck))
        # let the receiving chip link back to this wire transfer
        self.registry.push(("link_span", ck), b.context)
        self._xfer[(ev.source, ck)] = b

    def _on_chunk_tx(self, ev: Event) -> None:
        b = self._xfer.get((ev.source, ev.attrs.get("chunk")))
        if b is None:
            self._late(ev)
        else:
            b.span.add_event(ev.ts, "wire_tx", ev.attrs)
            # queueing delay = wire_tx.ts - span.start; recorded for analysis
            b.span.attrs["queue_ps"] = ev.ts - b.span.start

    def _on_chunk_drop(self, ev: Event) -> None:
        b = self._xfer.get((ev.source, ev.attrs.get("chunk")))
        if b is None:
            self._late(ev)
        else:
            b.span.add_event(ev.ts, "chunk_drop", ev.attrs)
            b.span.attrs["drops"] = int(b.span.attrs.get("drops", 0)) + 1

    def _on_chunk_rx(self, ev: Event) -> None:
        b = self._xfer.pop((ev.source, ev.attrs.get("chunk")), None)
        if b is not None:
            self.emit(b.finish(ev.ts))
        else:
            self._late(ev)

    def on_finish(self) -> None:
        for b in self._xfer.values():
            b.span.attrs["unclosed"] = True
            self.emit(b.finish(b.span.start))
        self._xfer.clear()


# ---------------------------------------------------------------------------
# Trace finalization: resolve deferred contexts, then recompute trace ids
# from the parent graph (handles chains host -> device -> net regardless of
# pipeline execution order).
# ---------------------------------------------------------------------------


def finalize_spans(spans: List[Span], registry: ContextRegistry) -> Dict[str, int]:
    """Post-weave pass: resolve deferred context links and unify every
    span's trace id with its root's; returns resolution counters."""
    stats = registry.resolve_deferred()
    unify_trace_ids(spans)
    return stats


def unify_trace_ids(spans: List[Span]) -> None:
    """Recompute every span's trace id from the parent graph so the whole
    causal chain (host -> device -> net) lands in one trace.

    Split out of :func:`finalize_spans` because the inline (in-sim) weave
    must run it *after* its own span-id normalization pass but after
    deferred resolution — the two post-weave steps are independent."""
    by_id: Dict[int, Span] = {s.context.span_id: s for s in spans}

    root_trace: Dict[int, int] = {}

    def trace_of(sid: int, _depth: int = 0) -> int:
        if sid in root_trace:
            return root_trace[sid]
        s = by_id.get(sid)
        if s is None:
            return -1
        if s.parent is None or s.parent.span_id not in by_id or _depth > 10000:
            t = s.context.trace_id
        else:
            t = trace_of(s.parent.span_id, _depth + 1)
        root_trace[sid] = t
        return t

    for s in spans:
        t = trace_of(s.context.span_id)
        if t != s.context.trace_id:
            s.context = SpanContext(t, s.context.span_id)
        if s.parent is not None and s.parent.span_id in by_id:
            pt = trace_of(s.parent.span_id)
            if pt != s.parent.trace_id:
                s.parent = SpanContext(pt, s.parent.span_id)


# Retained for backward compatibility; the authoritative binding lives in
# core/registry.py where user code can add simulator types at runtime.
WEAVERS = {
    SimType.HOST: HostSpanWeaver,
    SimType.DEVICE: DeviceSpanWeaver,
    SimType.NET: NetSpanWeaver,
}


def span_type_counts() -> Dict[str, int]:
    """Per-simulator-type span counts — the Table 1 inventory."""
    return {t.value: len(WEAVERS[t].span_types) for t in SimType}
