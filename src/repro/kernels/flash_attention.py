"""Flash attention forward — Pallas TPU kernel.

Online-softmax attention with explicit VMEM tiling:

* grid = (B, H, S/Bq, S/Bk); the last grid axis iterates sequentially on
  TPU, so (m, l, acc) live in VMEM scratch and carry across KV blocks.
* BlockSpecs stream q: (1,1,Bq,D), k/v: (1,1,Bk,D) HBM->VMEM; the GQA
  mapping happens in the k/v index_map (kv head = h // group).
* causal/local masking by block-position iota; fully-masked KV blocks are
  skipped via @pl.when on the block index (no MXU work issued), giving the
  ~2x causal saving without ragged grids.

MXU alignment: Bq/Bk default 512 and D is the head_dim (128 for most of the
assigned archs); f32 accumulation in VMEM scratch, bf16 I/O.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int], bq: int, bk: int, nk: int,
):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level reachability: skip fully-masked KV blocks entirely
    q_lo = pl.program_id(2) * bq
    k_lo = ki * bk
    reachable = jnp.asarray(True)
    if causal:
        reachable = jnp.logical_and(reachable, k_lo <= q_lo + bq - 1)
    if window is not None:
        reachable = jnp.logical_and(reachable, q_lo - (k_lo + bk - 1) < window)

    @pl.when(reachable)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                     # (Bq, Bk)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, -jnp.inf)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        # rows with no valid key yet keep m = -inf; guard the exp
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe[:, None]), 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,            # (B, H, S, D)
    k: jax.Array,            # (B, K, S, D)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, D = q.shape
    K = k.shape[1]
    g = H // K
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk, nk=nk
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
