"""Model configuration for the composable transformer stack.

One ModelConfig describes any of the 10 assigned architectures: a cyclic
``block_pattern`` selects per-layer block kinds (attention global/local,
RG-LRU, Mamba-1), with MoE substituting the MLP where configured.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

BLOCK_KINDS = ("attn", "attn_local", "rglru", "mamba")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # layer pattern, cycled; e.g. gemma3: 5x local + 1 global
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 4096              # local-attention window
    qk_norm: bool = False
    nonparametric_ln: bool = False  # olmo: LN without scale/bias
    mlp_act: str = "swiglu"         # swiglu | gelu
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0            # per-expert hidden (granite 512, llama4 8192)
    shared_expert_d_ff: int = 0     # llama4 shared expert
    capacity_factor: float = 1.25
    moe_every: int = 1              # MoE replaces MLP every k-th layer

    # Mamba-1
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model/16)

    # RG-LRU (recurrentgemma)
    lru_width: int = 0              # 0 -> d_model
    conv_width: int = 4

    # modality frontend stub: input_specs() provides precomputed embeddings
    frontend: str = "none"          # none | audio | vision

    # numerics / execution
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "full"             # none | full | dots
    attention_impl: str = "reference"  # reference | pallas
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (quantized cache)
    scan_layers: bool = True
    logits_f32: bool = True
    # cost-measurement mode: unroll inner lax.scans (attention KV blocks,
    # SSM time chunks) into python loops so compiled cost_analysis() FLOPs
    # are exact (XLA does not multiply while-loop bodies by trip count)
    unroll_inner: bool = False
    attn_block_q: int = 1024   # query-block size of the block-causal attention
    scan_chunk: int = 256      # time-chunk of the SSM/RG-LRU chunked scans

    def __post_init__(self):
        for b in self.block_pattern:
            assert b in BLOCK_KINDS, b
        assert self.family in ("dense", "moe", "ssm", "hybrid", "vlm", "audio")

    # -- derived -------------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank if self.dt_rank else math.ceil(self.d_model / 16)

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width if self.lru_width else self.d_model

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.pattern_period

    @property
    def n_rest_layers(self) -> int:
        return self.n_layers % self.pattern_period

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % self.pattern_period]

    @property
    def uses_attention(self) -> bool:
        return any(b.startswith("attn") for b in self.block_pattern)

    @property
    def pure_global_attention(self) -> bool:
        return all(b == "attn" for b in self.block_pattern)

    @property
    def n_params(self) -> float:
        """Approximate parameter count (used for 6ND model-FLOPs)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        per_layer = {}
        hd = self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        mlp_mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        dense_mlp = mlp_mult * d * self.d_ff
        moe_mlp = (
            self.n_experts * mlp_mult * d * self.expert_d_ff
            + mlp_mult * d * self.shared_expert_d_ff
            + d * self.n_experts
        )
        di, ds, dtr = self.d_inner, self.ssm_state, self.resolved_dt_rank
        mamba = 2 * d * di + di * self.ssm_conv + di * (dtr + 2 * ds) + dtr * di + di * ds + di + di * d
        lw = self.resolved_lru_width
        rglru = 2 * d * lw + lw * self.conv_width + 2 * lw * lw + lw + lw * d
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind in ("attn", "attn_local"):
                total += attn
                total += moe_mlp if (self.is_moe and i % self.moe_every == 0) else dense_mlp
            elif kind == "mamba":
                total += mamba
            elif kind == "rglru":
                total += rglru
                total += moe_mlp if (self.is_moe and i % self.moe_every == 0) else dense_mlp
        return float(total)

    @property
    def n_active_params(self) -> float:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.is_moe:
            return self.n_params
        d = self.d_model
        mlp_mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        inactive = (self.n_experts - self.top_k) * mlp_mult * d * self.expert_d_ff
        n_moe_layers = sum(
            1
            for i in range(self.n_layers)
            if self.block_kind(i).startswith("attn") and i % self.moe_every == 0
        )
        return self.n_params - n_moe_layers * inactive

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 * self.pattern_period),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            window=min(self.window, 64),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            expert_d_ff=64 if self.expert_d_ff else 0,
            shared_expert_d_ff=64 if self.shared_expert_d_ff else 0,
            lru_width=128 if self.lru_width else 0,
            dt_rank=8,
            remat="none",
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return replace(self, **small)
