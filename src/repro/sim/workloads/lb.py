"""Frontend load-balancer policies for the ``rpc`` serving workload.

A policy picks which backend serves the next attempt of a request.  Policies
register by name (``register_lb_policy``) on a small registry mirroring the
workload (:mod:`repro.sim.workload`) and mitigation
(:mod:`repro.sim.mitigation`) registries, so sweeps / the CLI / benchmarks
select them declaratively (``--lb power_of_two_choices``) and unknown knobs
raise ``TypeError`` instead of being silently ignored.

Built-ins:

* ``round_robin`` — cycle through the backends in pod order;
* ``least_loaded`` — pick the backend with the fewest queued + in-service
  subrequests (ties break to the first backend in pod order);
* ``power_of_two_choices`` — sample two distinct backends from the
  workload's seeded RNG stream, keep the less loaded one (the classic
  load-balancing result: almost least-loaded quality at O(1) cost).

Determinism contract: a policy's only randomness source is the
``random.Random`` handed to :meth:`LbPolicy.pick` (the rpc workload's
seeded stream), so one seed reproduces byte-identical logs and spans.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, ClassVar, Dict, List, Sequence


def backend_load(server: Any) -> int:
    """A backend's instantaneous load: queued + in-service subrequests
    (what ``least_loaded`` / ``power_of_two_choices`` compare and what the
    ``rpc_lb_pick`` event's ``qlen`` attribute records)."""
    return len(server.queue) + (1 if server.busy else 0)


@dataclass
class LbPolicy:
    """Base class: a frontend backend-selection policy.

    Subclasses set ``lb_name``, implement :meth:`pick`, and register with
    :func:`register_lb_policy`.  Policies may keep per-instance state (the
    round-robin cursor); one instance drives one workload run.
    """

    #: registry key; subclasses set it (e.g. "round_robin")
    lb_name: ClassVar[str] = ""

    def pick(self, servers: Sequence[Any], rng: random.Random) -> Any:
        """Choose the backend for the next attempt.  ``servers`` is the
        chip-bearing backend list in pod order; ``rng`` is the workload's
        seeded LB stream (the *only* permitted randomness source)."""
        raise NotImplementedError


_LB_POLICIES: Dict[str, type] = {}


def register_lb_policy(cls: type, replace: bool = False) -> type:
    """Class decorator: register an :class:`LbPolicy` subclass under its
    ``lb_name`` (the LB-layer analogue of ``register_workload``)."""
    name = getattr(cls, "lb_name", "")
    if not name:
        raise ValueError(f"{cls.__name__} must set a non-empty lb_name")
    if not replace and name in _LB_POLICIES:
        raise ValueError(
            f"lb policy {name!r} already registered; pass replace=True to override"
        )
    _LB_POLICIES[name] = cls
    return cls


def list_lb_policies() -> List[str]:
    """Registered load-balancer policy names, sorted."""
    return sorted(_LB_POLICIES)


def lb_policy_type(name: str) -> type:
    """Look up a registered LB policy class (KeyError lists what exists)."""
    try:
        return _LB_POLICIES[name]
    except KeyError:
        raise KeyError(
            f"unknown lb policy {name!r}; available: {', '.join(sorted(_LB_POLICIES))}"
        ) from None


def make_lb_policy(name: str, **params: Any) -> LbPolicy:
    """Instantiate a registered LB policy with ``params`` (unknown knobs
    raise ``TypeError`` naming the policy — same contract as
    :func:`~repro.sim.workload.make_workload`)."""
    cls = lb_policy_type(name)
    try:
        return cls(**params)
    except TypeError as e:
        raise TypeError(f"lb policy {name!r}: {e}") from None


@register_lb_policy
@dataclass
class RoundRobin(LbPolicy):
    """Cycle through the backends in pod order, one pick per attempt."""

    lb_name: ClassVar[str] = "round_robin"

    _next: int = field(default=0, init=False, repr=False)

    def pick(self, servers: Sequence[Any], rng: random.Random) -> Any:
        """The next backend in rotation."""
        srv = servers[self._next % len(servers)]
        self._next += 1
        return srv


@register_lb_policy
@dataclass
class LeastLoaded(LbPolicy):
    """Pick the backend with the fewest queued + in-service subrequests
    (ties break to the first backend in pod order — deterministic)."""

    lb_name: ClassVar[str] = "least_loaded"

    def pick(self, servers: Sequence[Any], rng: random.Random) -> Any:
        """The least-loaded backend (stable min: first wins ties)."""
        return min(servers, key=backend_load)


@register_lb_policy
@dataclass
class PowerOfTwoChoices(LbPolicy):
    """Sample two distinct backends from the seeded stream and keep the
    less loaded one (ties keep the first sampled)."""

    lb_name: ClassVar[str] = "power_of_two_choices"

    def pick(self, servers: Sequence[Any], rng: random.Random) -> Any:
        """The less loaded of two seeded random choices."""
        if len(servers) == 1:
            return servers[0]
        i, j = rng.sample(range(len(servers)), 2)
        a, b = servers[i], servers[j]
        return a if backend_load(a) <= backend_load(b) else b
