"""Trace analysis (Columbo §3.2 'Trace analysis', §5 case study figures).

Operates on finalized spans (weaver output).  Provides the analyses used by
the paper's evaluation plus the straggler/fault diagnostics the training
framework exposes as telemetry:

* per-component time breakdown of a trace (Fig. 6);
* clock-offset series from host clock_read events vs. the simulation's
  ground-truth global clock (Fig. 4) and NTP-estimated offsets (Fig. 5);
* critical path through a trace;
* straggler detection across per-chip/per-pod spans (k·MAD outliers);
* ``aggregate()`` — fleet-level statistics over *many* runs (sweep cells):
  per-component latency percentiles, per-fault-class detection and
  false-positive rates, critical-path frequency tables;
* ``score_mitigations()`` — remediation policies competing on the same
  fault trace: per-policy request-tail percentiles, detection-to-mitigation
  latency, and capacity penalty vs the ``do_nothing`` baseline.
"""
from __future__ import annotations

import gc
import statistics
from collections import Counter, defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .span import Span, SpanContext, Trace, assemble_traces

try:  # columnar backend for large-sweep statistics; pure-python fallback
    import numpy as _np
except ModuleNotFoundError:  # pragma: no cover - minimal installs
    _np = None

PS_PER_US = 1_000_000

# below this many samples the numpy round-trip costs more than it saves
_COLUMNAR_MIN_SAMPLES = 64


# ---------------------------------------------------------------------------
# Fig. 6 analogue: where did the time go, per component?
# ---------------------------------------------------------------------------


def component_breakdown(trace: Trace, leaf_only: bool = True) -> Dict[str, float]:
    """Map component -> µs of span time in this trace.

    With ``leaf_only`` (default), a span only contributes the parts of its
    duration not covered by its children, and a component's total is the
    *merged union* of those leaf intervals — overlapping sibling spans
    (async collectives, queued link transfers) count their overlap once, so
    each component's number is the wall-clock time it was busy instead of a
    double-counted sum.
    """
    if not leaf_only:
        out: Dict[str, float] = defaultdict(float)
        for s in trace.spans:
            out[f"{s.sim_type}:{s.component}"] += s.duration / PS_PER_US
        return dict(out)
    children: Dict[int, List[Span]] = defaultdict(list)
    for s in trace.spans:
        if s.parent is not None:
            children[s.parent.span_id].append(s)
    leaf_ivals: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
    for s in trace.spans:
        kids = children.get(s.context.span_id)
        if kids:
            covered = _merge_ivals([(c.start, c.end) for c in kids], s.start, s.end)
            leaf_ivals[f"{s.sim_type}:{s.component}"].extend(
                _subtract_ivals((s.start, s.end), covered)
            )
        else:
            leaf_ivals[f"{s.sim_type}:{s.component}"].append((s.start, s.end))
    return {
        comp: sum(b - a for a, b in _merge_ivals(ivals)) / PS_PER_US
        for comp, ivals in leaf_ivals.items()
    }


def span_name_breakdown(trace: Trace) -> Dict[str, float]:
    """Map span name -> summed µs of span time in this trace."""
    out: Dict[str, float] = defaultdict(float)
    for s in trace.spans:
        out[s.name] += s.duration / PS_PER_US
    return dict(out)


def _merge_ivals(
    ivals: Iterable[Tuple[int, int]],
    lo: Optional[int] = None,
    hi: Optional[int] = None,
) -> List[Tuple[int, int]]:
    """Sorted, disjoint union of intervals, optionally clamped to [lo, hi]."""
    clamped = (
        (a if lo is None else max(a, lo), b if hi is None else min(b, hi))
        for a, b in ivals
    )
    merged: List[Tuple[int, int]] = []
    for a, b in sorted(clamped):
        if b <= a:
            continue
        if merged and a <= merged[-1][1]:
            if b > merged[-1][1]:
                merged[-1] = (merged[-1][0], b)
        else:
            merged.append((a, b))
    return merged


def _subtract_ivals(
    span: Tuple[int, int], covered: List[Tuple[int, int]]
) -> List[Tuple[int, int]]:
    """Parts of ``span`` not covered by the merged intervals ``covered``."""
    out: List[Tuple[int, int]] = []
    cur = span[0]
    for a, b in covered:
        if a > cur:
            out.append((cur, min(a, span[1])))
        cur = max(cur, b)
        if cur >= span[1]:
            break
    if cur < span[1]:
        out.append((cur, span[1]))
    return out


# ---------------------------------------------------------------------------
# Critical path
# ---------------------------------------------------------------------------


def critical_path(trace: Trace) -> List[Span]:
    """Longest chain of child spans ending at the latest-finishing leaf.

    Walks from each root to the descendant that determines its end time.
    """
    children: Dict[int, List[Span]] = defaultdict(list)
    for s in trace.spans:
        if s.parent is not None:
            children[s.parent.span_id].append(s)

    path: List[Span] = []
    roots = trace.roots()
    if not roots:
        return path
    cur: Optional[Span] = max(roots, key=lambda s: s.end)
    seen = set()
    while cur is not None and cur.context.span_id not in seen:
        seen.add(cur.context.span_id)
        path.append(cur)
        kids = children.get(cur.context.span_id, [])
        # the child on the critical path is the one finishing last
        cur = max(kids, key=lambda s: s.end) if kids else None
    return path


# ---------------------------------------------------------------------------
# Clock analysis (Fig. 4 / Fig. 5)
# ---------------------------------------------------------------------------


def clock_offset_series(spans: Iterable[Span], host_a: str, host_b: str) -> List[Tuple[float, float]]:
    """Measured host_a - host_b system-clock difference over global time.

    clock_read events carry ``local`` (the host's system clock, ps) and are
    timestamped with the simulation's ground-truth global clock; the sim's
    global clock plays the paper's "true and precise global clock" role.
    Returns [(global_time_us, offset_us)].
    """
    reads: Dict[str, List[Tuple[int, int]]] = {host_a: [], host_b: []}
    for s in spans:
        if s.sim_type != "host" or s.component not in reads:
            continue
        for ts, name, attrs in s.events:
            if name == "clock_read" and "local" in attrs:
                reads[s.component].append((ts, int(attrs["local"])))
    for v in reads.values():
        v.sort()
    out: List[Tuple[float, float]] = []
    bi = 0
    b = reads[host_b]
    for ts, local_a in reads[host_a]:
        # nearest host_b read at (or before) the same global instant
        while bi + 1 < len(b) and b[bi + 1][0] <= ts:
            bi += 1
        if not b:
            break
        ts_b, local_b = b[bi]
        # correct for the sampling-instant difference using the global clock
        offset = (local_a - ts) - (local_b - ts_b)
        out.append((ts / PS_PER_US, offset / PS_PER_US))
    return out


def ntp_estimated_offsets(spans: Iterable[Span], host: str) -> List[Tuple[float, float]]:
    """Chrony-style estimated offsets from NtpSync spans: ((t2-t1)+(t3-t4))/2."""
    out = []
    for s in spans:
        if s.name == "NtpSync" and s.component == host:
            a = s.attrs
            if all(k in a for k in ("t1", "t2", "t3", "t4")):
                off = ((a["t2"] - a["t1"]) + (a["t3"] - a["t4"])) / 2
                out.append((s.start / PS_PER_US, off / PS_PER_US))
    out.sort()
    return out


def ntp_path_asymmetry(spans: Iterable[Span], host: str) -> List[Tuple[float, float, float]]:
    """(t_us, req_us, resp_us) one-way delays per NTP exchange — the quantity
    whose asymmetry under background traffic explains Fig. 4/6."""
    out = []
    for s in spans:
        if s.name == "NtpSync" and s.component == host:
            a = s.attrs
            if all(k in a for k in ("t1", "t2", "t3", "t4", "true_off")):
                # with ground truth offset we can compute true one-way delays
                req = (a["t2"] - a["true_off"]) - a["t1"]
                resp = a["t4"] - (a["t3"] - a["true_off"])
                out.append((s.start / PS_PER_US, req / PS_PER_US, resp / PS_PER_US))
    out.sort()
    return out


# ---------------------------------------------------------------------------
# Straggler / fault diagnostics (framework telemetry on top of Columbo)
# ---------------------------------------------------------------------------


def straggler_report(
    spans: Iterable[Span],
    span_name: str = "DeviceProgram",
    k: float = 4.0,
) -> Dict[str, Any]:
    """Flag components whose span durations are > median + k * MAD.

    Degenerate samples are guarded the same way as :func:`_mad_outliers`:
    fewer than 3 components, or a non-positive median (so the 1%-of-median
    MAD fallback would collapse to ~0 and flag everything), yield an empty
    straggler list instead of a division-by-zero or an
    everything-is-an-outlier verdict on tiny topologies.
    """
    durs: Dict[str, List[int]] = defaultdict(list)
    for s in spans:
        if s.name == span_name:
            durs[s.component].append(s.duration)
    if not durs:
        return {"stragglers": [], "median_us": 0.0, "per_component_us": {}}
    per_comp = {c: _median(v) / PS_PER_US for c, v in durs.items()}
    med = statistics.median(per_comp.values())
    if len(per_comp) < 3 or med <= 0:
        return {"stragglers": [], "median_us": med, "per_component_us": per_comp}
    mad = statistics.median(abs(v - med) for v in per_comp.values()) or max(med * 0.01, 1e-9)
    stragglers = sorted(
        (c for c, v in per_comp.items() if v > med + k * mad),
        key=lambda c: -per_comp[c],
    )
    return {"stragglers": stragglers, "median_us": med, "per_component_us": per_comp}


def trace_summary(spans: Sequence[Span]) -> Dict[str, Any]:
    """Shape-of-the-weave counters (spans, traces, links, parents)."""
    traces = assemble_traces(spans)
    return {
        "n_spans": len(spans),
        "n_traces": len(traces),
        "span_types": sorted({s.name for s in spans}),
        "components": sorted({f"{s.sim_type}:{s.component}" for s in spans}),
        "linked_spans": sum(1 for s in spans if s.links),
        "parented_spans": sum(1 for s in spans if s.parent is not None),
    }


# ---------------------------------------------------------------------------
# Per-request analysis (RPC serving workload): latency tails + drill-down
# ---------------------------------------------------------------------------
#
# The RPC workload (sim/workloads/rpc.py) weaves one span tree per request,
# rooted at an ``RpcRequest`` span carrying the request's trace-context id
# (``rid``).  These helpers turn that into the serving questions: what are
# the latency percentiles, which request was slowest, and what does *its*
# trace alone say went wrong — the per-request reading aggregate dashboards
# cannot give (the paper's §1 motivation).


def rpc_requests(spans: Iterable[Span]) -> List[Span]:
    """All per-request root spans (``RpcRequest``), slowest first."""
    return sorted(
        (s for s in spans if s.name == "RpcRequest"), key=lambda s: -s.duration
    )


def _request_outcome(s: Span) -> str:
    """A request span's terminal outcome.  Legacy fan-out runs carry no
    ``outcome`` attribute — every request completed."""
    return str(s.attrs.get("outcome", "completed"))


def completed_requests(spans: Iterable[Span]) -> List[Span]:
    """The ``RpcRequest`` roots that actually completed (drops/timeouts
    excluded), slowest first — the latency-CDF population."""
    return [s for s in rpc_requests(spans) if _request_outcome(s) == "completed"]


def request_latency_stats(spans: Iterable[Span]) -> Dict[str, float]:
    """End-to-end request latency percentiles in µs (p50/p90/p99/p99.9/max
    over **completed** ``RpcRequest`` span durations; zeros when the trace
    has no completed requests — a saturated all-dropped run yields the
    well-formed empty report, never a raise).  p99.9 is the mitigation
    scoreboard's headline metric — loss/stall faults live in the extreme
    tail."""
    lats = [s.duration / PS_PER_US for s in spans
            if s.name == "RpcRequest" and _request_outcome(s) == "completed"]
    if not lats:
        return {"n": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0, "p99.9": 0.0,
                "max": 0.0}
    p50, p90, p99, p999 = percentiles(lats, (50, 90, 99, 99.9))
    return {"n": float(len(lats)), "p50": p50, "p90": p90, "p99": p99,
            "p99.9": p999, "max": max(lats)}


def request_outcomes(spans: Iterable[Span]) -> Dict[str, Any]:
    """Request-outcome accounting over the ``RpcRequest`` roots.

    Returns the conservation counters (``issued == completed + dropped +
    timed_out`` — exact by construction, asserted in
    ``tests/test_serving_saturation.py``), the retried-request count and
    total attempts, goodput (completed / issued), and per-LB-policy
    completed-latency percentiles incl. p99.9 (``latency_us``, keyed by
    the root's ``lb`` attribute; legacy fan-out runs group under
    ``"fanout"``).  Zero-request and zero-completed populations return a
    well-formed report with zeroed stats."""
    reqs = rpc_requests(spans)
    counts = {"issued": len(reqs), "completed": 0, "dropped": 0,
              "timed_out": 0}
    retried = 0
    attempts = 0
    by_policy: Dict[str, List[float]] = {}
    for s in reqs:
        outcome = _request_outcome(s)
        counts[outcome] = counts.get(outcome, 0) + 1
        a = int(s.attrs.get("attempts", 1))
        attempts += a
        if a > 1:
            retried += 1
        if outcome == "completed":
            policy = str(s.attrs.get("lb", "fanout"))
            by_policy.setdefault(policy, []).append(s.duration / PS_PER_US)
    latency: Dict[str, Dict[str, float]] = {}
    for policy in sorted(by_policy):
        lats = by_policy[policy]
        p50, p99, p999 = percentiles(lats, (50, 99, 99.9))
        latency[policy] = {"n": float(len(lats)), "p50": p50, "p99": p99,
                           "p99.9": p999, "max": max(lats)}
    goodput = counts["completed"] / counts["issued"] if reqs else 0.0
    return {**counts, "retried": retried, "attempts": attempts,
            "goodput": goodput, "latency_us": latency}


def slowest_request(spans: Sequence[Span]) -> Optional[Trace]:
    """The slowest *completed* request's entire span tree (host + device +
    net); falls back to the slowest request of any outcome, or ``None``
    when no ``RpcRequest`` span exists."""
    reqs = completed_requests(spans) or rpc_requests(spans)
    if not reqs:
        return None
    return assemble_traces(spans).get(reqs[0].context.trace_id)


def request_report(spans: Sequence[Span], k: float = 4.0) -> str:
    """Tail-latency drill-down: outcome accounting, percentiles, the
    slowest request's critical path, and :func:`diagnose` run on that
    request's trace **alone** — the per-request attribution the RPC
    quickstart prints."""
    outcomes = request_outcomes(spans)
    if not outcomes["issued"]:
        return "no RpcRequest spans (not an RPC-serving trace)"
    stats = request_latency_stats(spans)
    lines = []
    if outcomes["issued"] != outcomes["completed"]:
        lines.append(
            f"outcomes: issued={outcomes['issued']}  "
            f"completed={outcomes['completed']}  "
            f"dropped={outcomes['dropped']}  "
            f"timed_out={outcomes['timed_out']}  "
            f"retried={outcomes['retried']}  "
            f"goodput={outcomes['goodput']:.3f}"
        )
    for policy, rl in outcomes["latency_us"].items():
        if policy != "fanout":
            lines.append(
                f"lb={policy}: n={rl['n']:.0f}  p50={rl['p50']:.0f}us  "
                f"p99={rl['p99']:.0f}us  p99.9={rl['p99.9']:.0f}us"
            )
    if not stats["n"]:
        lines.append("no completed requests (all dropped or timed out)")
        return "\n".join(lines)
    lines.append(
        f"requests: n={stats['n']:.0f}  p50={stats['p50']:.0f}us  "
        f"p90={stats['p90']:.0f}us  p99={stats['p99']:.0f}us  "
        f"p99.9={stats['p99.9']:.0f}us  max={stats['max']:.0f}us",
    )
    trace = slowest_request(spans)
    if trace is not None:
        root = rpc_requests(trace.spans)[0]
        lines.append(
            f"slowest request {root.attrs.get('rid')!r}: "
            f"{root.duration / PS_PER_US:.0f}us critical path:"
        )
        for s in critical_path(trace):
            lines.append(
                f"    {s.name:14s} [{s.sim_type}:{s.component}] "
                f"{s.duration / PS_PER_US:.1f}us"
            )
        per_request = diagnose(trace.spans, k=k)
        if per_request.findings:
            lines.append("diagnose() on the slowest request's trace alone:")
            for f in per_request.findings:
                lines.append(f"    {f}")
        else:
            lines.append("diagnose() on the slowest request's trace: clean")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# diagnose(): attribute trace anomalies to fault classes
# ---------------------------------------------------------------------------
#
# The detection half of the fault-injection loop (sim/faults.py is the
# injection half).  Every rule works purely from the woven spans — no access
# to the injected ground truth — and emits findings tagged with the same
# fault-class names the faults carry, so a scenario can assert the
# round-trip: inject F, weave, diagnose, find F's class.


@dataclass
class Finding:
    """One attributed anomaly: a fault class pinned to a component."""

    fault_class: str          # one of sim.faults.FAULT_CLASSES
    component: str            # "ici.pod0.l1", "pod1.chip02", "host0", ...
    rule: str                 # which detector fired
    severity: float           # rule-specific magnitude; bigger = worse
    evidence: Dict[str, Any] = field(default_factory=dict)

    def __str__(self) -> str:
        ev = ", ".join(f"{k}={v}" for k, v in self.evidence.items())
        return (
            f"[{self.fault_class}] {self.component} (rule={self.rule}, "
            f"severity={self.severity:.2f}{'; ' + ev if ev else ''})"
        )


@dataclass
class Diagnosis:
    """diagnose() output: ranked findings + trace-level context."""

    findings: List[Finding] = field(default_factory=list)
    critical_paths: Dict[int, str] = field(default_factory=dict)  # trace -> top component

    @property
    def fault_classes(self) -> List[str]:
        out: List[str] = []
        for f in self.findings:
            if f.fault_class not in out:
                out.append(f.fault_class)
        return out

    def __contains__(self, fault_class: str) -> bool:
        return fault_class in self.fault_classes

    def summary(self) -> str:
        if not self.findings:
            return "no anomalies attributed (healthy trace)"
        return "\n".join(str(f) for f in self.findings)


def diagnose(
    spans: Sequence[Span],
    k: float = 4.0,
    clock_threshold_us: float = 1.0,
    reorder_min_samples: int = 8,
    reorder_min_fraction: float = 0.05,
) -> Diagnosis:
    """Attribute anomalies in a woven trace set back to fault classes.

    Rules (each independent, all trace-derived):

    * **device stragglers** — per-chip k-MAD outliers over ``Op`` span
      durations -> ``device_slowdown``; a pod whose chips are uniformly
      slow (pod-level k-MAD, >= 3 pods) -> ``straggler_pod``.
    * **link service time** — per-link median wire time per byte (measured
      from the ``wire_tx`` span event to span end, i.e. excluding queueing),
      k-MAD outliers within a link family (ici/dcn/pcie/eth) ->
      ``link_degradation``.
    * **drops** — ``chunk_drop`` span events on a link -> ``link_loss``.
    * **arrival inversions** — a link whose transfers complete out of
      enqueue order (impossible on a healthy FIFO link) -> ``link_reorder``.
    * **host stalls** — ``gc_stall`` span events -> ``host_pause``.
    * **clock excursions** — host clock_read offsets vs the simulation's
      ground-truth global clock exceed ``clock_threshold_us`` ->
      ``clock_fault`` (classified step vs drift).

    Critical-path context: for each step trace, the component owning the
    largest share of the critical path is recorded in
    ``Diagnosis.critical_paths``; findings on a component that also
    dominates a critical path get their evidence annotated (the
    "critical-path shift" signal).
    """
    d = Diagnosis()
    d.findings.extend(_diagnose_device(spans, k))
    d.findings.extend(_diagnose_links(spans, k, reorder_min_samples, reorder_min_fraction))
    d.findings.extend(_diagnose_host_stalls(spans))
    d.findings.extend(_diagnose_clocks(spans, clock_threshold_us))
    d.critical_paths = _critical_path_components(spans)
    cp_components = set(d.critical_paths.values())
    for f in d.findings:
        for comp in cp_components:
            if f.component in comp:
                f.evidence["on_critical_path"] = comp
    d.findings.sort(key=lambda f: -f.severity)
    return d


def _mad_outliers(
    per_key: Dict[str, float], k: float, min_keys: int = 3
) -> List[Tuple[str, float, float]]:
    """(key, value, median) for values > median + k * MAD.  MAD degenerates
    to 1% of the median when all values agree, so identical-by-construction
    healthy populations never flag.

    Guards against degenerate samples: fewer than ``min_keys`` members
    (median/MAD of 1–2 values can only say "they differ", not which one is
    anomalous), and a non-positive median (the 1%-of-median MAD fallback
    would collapse to ~0, flag every positive value, and later divide
    severities by zero)."""
    if len(per_key) < min_keys:
        return []
    med = statistics.median(per_key.values())
    if med <= 0:
        return []
    mad = statistics.median(abs(v - med) for v in per_key.values()) or max(med * 0.01, 1e-9)
    return sorted(
        ((c, v, med) for c, v in per_key.items() if v > med + k * mad),
        key=lambda t: -t[1],
    )


def _diagnose_device(spans: Sequence[Span], k: float) -> List[Finding]:
    durs: Dict[str, List[int]] = defaultdict(list)
    for s in spans:
        if s.name == "Op":
            durs[s.component].append(s.duration)
    if not durs:
        return []
    per_chip = {c: _median(v) / PS_PER_US for c, v in durs.items()}
    findings = [
        Finding(
            "device_slowdown", chip, "op_kmad", v / med,
            {"median_op_us": round(v, 1), "fleet_median_us": round(med, 1)},
        )
        for chip, v, med in _mad_outliers(per_chip, k)
    ]
    # pod-level: median of each pod's chip medians ("pod1.chip02" -> "pod1")
    pods: Dict[str, List[float]] = defaultdict(list)
    for chip, v in per_chip.items():
        if "." in chip:
            pods[chip.split(".", 1)[0]].append(v)
    per_pod = {p: statistics.median(v) for p, v in pods.items()}
    for pod, v, med in _mad_outliers(per_pod, k):
        findings.append(
            Finding(
                "straggler_pod", pod, "pod_kmad", v / med,
                {"pod_median_op_us": round(v, 1), "fleet_median_us": round(med, 1),
                 "chips": sum(1 for c in per_chip if c.startswith(pod + "."))},
            )
        )
    return findings


def _link_family(link: str) -> str:
    return link.split(".", 1)[0]


def _diagnose_links(
    spans: Sequence[Span], k: float, reorder_min_samples: int, reorder_min_fraction: float
) -> List[Finding]:
    findings: List[Finding] = []
    per_link: Dict[str, List[Span]] = defaultdict(list)
    for s in spans:
        if s.name == "LinkTransfer":
            per_link[s.component].append(s)

    # -- service time per byte (k-MAD within a link family) -------------------
    per_byte: Dict[str, Dict[str, float]] = defaultdict(dict)   # family -> link -> med
    for link, ss in per_link.items():
        samples = []
        for s in ss:
            size = s.attrs.get("size")
            if not isinstance(size, int) or size < 4096:
                continue
            wire_start = next((ts for ts, n, _ in s.events if n == "wire_tx"), s.start)
            wire_ps = s.end - wire_start
            if wire_ps > 0:
                samples.append(wire_ps / size)
        if samples:
            per_byte[_link_family(link)][link] = _median(samples)
    for family, links in per_byte.items():
        for link, v, med in _mad_outliers(links, k):
            findings.append(
                Finding(
                    "link_degradation", link, "wire_time_kmad", v / med,
                    {"ps_per_byte": round(v, 3), "family_median": round(med, 3),
                     "family": family},
                )
            )

    # -- drops -> loss ---------------------------------------------------------
    for link, ss in per_link.items():
        n_drops = sum(int(s.attrs.get("drops", 0)) for s in ss)
        if n_drops:
            findings.append(
                Finding(
                    "link_loss", link, "chunk_drops", n_drops / len(ss),
                    {"drops": n_drops, "transfers": len(ss)},
                )
            )

    # -- arrival inversions -> reordering -------------------------------------
    for link, ss in per_link.items():
        ordered = sorted(ss, key=lambda s: (s.start, s.context.span_id))
        if len(ordered) < reorder_min_samples:
            continue
        inversions = sum(
            1
            for a, b in zip(ordered, ordered[1:])
            if a.start < b.start and b.end < a.end
        )
        frac = inversions / (len(ordered) - 1)
        if frac >= reorder_min_fraction:
            findings.append(
                Finding(
                    "link_reorder", link, "arrival_inversions", frac,
                    {"inversions": inversions, "transfers": len(ordered)},
                )
            )
    return findings


def _diagnose_host_stalls(spans: Sequence[Span]) -> List[Finding]:
    stalls: Dict[str, List[Tuple[int, Dict[str, Any]]]] = defaultdict(list)
    for s in spans:
        if s.sim_type != "host":
            continue
        for ts, name, attrs in s.events:
            if name == "gc_stall":
                stalls[s.component].append((ts, attrs))
    return [
        Finding(
            "host_pause", host, "gc_stall_events",
            sum(int(a.get("dur", 0)) for _, a in evs) / PS_PER_US,
            {"stalls": len(evs),
             "total_stall_us": round(sum(int(a.get("dur", 0)) for _, a in evs) / PS_PER_US, 1),
             "causes": sorted({str(a.get("cause", "?")) for _, a in evs})},
        )
        for host, evs in stalls.items()
    ]


def _diagnose_clocks(spans: Sequence[Span], threshold_us: float) -> List[Finding]:
    reads: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
    for s in spans:
        if s.sim_type != "host":
            continue
        for ts, name, attrs in s.events:
            if name == "clock_read" and "local" in attrs:
                reads[s.component].append((ts, int(attrs["local"])))
    findings = []
    for host, rr in sorted(reads.items()):
        rr.sort()
        offsets = [(ts, (local - ts) / PS_PER_US) for ts, local in rr]
        max_abs = max((abs(o) for _, o in offsets), default=0.0)
        if max_abs < threshold_us or len(offsets) < 2:
            continue
        jumps = [abs(b[1] - a[1]) for a, b in zip(offsets, offsets[1:])]
        span_ps = offsets[-1][0] - offsets[0][0]
        # ppm = (delta offset ps) / (elapsed ps) * 1e6
        slope_ppm = (
            (offsets[-1][1] - offsets[0][1]) * PS_PER_US / span_ps * 1e6 if span_ps else 0.0
        )
        kind = "step" if max(jumps) > 0.5 * max_abs else "drift"
        findings.append(
            Finding(
                "clock_fault", host, f"clock_{kind}", max_abs,
                {"max_offset_us": round(max_abs, 2), "slope_ppm": round(slope_ppm, 1),
                 "kind": kind},
            )
        )
    return findings


def _critical_path_components(spans: Sequence[Span]) -> Dict[int, str]:
    """trace_id -> 'sim_type:component' owning the largest critical-path
    share, for step traces (the paper's critical-path-shift signal)."""
    out: Dict[int, str] = {}
    for tid, trace in assemble_traces(spans).items():
        if not any(s.name == "HostStep" for s in trace.spans):
            continue
        share: Dict[str, int] = defaultdict(int)
        for s in critical_path(trace):
            share[f"{s.sim_type}:{s.component}"] += s.duration
        if share:
            out[tid] = max(share, key=share.get)
    return out


# ---------------------------------------------------------------------------
# aggregate(): fleet-level statistics over many runs (the sweep's analysis)
# ---------------------------------------------------------------------------
#
# A single trace answers "what happened in this run"; a sweep answers "how
# does the fleet behave across scenarios and seeds" (the aggregate-driven
# view of Anand et al.).  Each sweep cell pre-reduces its spans into a
# small, JSON-serializable RunStats; aggregate() merges any number of them
# into per-component latency percentiles, per-fault-class detection /
# false-positive rates, and critical-path frequency tables.


def percentile(samples: Sequence[float], q: float) -> float:
    """Deterministic linear-interpolation percentile (``q`` in [0, 100])."""
    return percentiles(samples, (q,))[0]


def percentiles(samples: Sequence[float], qs: Sequence[float]) -> List[float]:
    """Several linear-interpolation percentiles from **one** sort.

    The columnar path of the sweep analytics: large sample pools sort once
    in numpy (when available) and every requested ``q`` interpolates off
    the sorted array.  The interpolation arithmetic is the exact IEEE-754
    expression of the pure-python fallback, so both backends return
    bit-identical floats — aggregate reports do not depend on whether
    numpy is installed (asserted in ``tests/test_structured.py``).
    """
    n = len(samples)
    if n == 0:
        return [0.0 for _ in qs]
    if _np is not None and n >= _COLUMNAR_MIN_SAMPLES:
        s = _np.sort(_np.asarray(samples, dtype=_np.float64))
        out = []
        for q in qs:
            pos = (n - 1) * q / 100.0
            lo = int(pos)
            hi = min(lo + 1, n - 1)
            out.append(float(s[lo] + (s[hi] - s[lo]) * (pos - lo)))
        return out
    s = sorted(samples)
    out = []
    for q in qs:
        pos = (n - 1) * q / 100.0
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        out.append(s[lo] + (s[hi] - s[lo]) * (pos - lo))
    return out


def _median(values: Sequence[float]) -> float:
    """Median with a columnar (numpy) path for large samples.

    numpy's even-count mean-of-middles matches ``statistics.median``'s
    ``(a + b) / 2`` bit for bit in float64, so the backends agree exactly;
    int inputs come back as floats either way once divided by ``PS_PER_US``
    at every call site."""
    if _np is not None and len(values) >= _COLUMNAR_MIN_SAMPLES:
        return float(_np.median(_np.asarray(values, dtype=_np.float64)))
    return statistics.median(values)


class SpanColumns:
    """Struct-of-arrays view of finished spans — the columnar record
    format at the weaver/analysis boundary.

    One pass over the span objects encodes the reduction-relevant fields
    into parallel arrays (int64 durations, small-int codes for names and
    ``sim_type:component`` keys), after which :meth:`RunStats.from_columns`
    computes the per-component latency pools with numpy instead of a
    python loop per span.  Mitigation spans are rare and carry free-form
    ``penalty`` attrs, so their durations/penalties stay as plain lists.

    Falls back to plain python lists when numpy is unavailable — the
    reduction then matches :meth:`RunStats.from_spans` arithmetic exactly
    either way (int -> float64 conversion is exact below 2**53 ps and
    division by ``PS_PER_US`` rounds identically)."""

    __slots__ = ("n_spans", "dur_ps", "key_codes", "keys",
                 "request_idx", "mitigation_us", "mitigation_penalty")

    def __init__(self, spans: Sequence[Span]) -> None:
        n = len(spans)
        self.n_spans = n
        key_of: Dict[Tuple[str, str], int] = {}
        keys: List[str] = []
        dur = [0] * n
        codes = [0] * n
        request_idx: List[int] = []
        self.mitigation_us: List[float] = []
        self.mitigation_penalty = 0.0
        for i, s in enumerate(spans):
            dur[i] = s.end - s.start
            k = (s.sim_type, s.component)
            c = key_of.get(k)
            if c is None:
                c = key_of[k] = len(keys)
                keys.append(f"{s.sim_type}:{s.component}")
            codes[i] = c
            name = s.name
            if name == "RpcRequest":
                request_idx.append(i)
            elif name == "Mitigation":
                d = dur[i]
                self.mitigation_us.append((d if d > 1 else 1) / PS_PER_US)
                try:
                    self.mitigation_penalty += float(s.attrs.get("penalty", 0.0))
                except (TypeError, ValueError):
                    pass
        self.keys = keys
        if _np is not None:
            self.dur_ps = _np.asarray(dur, dtype=_np.int64)
            self.key_codes = _np.asarray(codes, dtype=_np.int64)
            self.request_idx = _np.asarray(request_idx, dtype=_np.int64)
        else:  # pragma: no cover - minimal installs
            self.dur_ps = dur
            self.key_codes = codes
            self.request_idx = request_idx

    @classmethod
    def from_parts(cls, n_spans, dur_ps, key_codes, keys,
                   request_idx, mitigation_us, mitigation_penalty) -> "SpanColumns":
        """Assemble from precomputed arrays (no span loop).  The caller
        owns the invariants the span-loop constructor guarantees: codes
        numbered by first occurrence in span order, durations in ps,
        request indices ascending."""
        self = cls.__new__(cls)
        self.n_spans = n_spans
        self.dur_ps = dur_ps
        self.key_codes = key_codes
        self.keys = keys
        self.request_idx = request_idx
        self.mitigation_us = mitigation_us
        self.mitigation_penalty = mitigation_penalty
        return self

    @classmethod
    def from_woven(cls, woven) -> "SpanColumns":
        """Columnar-to-columnar build from a finished
        ``streaming.WovenColumns`` — bit-identical to
        ``SpanColumns(woven.to_spans())`` without materializing the net
        spans.  Durations and component codes for the net rows come
        straight from the emit-time builder arrays; the object-path spans
        (host/device) contribute through the same per-span loop the plain
        constructor runs, in the same (sorted) relative order, so the
        rare-span fields (mitigation durations, penalty float
        accumulation order, request indices) reproduce exactly."""
        nb = woven.nb
        obj = woven.obj_spans
        m = len(obj)
        n = woven.n_net
        key_of: Dict[Tuple[str, str], int] = {}
        pool: List[str] = []
        ocodes = [0] * m
        odur = [0] * m
        request_rows: List[int] = []
        mitigation_us: List[float] = []
        mitigation_penalty = 0.0
        for i, s in enumerate(obj):
            odur[i] = s.end - s.start
            k = (s.sim_type, s.component)
            c = key_of.get(k)
            if c is None:
                c = key_of[k] = len(pool)
                pool.append(f"{s.sim_type}:{s.component}")
            ocodes[i] = c
            name = s.name
            if name == "RpcRequest":
                request_rows.append(i)
            elif name == "Mitigation":
                d = odur[i]
                mitigation_us.append((d if d > 1 else 1) / PS_PER_US)
                try:
                    mitigation_penalty += float(s.attrs.get("penalty", 0.0))
                except (TypeError, ValueError):
                    pass
        off = len(pool)
        pool.extend("net:" + link for link in nb.comp_pool)
        order = woven.order
        if _np is not None:
            dur_all = _np.empty(m + n, dtype=_np.int64)
            dur_all[:m] = odur
            codes_all = _np.empty(m + n, dtype=_np.int64)
            codes_all[:m] = ocodes
            if n:
                dur_all[m:] = nb.ends
                dur_all[m:] -= _np.asarray(nb.starts, dtype=_np.int64)
                codes_all[m:] = nb.comp_codes
                codes_all[m:] += off
            order = _np.asarray(order)
            dur_all = dur_all[order]
            codes_all = codes_all[order]
            # renumber codes by first occurrence in the merged canonical
            # order — the numbering the span-loop constructor produces
            uniq, first = _np.unique(codes_all, return_index=True)
            appearance = uniq[_np.argsort(first)]
            new_code = _np.empty(len(pool), dtype=_np.int64)
            new_code[appearance] = _np.arange(len(appearance))
            key_codes = new_code[codes_all]
            keys = [pool[c] for c in appearance.tolist()]
            pos = _np.empty(m + n, dtype=_np.int64)
            pos[order] = _np.arange(m + n)
            request_idx = pos[request_rows] if request_rows else _np.empty(0, dtype=_np.int64)
        else:  # pragma: no cover - minimal installs
            dur_cat = odur + [e - s for e, s in zip(nb.ends, nb.starts)]
            codes_cat = ocodes + [c + off for c in nb.comp_codes]
            order = list(order)
            dur_all = [dur_cat[j] for j in order]
            codes_raw = [codes_cat[j] for j in order]
            renum: Dict[int, int] = {}
            keys = []
            codes_new = [0] * len(codes_raw)
            for i, c in enumerate(codes_raw):
                nc = renum.get(c)
                if nc is None:
                    nc = renum[c] = len(keys)
                    keys.append(pool[c])
                codes_new[i] = nc
            key_codes = codes_new
            pos = [0] * (m + n)
            for p, j in enumerate(order):
                pos[j] = p
            request_idx = [pos[r] for r in request_rows]
        return cls.from_parts(m + n, dur_all, key_codes, keys,
                              request_idx, mitigation_us, mitigation_penalty)

    def component_us(self) -> Dict[str, List[float]]:
        """Per-``sim_type:component`` duration pools (µs, 1 ps floor), each
        pool in span order — exactly :meth:`RunStats.from_spans`'s dict."""
        keys = self.keys
        if _np is None or self.n_spans < _COLUMNAR_MIN_SAMPLES:  # pragma: no cover
            out: Dict[str, List[float]] = {k: [] for k in keys}
            for c, d in zip(self.key_codes, self.dur_ps):
                out[keys[c]].append((d if d > 1 else 1) / PS_PER_US)
            return {k: v for k, v in out.items() if v}
        us = _np.maximum(self.dur_ps, 1) / PS_PER_US
        order = _np.argsort(self.key_codes, kind="stable")
        sorted_codes = self.key_codes[order]
        bounds = _np.searchsorted(sorted_codes, _np.arange(len(keys) + 1))
        out = {}
        for c, k in enumerate(keys):
            lo, hi = bounds[c], bounds[c + 1]
            if hi > lo:
                out[k] = us[order[lo:hi]].tolist()
        return out

    def request_us(self) -> List[float]:
        """RpcRequest latency pool (µs, 1 ps floor), in span order."""
        if _np is None or not len(self.request_idx):  # pragma: no cover
            return [
                (self.dur_ps[i] if self.dur_ps[i] > 1 else 1) / PS_PER_US
                for i in self.request_idx
            ]
        return (_np.maximum(self.dur_ps[self.request_idx], 1) / PS_PER_US).tolist()


@dataclass
class RunStats:
    """One run's pre-reduced statistics — the unit :func:`aggregate` merges.

    Built in-process from woven spans (:meth:`from_spans`, what sweep
    workers do) or offline from a SpanJSONL shard (:meth:`from_jsonl`,
    re-aggregating archived sweeps); both paths are deterministic and
    JSON-round-trippable (:meth:`to_dict` / :meth:`from_dict`).
    """

    scenario: str
    seed: int
    expected: Tuple[str, ...] = ()     # injected fault classes (ground truth)
    detected: Tuple[str, ...] = ()     # fault classes diagnose() reported
    wall_s: float = 0.0                # host wall-clock spent simulating+weaving
    events: int = 0                    # DES events the kernel executed
    n_spans: int = 0
    component_us: Dict[str, List[float]] = field(default_factory=dict)
    critical_components: List[str] = field(default_factory=list)
    request_us: List[float] = field(default_factory=list)   # RpcRequest latencies
    mitigation: str = ""               # policy name ("" = unmitigated/baseline)
    mitigation_us: List[float] = field(default_factory=list)  # trigger->done (µs)
    capacity_penalty: float = 0.0      # summed penalty attrs of Mitigation spans
    magnitude: float = 1.0             # fault-magnitude axis value for this cell
    # ground truth / diagnosis at component granularity, keyed by fault
    # class — what the evaluation harness scores component naming against
    expected_components: Dict[str, List[str]] = field(default_factory=dict)
    finding_components: Dict[str, List[str]] = field(default_factory=dict)
    diag_wall_s: float = 0.0           # wall time spent inside diagnose()
    late_events: int = 0               # events dropped after their span closed

    @property
    def ok(self) -> bool:
        """Detection verdict: every injected class diagnosed (clean runs
        must diagnose nothing)."""
        if not self.expected:
            return not self.detected
        return set(self.expected) <= set(self.detected)

    @classmethod
    def from_spans(
        cls,
        spans: Sequence[Span],
        scenario: str = "",
        seed: int = 0,
        expected: Sequence[str] = (),
        detected: Optional[Sequence[str]] = None,
        wall_s: float = 0.0,
        events: int = 0,
        mitigation: str = "",
        findings: Optional[Sequence[Finding]] = None,
        expected_components: Optional[Dict[str, Sequence[str]]] = None,
        diag_wall_s: float = 0.0,
        magnitude: float = 1.0,
        late_events: int = 0,
    ) -> "RunStats":
        """Reduce woven spans (``detected=None`` runs :func:`diagnose`)."""
        if detected is None:
            d = diagnose(spans)
            detected = d.fault_classes
            if findings is None:
                findings = d.findings
        finding_components: Dict[str, List[str]] = {}
        for f in findings or ():
            comps = finding_components.setdefault(f.fault_class, [])
            if f.component not in comps:
                comps.append(f.component)
        comp: Dict[str, List[float]] = defaultdict(list)
        request_us: List[float] = []
        mitigation_us: List[float] = []
        capacity_penalty = 0.0
        # pause the cyclic GC for the reduction (EventKernel.run rationale:
        # the loop allocates floats/lists but no cycles, while gen-2 passes
        # re-walk the entire span graph; at 256 pods that halved this stage)
        paused = gc.isenabled()
        if paused:
            gc.disable()
        try:
            for s in spans:
                # 1 ps floor matches what SpanJSONLExporter publishes: stats
                # built from live spans and from shard files agree exactly
                us = max(s.duration, 1) / PS_PER_US
                comp[f"{s.sim_type}:{s.component}"].append(us)
                if s.name == "RpcRequest":
                    request_us.append(us)
                elif s.name == "Mitigation":
                    # trigger->done = the policy's detection-to-mitigation
                    # latency; its penalty attr is the capacity it gave up
                    mitigation_us.append(us)
                    try:
                        capacity_penalty += float(s.attrs.get("penalty", 0.0))
                    except (TypeError, ValueError):
                        pass
        finally:
            if paused:
                gc.enable()
        return cls(
            scenario=scenario,
            seed=seed,
            expected=tuple(expected),
            detected=tuple(detected),
            wall_s=wall_s,
            events=events,
            n_spans=len(spans),
            component_us=dict(comp),
            critical_components=list(_critical_path_components(spans).values()),
            request_us=request_us,
            mitigation=mitigation,
            mitigation_us=mitigation_us,
            capacity_penalty=capacity_penalty,
            magnitude=magnitude,
            expected_components={
                k: list(v) for k, v in (expected_components or {}).items()
            },
            finding_components=finding_components,
            diag_wall_s=diag_wall_s,
            late_events=late_events,
        )

    @classmethod
    def from_columns(
        cls,
        cols: "SpanColumns",
        spans: Optional[Sequence[Span]] = None,
        scenario: str = "",
        seed: int = 0,
        expected: Sequence[str] = (),
        detected: Optional[Sequence[str]] = None,
        wall_s: float = 0.0,
        events: int = 0,
        mitigation: str = "",
        findings: Optional[Sequence[Finding]] = None,
        expected_components: Optional[Dict[str, Sequence[str]]] = None,
        diag_wall_s: float = 0.0,
        magnitude: float = 1.0,
        late_events: int = 0,
    ) -> "RunStats":
        """Columnar twin of :meth:`from_spans`: identical RunStats (same
        float bits, same dict ordering) computed from a
        :class:`SpanColumns` reduction instead of a per-span python loop.

        ``spans`` is only needed for the graph-walking parts — critical
        paths and (when ``detected`` is None) diagnosis; pass ``None`` to
        skip them when the caller already knows the verdicts and does not
        need critical components."""
        if detected is None:
            if spans is None:
                raise ValueError("from_columns needs spans to run diagnose(); "
                                 "pass detected= to skip diagnosis")
            d = diagnose(spans)
            detected = d.fault_classes
            if findings is None:
                findings = d.findings
        finding_components: Dict[str, List[str]] = {}
        for f in findings or ():
            comps = finding_components.setdefault(f.fault_class, [])
            if f.component not in comps:
                comps.append(f.component)
        critical = (
            list(_critical_path_components(spans).values()) if spans is not None else []
        )
        return cls(
            scenario=scenario,
            seed=seed,
            expected=tuple(expected),
            detected=tuple(detected),
            wall_s=wall_s,
            events=events,
            n_spans=cols.n_spans,
            component_us=cols.component_us(),
            critical_components=critical,
            request_us=cols.request_us(),
            mitigation=mitigation,
            mitigation_us=list(cols.mitigation_us),
            capacity_penalty=cols.mitigation_penalty,
            magnitude=magnitude,
            expected_components={
                k: list(v) for k, v in (expected_components or {}).items()
            },
            finding_components=finding_components,
            diag_wall_s=diag_wall_s,
            late_events=late_events,
        )

    @classmethod
    def from_jsonl(
        cls,
        path: str,
        scenario: str = "",
        seed: int = 0,
        expected: Sequence[str] = (),
        detected: Sequence[str] = (),
    ) -> "RunStats":
        """Reduce a SpanJSONL shard file (one JSON span per line).

        Detection verdicts are not recomputable from JSONL (diagnosis needs
        span events), so ``expected``/``detected`` come from the sweep's
        summary; latency percentiles and critical paths are recomputed from
        the records themselves.
        """
        from .exporters import iter_span_records

        records = list(iter_span_records(path))
        comp: Dict[str, List[float]] = defaultdict(list)
        request_us: List[float] = []
        for r in records:
            comp[f"{r['sim_type']}:{r['component']}"].append(float(r["duration_us"]))
            if r["name"] == "RpcRequest":
                request_us.append(float(r["duration_us"]))
        spans = _records_to_spans(records)
        return cls(
            scenario=scenario,
            seed=seed,
            expected=tuple(expected),
            detected=tuple(detected),
            n_spans=len(records),
            component_us=dict(comp),
            critical_components=list(_critical_path_components(spans).values()),
            request_us=request_us,
        )

    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable form (sweep.json cell payload)."""
        return {
            "scenario": self.scenario,
            "seed": self.seed,
            "expected": list(self.expected),
            "detected": list(self.detected),
            "wall_s": self.wall_s,
            "events": self.events,
            "n_spans": self.n_spans,
            "component_us": self.component_us,
            "critical_components": self.critical_components,
            "request_us": self.request_us,
            "mitigation": self.mitigation,
            "mitigation_us": self.mitigation_us,
            "capacity_penalty": self.capacity_penalty,
            "magnitude": self.magnitude,
            "expected_components": self.expected_components,
            "finding_components": self.finding_components,
            "diag_wall_s": self.diag_wall_s,
            "late_events": self.late_events,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunStats":
        """Inverse of :meth:`to_dict`."""
        return cls(
            scenario=d["scenario"],
            seed=int(d["seed"]),
            expected=tuple(d.get("expected", ())),
            detected=tuple(d.get("detected", ())),
            wall_s=float(d.get("wall_s", 0.0)),
            events=int(d.get("events", 0)),
            n_spans=int(d.get("n_spans", 0)),
            component_us={k: list(v) for k, v in d.get("component_us", {}).items()},
            critical_components=list(d.get("critical_components", ())),
            request_us=list(d.get("request_us", ())),
            # absent in schema-v2 sweep payloads: default = unmitigated
            mitigation=str(d.get("mitigation", "")),
            mitigation_us=list(d.get("mitigation_us", ())),
            capacity_penalty=float(d.get("capacity_penalty", 0.0)),
            # absent before schema-v4: full intensity, no component truth
            magnitude=float(d.get("magnitude", 1.0)),
            expected_components={
                k: list(v) for k, v in d.get("expected_components", {}).items()
            },
            finding_components={
                k: list(v) for k, v in d.get("finding_components", {}).items()
            },
            diag_wall_s=float(d.get("diag_wall_s", 0.0)),
            # absent before schema-v5: late events were silently dropped
            late_events=int(d.get("late_events", 0)),
        )


def _records_to_spans(records: Sequence[Dict[str, Any]]) -> List[Span]:
    """Rehydrate SpanJSONL records into lightweight :class:`Span` objects
    (times in µs rather than ps — only relative comparisons matter to the
    analyses), so record-based paths reuse the span-based walks instead of
    maintaining dict-shaped mirrors of them."""
    spans: List[Span] = []
    for r in records:
        tid = int(r["trace_id"], 16)
        parent = (
            SpanContext(trace_id=tid, span_id=int(r["parent_id"], 16))
            if r.get("parent_id")
            else None
        )
        start = float(r["start_us"])
        spans.append(
            Span(
                name=r["name"],
                start=start,
                end=start + float(r["duration_us"]),
                context=SpanContext(trace_id=tid, span_id=int(r["span_id"], 16)),
                parent=parent,
                component=r["component"],
                sim_type=r["sim_type"],
            )
        )
    return spans


@dataclass
class AggregateReport:
    """What :func:`aggregate` returns: the sweep-level rollup."""

    n_runs: int
    scenarios: List[str]
    ok_runs: int
    component_latency: Dict[str, Dict[str, float]]   # comp -> n/p50/p90/p99/max (µs)
    detection: Dict[str, Dict[str, Any]]             # fault class -> rate table
    critical_path_freq: Dict[str, Dict[str, float]]  # comp -> count/fraction
    wall_s_total: float = 0.0
    events_total: int = 0
    request_latency: Dict[str, float] = field(default_factory=dict)  # RPC rollup

    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable form (written as aggregate.json by sweeps)."""
        return {
            "n_runs": self.n_runs,
            "scenarios": self.scenarios,
            "ok_runs": self.ok_runs,
            "wall_s_total": self.wall_s_total,
            "events_total": self.events_total,
            "component_latency": self.component_latency,
            "detection": self.detection,
            "critical_path_freq": self.critical_path_freq,
            "request_latency": self.request_latency,
        }

    def report(self, top: int = 12) -> str:
        """Human-readable rollup (the sweep CLI prints this)."""
        lines = [
            f"aggregate over {self.n_runs} runs "
            f"({len(self.scenarios)} scenarios; {self.ok_runs}/{self.n_runs} diagnosed as expected; "
            f"{self.events_total} DES events, {self.wall_s_total:.2f}s wall)",
            "  per-component span latency (us), top by p99:",
            f"    {'component':30s} {'n':>6s} {'p50':>10s} {'p90':>10s} {'p99':>10s} {'max':>10s}",
        ]
        ranked = sorted(
            self.component_latency.items(), key=lambda kv: -kv[1]["p99"]
        )[:top]
        for comp, st in ranked:
            lines.append(
                f"    {comp:30s} {st['n']:6.0f} {st['p50']:10.1f} {st['p90']:10.1f} "
                f"{st['p99']:10.1f} {st['max']:10.1f}"
            )
        if self.request_latency.get("n"):
            rl = self.request_latency
            lines.append(
                f"  end-to-end request latency (us): n={rl['n']:.0f} "
                f"p50={rl['p50']:.1f} p90={rl['p90']:.1f} p99={rl['p99']:.1f} "
                f"max={rl['max']:.1f}"
            )
        if self.detection:
            lines.append("  fault-class detection (injected vs diagnosed):")
            lines.append(
                f"    {'class':18s} {'injected':>8s} {'found':>6s} {'rate':>6s} "
                f"{'clean':>6s} {'fp':>4s} {'fp_rate':>8s}"
            )
            for fc, d in sorted(self.detection.items()):
                rate = "-" if d["detection_rate"] is None else f"{d['detection_rate']:.2f}"
                fpr = "-" if d["false_positive_rate"] is None else f"{d['false_positive_rate']:.2f}"
                lines.append(
                    f"    {fc:18s} {d['injected_runs']:8d} {d['detected']:6d} {rate:>6s} "
                    f"{d['clean_runs']:6d} {d['false_positives']:4d} {fpr:>8s}"
                )
        if self.critical_path_freq:
            lines.append("  critical-path leader frequency (per step trace):")
            for comp, d in list(self.critical_path_freq.items())[:top]:
                lines.append(f"    {comp:30s} {d['count']:6.0f}  ({d['fraction']:.0%})")
        return "\n".join(lines)


def aggregate(runs: Iterable[RunStats]) -> AggregateReport:
    """Merge many runs' :class:`RunStats` into one :class:`AggregateReport`.

    * **per-component latency percentiles** — p50/p90/p99/max of span
      durations pooled across runs, keyed ``sim_type:component``;
    * **detection / false-positive rates** — for every fault class seen in
      any run's expected or detected set: the fraction of injected runs
      where it was diagnosed, and the fraction of clean runs where it was
      diagnosed anyway;
    * **critical-path frequency** — how often each component led a step
      trace's critical path, pooled across runs.
    """
    runs = list(runs)
    comp: Dict[str, List[float]] = defaultdict(list)
    for r in runs:
        for c, samples in r.component_us.items():
            comp[c].extend(samples)
    component_latency = {}
    for c, v in sorted(comp.items()):
        # one sort per component (columnar when numpy is present) instead
        # of one sort per percentile — the sweep rollup's hot loop
        p50, p90, p99 = percentiles(v, (50, 90, 99))
        component_latency[c] = {
            "n": float(len(v)), "p50": p50, "p90": p90, "p99": p99, "max": max(v),
        }
    classes = sorted({fc for r in runs for fc in (*r.expected, *r.detected)})
    detection: Dict[str, Dict[str, Any]] = {}
    for fc in classes:
        injected = [r for r in runs if fc in r.expected]
        clean = [r for r in runs if fc not in r.expected]
        hits = sum(1 for r in injected if fc in r.detected)
        fps = sum(1 for r in clean if fc in r.detected)
        detection[fc] = {
            "injected_runs": len(injected),
            "detected": hits,
            "detection_rate": hits / len(injected) if injected else None,
            "clean_runs": len(clean),
            "false_positives": fps,
            "false_positive_rate": fps / len(clean) if clean else None,
        }
    cp = Counter(c for r in runs for c in r.critical_components)
    total = sum(cp.values())
    critical_path_freq = {
        c: {"count": float(n), "fraction": n / total} for c, n in cp.most_common()
    }
    req = [x for r in runs for x in r.request_us]
    request_latency: Dict[str, float] = {}
    if req:
        p50, p90, p99, p999 = percentiles(req, (50, 90, 99, 99.9))
        request_latency = {"n": float(len(req)), "p50": p50, "p90": p90,
                           "p99": p99, "p99.9": p999, "max": max(req)}
    scenarios: List[str] = []
    for r in runs:
        if r.scenario not in scenarios:
            scenarios.append(r.scenario)
    return AggregateReport(
        n_runs=len(runs),
        scenarios=scenarios,
        ok_runs=sum(1 for r in runs if r.ok),
        component_latency=component_latency,
        detection=detection,
        critical_path_freq=critical_path_freq,
        wall_s_total=sum(r.wall_s for r in runs),
        events_total=sum(r.events for r in runs),
        request_latency=request_latency,
    )


# ---------------------------------------------------------------------------
# score_mitigations(): remediation policies competing on the same fault trace
# ---------------------------------------------------------------------------
#
# The mitigation engine's analysis half (sim/mitigation.py is the acting
# half).  A sweep with a ``mitigations`` axis runs the same scenario x seed
# cells once per policy; this rollup groups the resulting RunStats by
# policy and answers the operator's question — which remediation actually
# helps, how fast it kicked in, and what capacity it paid — always relative
# to the ``do_nothing`` baseline (byte-identical to an unmitigated run).


@dataclass
class MitigationScore:
    """One policy's scorecard across its runs of a mitigation sweep."""

    mitigation: str
    n_runs: int
    request_latency: Dict[str, float]      # pooled n/p50/p99/p99.9/max (µs)
    triggers: int                          # Mitigation spans across runs
    mitigation_us: Dict[str, float]        # mean/max detection->mitigation
    capacity_penalty: float                # mean per-run summed penalty
    p999_vs_baseline: Optional[float] = None   # p99.9 ratio (active/baseline)
    beats_baseline: Optional[bool] = None      # p99.9 strictly better?

    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable form (mitigations.json scoreboard rows)."""
        return {
            "mitigation": self.mitigation,
            "n_runs": self.n_runs,
            "request_latency": self.request_latency,
            "triggers": self.triggers,
            "mitigation_us": self.mitigation_us,
            "capacity_penalty": self.capacity_penalty,
            "p999_vs_baseline": self.p999_vs_baseline,
            "beats_baseline": self.beats_baseline,
        }


@dataclass
class MitigationScoreboard:
    """:func:`score_mitigations` output: one scorecard per policy."""

    baseline: str
    scores: List[MitigationScore] = field(default_factory=list)

    def __getitem__(self, mitigation: str) -> MitigationScore:
        for s in self.scores:
            if s.mitigation == mitigation:
                return s
        raise KeyError(
            f"no scorecard for mitigation {mitigation!r}; have: "
            f"{[s.mitigation for s in self.scores]}"
        )

    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable form."""
        return {
            "baseline": self.baseline,
            "scores": [s.to_dict() for s in self.scores],
        }

    def report(self) -> str:
        """Human-readable scoreboard (the sweep CLI prints this)."""
        lines = [
            f"mitigation scoreboard (baseline: {self.baseline}; request "
            f"latency in us, vs-base = p99.9 ratio):",
            f"    {'policy':22s} {'runs':>4s} {'p50':>9s} {'p99':>9s} "
            f"{'p99.9':>9s} {'vs-base':>8s} {'penalty':>8s} "
            f"{'trig':>4s} {'det->mit':>9s}",
        ]
        for s in self.scores:
            rl = s.request_latency
            vs = "-" if s.p999_vs_baseline is None else f"{s.p999_vs_baseline:.2f}x"
            mit = ("-" if not s.mitigation_us
                   else f"{s.mitigation_us['mean_us']:.0f}us")
            lines.append(
                f"    {s.mitigation:22s} {s.n_runs:4d} "
                f"{rl.get('p50', 0.0):9.0f} {rl.get('p99', 0.0):9.0f} "
                f"{rl.get('p99.9', 0.0):9.0f} {vs:>8s} "
                f"{s.capacity_penalty:8.4f} {s.triggers:4d} {mit:>9s}"
            )
        winners = [
            s.mitigation for s in self.scores if s.beats_baseline
        ]
        if winners:
            lines.append(f"    -> beats {self.baseline} on p99.9: {', '.join(winners)}")
        return "\n".join(lines)


def score_mitigations(
    runs: Iterable[RunStats], baseline: str = "do_nothing"
) -> MitigationScoreboard:
    """Group runs by mitigation policy and score each against ``baseline``.

    Per policy: pooled request-latency percentiles (p50/p99/p99.9/max),
    trigger count and mean/max detection-to-mitigation latency (the
    ``Mitigation`` span durations), mean capacity penalty per run, and —
    for active policies — the p99.9 ratio vs the baseline group.  Runs with
    an empty ``mitigation`` tag count as the baseline (pre-mitigation-era
    shards re-aggregate cleanly)."""
    groups: Dict[str, List[RunStats]] = {}
    for r in runs:
        groups.setdefault(r.mitigation or baseline, []).append(r)
    base_req = [x for r in groups.get(baseline, []) for x in r.request_us]
    base_p999 = percentiles(base_req, (99.9,))[0] if base_req else None
    names = sorted(groups, key=lambda n: (n != baseline, n))  # baseline first
    scores: List[MitigationScore] = []
    for name in names:
        rs = groups[name]
        req = [x for r in rs for x in r.request_us]
        rl: Dict[str, float] = {}
        if req:
            p50, p99, p999 = percentiles(req, (50, 99, 99.9))
            rl = {"n": float(len(req)), "p50": p50, "p99": p99,
                  "p99.9": p999, "max": max(req)}
        mit = [x for r in rs for x in r.mitigation_us]
        mit_stats: Dict[str, float] = {}
        if mit:
            mit_stats = {"mean_us": sum(mit) / len(mit), "max_us": max(mit)}
        penalty = sum(r.capacity_penalty for r in rs) / len(rs) if rs else 0.0
        ratio: Optional[float] = None
        beats: Optional[bool] = None
        if name != baseline and base_p999 and rl:
            ratio = rl["p99.9"] / base_p999
            beats = rl["p99.9"] < base_p999
        scores.append(MitigationScore(
            mitigation=name,
            n_runs=len(rs),
            request_latency=rl,
            triggers=len(mit),
            mitigation_us=mit_stats,
            capacity_penalty=penalty,
            p999_vs_baseline=ratio,
            beats_baseline=beats,
        ))
    return MitigationScoreboard(baseline=baseline, scores=scores)
