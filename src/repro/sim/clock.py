"""Discrete-event simulation core: global virtual clock + event queue.

The global clock is the "true and precise global clock for all events" the
paper highlights as a key advantage of simulation (§1 advantage iii).
Times are integer picoseconds.
"""
from __future__ import annotations

import heapq
from typing import Callable, List, Optional, Tuple


class Sim:
    """Minimal DES kernel."""

    def __init__(self) -> None:
        self.now: int = 0
        self._q: List[Tuple[int, int, Callable[[], None]]] = []
        self._seq = 0
        self.events_executed = 0

    def at(self, t: int, fn: Callable[[], None]) -> None:
        assert t >= self.now, f"scheduling into the past: {t} < {self.now}"
        heapq.heappush(self._q, (int(t), self._seq, fn))
        self._seq += 1

    def after(self, dt: int, fn: Callable[[], None]) -> None:
        self.at(self.now + int(dt), fn)

    def run(self, until: Optional[int] = None, max_events: int = 100_000_000) -> None:
        while self._q and self.events_executed < max_events:
            t, _, fn = self._q[0]
            if until is not None and t > until:
                break
            heapq.heappop(self._q)
            self.now = t
            fn()
            self.events_executed += 1

    def empty(self) -> bool:
        return not self._q


class LogWriter:
    """Collects one simulator instance's ad-hoc log lines.

    Lines buffer in memory and flush to a file (or named pipe for §3.8
    online mode) — simulators in the paper write files; ours do too.
    """

    def __init__(self, path: Optional[str] = None, stream=None) -> None:
        self.path = path
        self.lines: List[str] = []
        self._stream = stream
        if path is not None and stream is None:
            self._stream = open(path, "w", buffering=1 << 20)

    def write(self, line: str) -> None:
        if self._stream is not None:
            self._stream.write(line)
            self._stream.write("\n")
        else:
            self.lines.append(line)

    def close(self) -> None:
        if self._stream is not None:
            self._stream.close()
            self._stream = None

    def __enter__(self) -> "LogWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
