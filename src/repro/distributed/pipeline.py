"""Pipeline parallelism: GPipe-style fill-drain schedule with shard_map +
collective_permute over a mesh axis.

``pipeline_apply`` runs ``stage_fn`` as an S-stage pipeline over
microbatches.  Stage parameters are stacked on a leading axis sharded over
the pipeline mesh axis; activations flow stage->stage via ppermute.
Differentiable (ppermute transposes to the reverse permute), so the same
schedule trains — the multi-pod mesh's "pod" axis can act as a 2-stage
pipeline (see tests/test_pipeline.py and EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .compat import shard_map


def pipeline_apply(
    mesh: Mesh,
    axis: str,
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,            # pytree, leaves stacked (n_stages, ...)
    x_mb: jax.Array,              # (n_micro, mb, ...) microbatched input
) -> jax.Array:
    """Returns (n_micro, mb, ...) outputs of the final stage."""
    n_stages = mesh.shape[axis]

    def per_device(params, x):
        # params leaves arrive as (1, ...) shards of the stacked stage dim
        params = jax.tree_util.tree_map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        n_micro = x.shape[0]
        T = n_micro + n_stages - 1

        buf = jnp.zeros_like(x[0])                    # inter-stage recv buffer
        out = jnp.zeros_like(x)

        def tick(carry, t):
            buf, out = carry
            feed = x[jnp.minimum(t, n_micro - 1)]
            inp = jnp.where(stage == 0, feed, buf)
            y = stage_fn(params, inp)
            # shift activations down the pipe: stage i -> i+1
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            # last stage emits microbatch t-(S-1)
            emit_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_emit = jnp.logical_and(stage == n_stages - 1, t >= n_stages - 1)
            cur = jax.lax.dynamic_index_in_dim(out, emit_idx, 0, keepdims=False)
            upd = jnp.where(is_emit, y, cur)
            out = jax.lax.dynamic_update_index_in_dim(out, upd, emit_idx, 0)
            return (nxt, out), None

        (buf, out), _ = jax.lax.scan(tick, (buf, out), jnp.arange(T))
        # replicate the final-stage outputs to all stages (masked psum)
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)), axis
        )
        return out

    in_specs = (
        jax.tree_util.tree_map(lambda _: P(axis), stage_params),
        P(),
    )
    fn = shard_map(
        per_device, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
    )
    return fn(stage_params, x_mb)
