"""Production meshes.

``make_production_mesh`` is a FUNCTION (never evaluated at import) so that
importing this module touches no jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to satisfy the 512-chip multi-pod mesh on the CPU-only container.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, devices=jax.devices()[: math.prod(shape)])


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh over the first prod(shape) devices (tests, elastic)."""
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def best_mesh_for(n_devices: int, model_parallel: int = 1):
    """Elastic restart helper: the largest (data, model) grid that fits
    ``n_devices`` with the requested model-parallel degree."""
    model = model_parallel
    while model > 1 and (n_devices % model or n_devices // model < 1):
        model //= 2
    data = n_devices // model
    return make_mesh((data, model), ("data", "model"))
