"""End-to-end training driver: a ~100M-class config trained for a few
hundred steps on synthetic data, with checkpointing + resume.

The default CPU-friendly run uses a reduced model (--preset cpu) so the
example finishes in minutes; --preset 100m selects the real ~100M model
(same code path; run it where you have the FLOPs).

    PYTHONPATH=src python examples/train_e2e.py --steps 200
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["cpu", "100m"], default="cpu")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="results/train_e2e_ckpt")
    args = ap.parse_args()

    from repro.models import ModelConfig
    from repro.training import AdamWConfig, TrainConfig
    from repro.training.trainer import Trainer, TrainerConfig

    if args.preset == "100m":
        # ~100M params: 12L x 768, GPT-2-small-class
        cfg = ModelConfig(
            name="repro-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=32768,
            head_dim=64, remat="none",
        )
    else:
        cfg = ModelConfig(
            name="repro-cpu", family="dense", n_layers=4, d_model=256,
            n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=4096,
            head_dim=32, remat="none",
        )
    print(f"model {cfg.name}: {cfg.n_params/1e6:.1f}M params")

    tc = TrainConfig(
        adamw=AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    )
    trainer = Trainer(
        cfg,
        tc,
        TrainerConfig(
            total_steps=args.steps,
            ckpt_every=max(args.steps // 4, 1),
            ckpt_dir=args.ckpt_dir,
            log_every=10,
        ),
    )
    state = trainer.run()
    losses = [m["loss"] for m in trainer.metrics_log]
    print(
        f"\ntrained {len(losses)} steps: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
        f"(median step {sorted(m['step_time_s'] for m in trainer.metrics_log)[len(losses)//2]*1e3:.0f} ms)"
    )
    print(f"checkpoints: {trainer.ckpt.all_steps()} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
