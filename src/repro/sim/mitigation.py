"""The pluggable mitigation layer: remediation policies on a live cluster.

Columbo's diagnosis loop (``sim/faults.py`` injects, ``core.analysis.diagnose``
attributes) answers *"can we see the fault?"*.  This module answers the next
question — *"what should the fleet do about it, and what does it cost?"* —
with the same architecture the workload layer uses:

* :class:`MitigationPolicy` — the protocol: a dataclass of knobs plus
  ``attach(cluster)``, called by :meth:`ScenarioSpec.simulate` after faults
  are scheduled and before the workload drives.  A policy arms a **seeded
  deterministic trigger loop** (:meth:`MitigationPolicy.watch`) that polls
  simulator telemetry — per-link drop counters
  (:meth:`~repro.sim.netsim.NetSim.link_drop_counts`), host stall state
  (:attr:`~repro.sim.hostsim.HostSim.pending_stall_ps`), per-chip compute
  scales (:meth:`~repro.sim.devicesim.DeviceSim.scale_of`) — and fires
  remediation actions through the simulators' mitigation hooks.
* a name registry — :func:`register_mitigation` / :func:`make_mitigation` /
  :func:`list_mitigations` / :func:`mitigation_type`, mirroring
  ``sim/workload.py``.
* :class:`DoNothing` — the baseline.  Its ``attach`` is a strict no-op
  (zero kernel events, zero log records), so a ``do_nothing`` run is
  **byte-identical** to an unmitigated one: the goldens hold, and every
  active policy is scored against it by ``core.analysis.score_mitigations``.

Every trigger/action/recovery logs host events (``mitigation_trigger`` /
``mitigation_action`` / ``mitigation_done``, plus ``retransmit_begin`` /
``retransmit_end`` from the loss-protection policy) that weave into
``Mitigation`` span subtrees on both the text and structured paths.

Built-ins live in the ``sim/mitigations/`` package (same split as
``sim/workload.py`` + ``sim/workloads/``): ``disable_and_reroute``,
``retransmit``, ``evict_straggler``, ``checkpoint_restore``.
``docs/mitigations.md`` is the cookbook.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Callable, ClassVar, Dict, List, Optional, Tuple, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .cluster import ClusterOrchestrator
    from .hostsim import HostSim


class MitigationConflictError(ValueError):
    """A mitigation would mask the very diagnosis a scenario asserts.

    Raised by ``ScenarioSpec.run(mitigation=...)`` when the policy's
    declared ``masks`` intersect the scenario's ``expected`` fault classes:
    running the combination would make the scenario's acceptance check
    vacuous (the fault gets remediated before diagnosis can see it).
    Construct the spec with the ``mitigation`` field directly — or override
    ``expected`` in the same call — to opt in deliberately.
    """


@dataclass
class MitigationPolicy:
    """Base class: a remediation policy that arms itself on a cluster.

    Subclasses implement :meth:`attach`, which registers a trigger loop (or
    nothing, for the baseline) on the cluster's shared
    :class:`~repro.sim.engine.EventKernel` **before** the workload drives.
    The two standard knobs bound the watch window so the DES always drains:

    * ``poll_every_ps`` — trigger-loop cadence (how often telemetry is
      polled);
    * ``max_polls``     — polls before the policy gives up watching.

    ``masks`` declares the fault classes whose *diagnosis signal* the
    policy removes when it fires (e.g. evicting a straggler normalizes the
    op durations the straggler rules read); ``ScenarioSpec.run`` refuses
    ``mitigation=`` overrides that would mask a scenario's expected
    diagnosis (:class:`MitigationConflictError`).
    """

    #: registry key; subclasses set it (e.g. "retransmit") and register
    mitigation_name: ClassVar[str] = ""
    #: fault classes whose diagnosis this policy can mask once triggered
    masks: ClassVar[Tuple[str, ...]] = ()

    seed: int = 0
    poll_every_ps: int = 1_000_000_000      # 1 ms trigger-loop cadence
    max_polls: int = 40

    def attach(self, cluster: "ClusterOrchestrator") -> None:
        """Arm the policy's trigger loop on ``cluster`` (before ``run()``)."""
        raise NotImplementedError

    def describe(self) -> str:
        """One-line summary for reports and ``--list-mitigations``."""
        doc = (type(self).__doc__ or "").strip().splitlines()
        return doc[0] if doc else (self.mitigation_name or type(self).__name__)

    # -- shared helpers for subclasses ------------------------------------------

    def rng(self, stream: int = 0) -> random.Random:
        """A deterministic per-``(seed, stream)`` random source (the same
        arithmetic-derivation scheme as ``FaultPlan`` / ``Workload``, with
        a third offset so mitigation streams never collide with fault or
        workload streams)."""
        return random.Random(self.seed * 1_000_003 + stream * 7_919 + 911_657)

    def controller(self, cluster: "ClusterOrchestrator") -> "HostSim":
        """The host that logs this policy's events: the first chip-bearing
        host (the fleet-controller stand-in), else the first host."""
        for h in cluster.hosts.values():
            if h.chips:
                return h
        return next(iter(cluster.hosts.values()))

    def watch(
        self,
        cluster: "ClusterOrchestrator",
        probe: Callable[[int], bool],
    ) -> None:
        """The seeded deterministic trigger loop.

        Calls ``probe(i)`` every ``poll_every_ps`` of simulated time; a
        ``True`` return means the policy triggered and the loop cancels
        itself (one-shot remediation).  After ``max_polls`` quiet polls the
        loop expires on its own, so an un-triggered policy never keeps the
        kernel alive."""
        state: Dict[str, Any] = {}

        def _tick(i: int) -> None:
            if probe(i):
                # shrink n to the fire count: the task never re-arms, so a
                # triggered policy leaves zero trailing kernel events
                state["task"].n = state["task"].fires

        state["task"] = cluster.sim.every(
            self.poll_every_ps, _tick, n=self.max_polls
        )

    # -- event helpers (weave into the Mitigation span subtree) ------------------

    def log_trigger(self, cluster: "ClusterOrchestrator", **attrs: Any) -> None:
        """Log ``mitigation_trigger`` (opens the policy's Mitigation span)."""
        self.controller(cluster).log_event(
            "mitigation_trigger", policy=self.mitigation_name, **attrs
        )

    def log_action(self, cluster: "ClusterOrchestrator", **attrs: Any) -> None:
        """Log ``mitigation_action`` (a remediation step, inside the span)."""
        self.controller(cluster).log_event(
            "mitigation_action", policy=self.mitigation_name, **attrs
        )

    def log_done(self, cluster: "ClusterOrchestrator", **attrs: Any) -> None:
        """Log ``mitigation_done`` (closes the span; trigger→done is the
        detection-to-mitigation latency)."""
        self.controller(cluster).log_event(
            "mitigation_done", policy=self.mitigation_name, **attrs
        )


# ---------------------------------------------------------------------------
# Registry (mirrors sim/workload.py)
# ---------------------------------------------------------------------------


_MITIGATIONS: Dict[str, type] = {}
_BUILTINS_LOADED = False


def _ensure_builtin_mitigations() -> None:
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        _BUILTINS_LOADED = True
        from . import mitigations  # noqa: F401  (registers the built-ins)


def register_mitigation(cls: type, replace: bool = False) -> type:
    """Class decorator: register a :class:`MitigationPolicy` subclass under
    its ``mitigation_name`` (the mitigation-layer analogue of
    :func:`~repro.sim.workload.register_workload`)."""
    name = getattr(cls, "mitigation_name", "")
    if not name:
        raise ValueError(f"{cls.__name__} must set a non-empty mitigation_name")
    if not replace and name in _MITIGATIONS:
        raise ValueError(
            f"mitigation {name!r} already registered; pass replace=True to override"
        )
    _MITIGATIONS[name] = cls
    return cls


def list_mitigations() -> List[str]:
    """Registered mitigation names, sorted (built-ins load on first use)."""
    _ensure_builtin_mitigations()
    return sorted(_MITIGATIONS)


def mitigation_type(name: str) -> type:
    """Look up a registered mitigation class (KeyError lists what exists)."""
    _ensure_builtin_mitigations()
    try:
        return _MITIGATIONS[name]
    except KeyError:
        raise KeyError(
            f"unknown mitigation {name!r}; available: "
            f"{', '.join(sorted(_MITIGATIONS))}"
        ) from None


def make_mitigation(name: str, **params: Any) -> MitigationPolicy:
    """Instantiate a registered mitigation with ``params``.

    Unknown knobs raise ``TypeError`` naming the policy — misspelled
    parameters must never be silently ignored (the same contract
    :func:`~repro.sim.workload.make_workload` enforces)."""
    cls = mitigation_type(name)
    try:
        return cls(**params)
    except TypeError as e:
        raise TypeError(f"mitigation {name!r}: {e}") from None


@register_mitigation
@dataclass
class DoNothing(MitigationPolicy):
    """Baseline: ride the fault out (what every scenario did before the
    mitigation layer existed).

    ``attach`` is a strict no-op — no kernel events scheduled, no log
    records emitted — so a ``do_nothing`` run is byte-identical to an
    unmitigated one and every active policy's cost/benefit is measured
    against it.
    """

    mitigation_name: ClassVar[str] = "do_nothing"

    def attach(self, cluster: "ClusterOrchestrator") -> None:
        """Deliberately nothing: the baseline must not perturb the DES."""
        return None
