"""Deterministic synthetic LM data pipeline.

Generates Zipf-distributed token streams from a counter-based PRNG, so the
loader's state is exactly (seed, step) — checkpointable and elastically
reshardable by construction (any host can regenerate any shard).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    with_embeds: bool = False      # modality-frontend stub archs
    d_model: int = 0


class SyntheticLM:
    """Host-sharded deterministic batches.

    ``host_index``/``host_count`` split the global batch; every batch for
    every step is a pure function of (seed, step), so restarts and
    re-sharding never replay or skip data.
    """

    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        # Zipf-ish distribution over the vocab via inverse-CDF sampling
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        weights = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(weights / weights.sum())

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, self.host_index])
        )
        u = rng.random((self.local_batch, c.seq_len + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        np.clip(toks, 0, c.vocab_size - 1, out=toks)
        batch: Dict[str, np.ndarray] = {
            "labels": toks[:, 1:].copy(),
        }
        if c.with_embeds:
            batch["embeds"] = rng.standard_normal(
                (self.local_batch, c.seq_len, c.d_model)
            ).astype(np.float32) * 0.02
        else:
            batch["tokens"] = toks[:, :-1].copy()
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Double-buffered host->device prefetch around any step-indexed source."""

    def __init__(self, source: SyntheticLM, put_fn=None, depth: int = 2):
        import queue
        import threading

        self.source = source
        self.put_fn = put_fn or (lambda x: x)
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = False
        self._step = 0
        self._thread = threading.Thread(target=self._fill, daemon=True)

    def start(self, from_step: int = 0) -> "PrefetchLoader":
        self._step = from_step
        self._thread.start()
        return self

    def _fill(self) -> None:
        while not self._stop:
            b = self.source.batch_at(self._step)
            self._q.put((self._step, self.put_fn(b)))
            self._step += 1

    def __next__(self):
        return self._q.get()

    def stop(self) -> None:
        self._stop = True
        try:
            self._q.get_nowait()
        except Exception:
            pass
