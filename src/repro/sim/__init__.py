"""Modular full-system simulation of the multi-pod TPU testbed.

Component simulators (each writing its own ad-hoc log format):
* devicesim — chips (gem5 role): op timeline under a roofline cost model
* hostsim   — host runtime (SimBricks host/NIC role): input pipeline, DMA,
              dispatch, checkpoints, clocks + NTP
* netsim    — interconnect (ns3 role): ICI/DCN/PCIe links, chunk transfers,
              background traffic

cluster.ClusterOrchestrator assembles them (SimBricks role); workload builds
device programs from compiled XLA artifacts or synthetic specs.

engine.EventKernel is the shared discrete-event kernel all of them schedule
on; sweep runs fleets of (scenario, workload, mitigation, seed) cells in
parallel over a persistent warm worker pool; mitigation attaches pluggable
remediation policies (retransmit, disable_and_reroute, evict_straggler,
checkpoint_restore) that compete against the do_nothing baseline on the
same fault trace.
"""
from .clock import LogWriter, Sim, StructuredLogWriter
from .cluster import ClusterOrchestrator, FailurePlan, run_ntp_sim, run_training_sim
from .engine import EventHandle, EventKernel, PeriodicTask, SimPort
from .devicesim import CollectiveInstance, DeviceSim
from .faults import (
    FAULT_CLASSES,
    ChunkReorder,
    ClockDrift,
    ClockStep,
    DeviceSlowdown,
    FaultPlan,
    FaultSpec,
    HostPause,
    LinkDegradation,
    LinkLoss,
    LossRateTrace,
    StragglerPod,
)
from .hostsim import HostClock, HostSim
from .mitigation import (
    DoNothing,
    MitigationConflictError,
    MitigationPolicy,
    list_mitigations,
    make_mitigation,
    mitigation_type,
    register_mitigation,
)
from .mitigations import (
    CheckpointRestore,
    DisableAndReroute,
    EvictStraggler,
    Retransmit,
)
from .netsim import LinkFault, NetSim
from .scenarios import (
    SCENARIOS,
    ScenarioRun,
    ScenarioSpec,
    get_scenario,
    list_scenarios,
)
from .sweep import (
    CellResult,
    SweepResult,
    SweepSpec,
    load_sweep,
    run_sweep,
    shutdown_pool,
)
from .topology import Link, Topology, fat_tree_cluster, ntp_testbed, scale, tpu_cluster
from .workload import (
    CollectiveTraining,
    OpSpec,
    ProgramSpec,
    Workload,
    list_workloads,
    make_workload,
    program_from_compiled,
    register_workload,
    synthetic_program,
    workload_type,
)
from .workloads import (
    LbPolicy,
    PipelinedTraining,
    RpcServing,
    StorageIO,
    lb_policy_type,
    list_lb_policies,
    make_lb_policy,
    register_lb_policy,
    rpc_handler_program,
)

__all__ = [k for k in dir() if not k.startswith("_")]
