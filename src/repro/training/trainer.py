"""Trainer: the host-side loop the framework deploys.

Wires together the sharded train step, the deterministic data pipeline,
checkpointing (periodic + async), telemetry hooks, preemption handling, and
elastic restart (resume the latest checkpoint onto whatever mesh exists).
Runs unchanged from 1 CPU device (examples/tests) to the production mesh.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..checkpoint import CheckpointManager
from ..data import DataConfig, SyntheticLM
from ..models.config import ModelConfig
from ..models.params import abstract_params, init_params, partition_specs
from ..models.sharding import make_rules, sharding_context
from ..models.transformer import model_pspecs
from .optimizer import AdamWConfig
from .train_step import TrainConfig, init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10
    seed: int = 0
    preemption_file: Optional[str] = None    # touch this file to request stop
    straggler_threshold: float = 2.0         # x median step time


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tc: TrainConfig,
        trainer_cfg: TrainerConfig,
        mesh: Optional[Mesh] = None,
        fsdp: bool = True,
    ):
        self.cfg = cfg
        self.tc = tc
        self.c = trainer_cfg
        self.mesh = mesh
        self.rules = make_rules(mesh, fsdp=fsdp) if mesh is not None else None
        self.ckpt = (
            CheckpointManager(self.c.ckpt_dir, keep=self.c.ckpt_keep)
            if self.c.ckpt_every
            else None
        )
        self.metrics_log: List[Dict[str, float]] = []
        self.step_times: List[float] = []
        self.hooks: List[Callable[[int, Dict[str, float]], None]] = []

        pspecs = model_pspecs(cfg)
        if mesh is not None:
            specs = partition_specs(pspecs, self.rules)
            ns = lambda s: NamedSharding(mesh, s)
            self.param_shardings = jax.tree_util.tree_map(
                ns, specs, is_leaf=lambda x: isinstance(x, P)
            )
            opt_sh = {"m": self.param_shardings, "v": self.param_shardings}
            if cfg.param_dtype != "float32":
                opt_sh["master"] = self.param_shardings
            self.state_shardings = {
                "params": self.param_shardings,
                "opt": opt_sh,
                "step": ns(P()),
            }
        else:
            self.param_shardings = None
            self.state_shardings = None

        step_fn = make_train_step(cfg, tc)
        if mesh is not None:
            self._step = jax.jit(
                step_fn,
                in_shardings=(self.state_shardings, None),
                donate_argnums=(0,),
            )
        else:
            self._step = jax.jit(step_fn, donate_argnums=(0,))

    # -- state ------------------------------------------------------------------

    def init_state(self) -> Dict[str, Any]:
        params = init_params(jax.random.PRNGKey(self.c.seed), model_pspecs(self.cfg))
        state = init_train_state(self.cfg, params)
        if self.state_shardings is not None:
            state = jax.device_put(state, self.state_shardings)
        return state

    def restore_or_init(self) -> Dict[str, Any]:
        state = self.init_state()
        if self.ckpt and self.ckpt.latest_step() is not None:
            state, _ = self.ckpt.restore(state, shardings=self.state_shardings)
        return state

    # -- loop -------------------------------------------------------------------

    def run(self, state: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        cfg, c = self.cfg, self.c
        state = state if state is not None else self.restore_or_init()
        start = int(jax.device_get(state["step"]))
        data = SyntheticLM(
            DataConfig(
                vocab_size=cfg.vocab_size,
                seq_len=256 if cfg.frontend == "none" else 64,
                global_batch=8,
                seed=c.seed,
                with_embeds=cfg.frontend != "none",
                d_model=cfg.d_model,
            )
        )

        ctx = (
            sharding_context(self.mesh, self.rules)
            if self.mesh is not None
            else _nullcontext()
        )
        with ctx:
            for step in range(start, c.total_steps):
                if c.preemption_file and os.path.exists(c.preemption_file):
                    # graceful preemption: checkpoint + stop
                    if self.ckpt:
                        self.ckpt.wait()
                        self.ckpt.save(step, state, extras={"preempted": True})
                    break
                batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
                t0 = time.time()
                state, metrics = self._step(state, batch)
                metrics = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                dt = time.time() - t0
                self.step_times.append(dt)
                metrics["step_time_s"] = dt
                self.metrics_log.append(metrics)
                for h in self.hooks:
                    h(step, metrics)
                if (
                    len(self.step_times) > 4
                    and dt > self.c.straggler_threshold * float(np.median(self.step_times))
                ):
                    metrics["straggler_flag"] = 1.0
                if c.log_every and step % c.log_every == 0:
                    print(
                        f"step {step:5d} loss {metrics['loss']:.4f} "
                        f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f}ms",
                        flush=True,
                    )
                if self.ckpt and c.ckpt_every and (step + 1) % c.ckpt_every == 0:
                    if c.ckpt_async:
                        self.ckpt.save_async(step + 1, state)
                    else:
                        self.ckpt.save(step + 1, state)
        if self.ckpt:
            self.ckpt.wait()
        return state


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
