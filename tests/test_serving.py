"""Serving engine behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# long-running engine/decode loops: excluded from the tier-1 profile
pytestmark = pytest.mark.slow

from repro.configs import get_arch
from repro.models import forward, init_params, model_pspecs
from repro.serving import Request, ServingEngine

# float32 throughout: the greedy tests compare argmax between the engine's
# incremental decode and a full-sequence forward(), and in bf16 the reduced
# 512-vocab config hits exact logit ties whose winner flips with summation
# order (same reason test_arch_decode_matches_forward pins float32)
CFG = dataclasses.replace(
    get_arch("olmo-1b").config.reduced(n_layers=2),
    dtype="float32", kv_cache_dtype="float32", logits_f32=True,
)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), model_pspecs(CFG))


def test_greedy_serving_matches_manual_decode(params):
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, CFG.vocab_size, size=8).astype(np.int32)
    engine = ServingEngine(CFG, params, batch_size=1, max_seq=32)
    [req] = engine.serve([Request(prompt=prompt, max_new_tokens=6)])
    assert req.output is not None and len(req.output) == 6

    # manual greedy rollout with plain forward() must agree
    toks = list(prompt)
    for _ in range(6):
        lg, _ = jax.jit(lambda p, t: forward(CFG, p, t))(
            params, jnp.asarray([toks], jnp.int32)
        )
        toks.append(int(jnp.argmax(lg[0, -1])))
    np.testing.assert_array_equal(req.output, np.asarray(toks[len(prompt):], np.int32))


def test_batched_waves_and_stats(params):
    rng = np.random.default_rng(1)
    reqs = [
        Request(prompt=rng.integers(0, CFG.vocab_size, size=8).astype(np.int32),
                max_new_tokens=4, temperature=0.8 if i % 2 else 0.0)
        for i in range(6)
    ]
    engine = ServingEngine(CFG, params, batch_size=4, max_seq=16)
    engine.serve(reqs)
    assert engine.stats.waves == 2
    assert engine.stats.requests == 6
    assert all(r.output is not None and len(r.output) == 4 for r in reqs)
    assert engine.stats.tokens_per_s > 0


def test_eos_stops_generation(params):
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, CFG.vocab_size, size=4).astype(np.int32)
    engine = ServingEngine(CFG, params, batch_size=1, max_seq=64)
    # discover the greedy first token, then use it as "EOS"
    [probe] = engine.serve([Request(prompt=prompt.copy(), max_new_tokens=3)])
    eos = int(probe.output[0])
    [req] = engine.serve([Request(prompt=prompt.copy(), max_new_tokens=32, eos_id=eos)])
    assert len(req.output) <= 2
