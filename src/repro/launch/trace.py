"""Trace launcher — the Columbo end-to-end path for this framework:

1. read a dry-run artifact (or lower one on the fly) for an (arch, shape),
2. build its device ProgramSpec (real compiled aggregate costs + the real
   collective schedule),
3. simulate the multi-pod cluster executing it (component sims write their
   ad-hoc logs),
4. run a declarative TraceSpec over the tagged logs,
5. stream Jaeger/Chrome/OTLP/JSONL traces + print the per-component breakdown.

``python -m repro.launch.trace --arch olmo-1b --shape train_4k --steps 2``

Fault scenarios (sim/scenarios.py) run through the same path, under any
registered workload (sim/workload.py: collective / rpc / storage /
pipeline):

``python -m repro.launch.trace --scenario throttled_chip --seed 7``
``python -m repro.launch.trace --scenario degraded_ici_link --workload rpc``
``python -m repro.launch.trace --list-scenarios [--workload rpc]``

Mitigation policies (sim/mitigation.py) attach to the same fault trace and
compete against the ``do_nothing`` baseline; ``--mitigations`` fans them out
as a sweep axis and prints the ``score_mitigations()`` scoreboard:

``python -m repro.launch.trace --scenario link_loss_rpc --mitigation retransmit``
``python -m repro.launch.trace --scenario 'link_loss_*' --sweep \\
     --mitigations do_nothing,retransmit,disable_and_reroute``
``python -m repro.launch.trace --list-mitigations``

Fleet sweeps (sim/sweep.py) fan (scenario, seed) cells over worker
processes, stream per-cell SpanJSONL shards, and print the aggregate
report (detection rates, latency percentiles, critical-path frequency):

``python -m repro.launch.trace --sweep --seeds 0:8 --jobs 8``
``python -m repro.launch.trace --sweep --scenarios lossy_dcn,healthy_baseline \\
     --seeds 0,1,2 --sweep-pods 64 --fabric fat-tree``

``--magnitudes`` adds the fault-magnitude sweep axis (scaled fault
intensities — the detection-sensitivity curves' x axis), and
``--diag-bench`` runs the scored diagnosis benchmark end to end:

``python -m repro.launch.trace --sweep --scenarios degraded_ici_link \\
     --magnitudes 0.0,0.25,1.0``
``python -m repro.launch.trace --diag-bench [--diag-smoke]``

Saturation serving (docs/workloads.md "Saturation & load balancing"):
``--arrival-rate`` drives the rpc workload's open-loop Poisson arrival
rate (a comma list under ``--sweep`` becomes the arrival-rate axis), and
``--queue-depth`` / ``--lb`` bound each backend's FIFO and pick the
frontend load-balancing policy (``round_robin``, ``least_loaded``,
``power_of_two_choices``):

``python -m repro.launch.trace --scenario healthy_baseline --workload rpc \\
     --arrival-rate 2e6 --queue-depth 4 --lb least_loaded``
``python -m repro.launch.trace --sweep --scenarios healthy_baseline \\
     --workloads rpc --arrival-rate 1e3,1e5,2e6 --lb power_of_two_choices``

``--structured`` switches every path onto the zero-parse event fast path
(simulators hand Event records straight to the weavers; no text logs are
formatted or re-parsed).  Output bytes are identical — only faster:

``python -m repro.launch.trace --scenario throttled_chip --structured``
``python -m repro.launch.trace --sweep --jobs 8 --structured``

``--weave inline`` goes one step further: spans assemble *while the
kernel runs* (``core.streaming.StreamingWeaver`` — no format, no parse,
no post-hoc weave pass), ``--weave sharded`` adds a ``--jobs``-way
parallel export merged back in canonical order, and ``--weave columnar``
keeps the dominant net records in column arrays end to end (no Span
objects on the hot path, JSONL rendered straight from the arrays).  All
modes produce byte-identical SpanJSONL (the golden-equivalence harness
in ``tests/test_streaming_weave.py`` holds them to it):

``python -m repro.launch.trace --scenario throttled_chip --weave inline``
``python -m repro.launch.trace --scenario lossy_dcn --weave sharded --jobs 4``
``python -m repro.launch.trace --scenario degraded_ici_link --weave columnar``
``python -m repro.launch.trace --sweep --weave columnar --jobs 8``
"""
import argparse
import fnmatch
import json
import os


def _parse_seeds(text: str):
    """``"0:8"`` -> range(0, 8); ``"0,3,7"`` -> those seeds."""
    if ":" in text:
        lo, hi = text.split(":", 1)
        return tuple(range(int(lo), int(hi)))
    return tuple(int(s) for s in text.split(",") if s.strip())


def _expand_scenarios(patterns: str):
    """Comma list of scenario names/globs -> matching library names."""
    from ..sim.scenarios import SCENARIOS

    names = []
    for pat in (p.strip() for p in patterns.split(",")):
        if not pat:
            continue
        hits = [n for n in SCENARIOS if fnmatch.fnmatch(n, pat)]
        if not hits:
            raise SystemExit(f"no scenario matches {pat!r} "
                             f"(see --list-scenarios)")
        names.extend(n for n in hits if n not in names)
    return tuple(names)


def _run_sweep(args) -> None:
    from ..sim.sweep import SweepSpec, run_sweep

    scenarios = None
    patterns = ",".join(p for p in (args.scenarios, args.scenario) if p)
    if patterns:
        scenarios = _expand_scenarios(patterns)
    seeds = _parse_seeds(args.seeds)
    overrides = {}
    if args.sweep_pods:
        overrides["n_pods"] = args.sweep_pods
    if args.sweep_chips_per_pod:
        overrides["chips_per_pod"] = args.sweep_chips_per_pod
    if args.fabric:
        overrides["fabric"] = args.fabric
    if args.workloads:
        overrides["workloads"] = tuple(
            w.strip() for w in args.workloads.split(",") if w.strip()
        )
    elif args.workload:
        overrides["workloads"] = (args.workload,)
    if args.mitigations:
        overrides["mitigations"] = tuple(
            m.strip() for m in args.mitigations.split(",") if m.strip()
        )
    elif args.mitigation:
        overrides["mitigations"] = (args.mitigation,)
    if args.magnitudes:
        overrides["magnitudes"] = tuple(
            float(m) for m in args.magnitudes.split(",") if m.strip()
        )
    if args.arrival_rate:
        overrides["arrival_rates"] = tuple(
            float(r) for r in args.arrival_rate.split(",") if r.strip()
        )
    if args.queue_depth:
        overrides["queue_depth"] = args.queue_depth
    if args.lb:
        overrides["lb"] = args.lb
    if scenarios is None:
        spec = SweepSpec.library(seeds=seeds, **overrides)
    else:
        spec = SweepSpec(scenarios=scenarios, seeds=seeds, **overrides)
    outdir = os.path.join(args.outdir, "sweep")
    result = run_sweep(spec, outdir, jobs=args.jobs, structured=args.structured,
                       weave=args.weave)
    agg = result.aggregate()
    print(result.report(aggregate_report=agg))
    agg_path = os.path.join(outdir, "aggregate.json")
    with open(agg_path, "w") as f:
        json.dump(agg.to_dict(), f, indent=1)
    if spec.mitigations:
        score_path = os.path.join(outdir, "mitigation_scores.json")
        with open(score_path, "w") as f:
            json.dump(result.score_mitigations().to_dict(), f, indent=1)
        print(f"[sweep] mitigation scoreboard in {score_path}")
    print(f"[sweep] {len(result.cells)} shards in {outdir}/shards/, "
          f"summary in {outdir}/sweep.json, rollup in {agg_path}")
    if not result.ok:
        raise SystemExit(1)


def _run_scenario(args) -> None:
    from ..core import (ChromeTraceExporter, SpanJSONLExporter, request_report,
                        trace_summary)
    from ..sim.scenarios import get_scenario

    spec = get_scenario(args.scenario)
    os.makedirs(args.outdir, exist_ok=True)
    tag = f".{args.workload}" if args.workload else ""
    mit_tag = f".{args.mitigation}" if args.mitigation else ""
    base = os.path.join(args.outdir, f"scenario.{spec.name}{tag}{mit_tag}")
    overrides = {"workload": args.workload} if args.workload else {}
    if args.mitigation:
        overrides["mitigation"] = args.mitigation
    serving = {}
    if args.arrival_rate:
        if "," in args.arrival_rate:
            raise SystemExit(
                "a comma list of --arrival-rate values is the sweep axis; "
                "with --scenario pass one rate (or add --sweep)"
            )
        serving["rate_rps"] = float(args.arrival_rate)
        serving["arrival"] = "open"
    if args.queue_depth:
        serving["queue_depth"] = args.queue_depth
    if args.lb:
        serving["lb"] = args.lb
    if serving:
        # per-type knobs reset on a cross-type --workload override; either
        # way the serving knobs layer on top (make_workload still rejects
        # them for non-rpc workloads — no silent ignore)
        base_params = (() if args.workload and args.workload != spec.workload
                       else spec.workload_params)
        overrides["workload_params"] = tuple(
            {**dict(base_params), **serving}.items()
        )
    run = spec.run(
        outdir=(None if args.structured or args.weave != "post"
                else base + ".logs"),
        seed=args.seed,
        exporters=(
            ChromeTraceExporter(base + ".chrome.json"),
            SpanJSONLExporter(base + ".spans.jsonl"),
        ),
        structured=args.structured,
        weave=args.weave,
        jobs=args.jobs if args.weave == "sharded" else 1,
        **overrides,
    )
    print(f"[trace] {trace_summary(run.spans)}")
    print(run.report())
    if any(s.name == "RpcRequest" for s in run.spans):
        # per-request drill-down: tail percentiles + the slowest request's
        # critical path + diagnose() on its trace alone
        print("[trace] " + request_report(run.spans).replace("\n", "\n[trace] "))
    logs = ("structured fast path, no logs" if args.structured
            else "woven in-sim, no logs"
            if args.weave != "post" else f"logs in {base}.logs/")
    print(f"[trace] exported {base}.chrome.json + .spans.jsonl "
          f"(weave={args.weave}, {logs})")
    if not run.ok:
        raise SystemExit(1)


def _run_diag_bench(args) -> None:
    """Run the scored diagnosis benchmark (benchmarks/diag_bench.py) and
    write its leaderboard payload under ``--outdir``."""
    try:
        from benchmarks import diag_bench          # repo root on sys.path
    except ImportError:                            # installed package: load by path
        import importlib.util

        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        path = os.path.join(root, "benchmarks", "diag_bench.py")
        if not os.path.exists(path):
            raise SystemExit(
                "benchmarks/diag_bench.py not found; run from the repo root "
                "or use `python -m benchmarks.diag_bench`"
            )
        spec = importlib.util.spec_from_file_location("diag_bench", path)
        diag_bench = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(diag_bench)
    payload = diag_bench.collect(smoke=args.diag_smoke, jobs=args.jobs)
    os.makedirs(args.outdir, exist_ok=True)
    out = os.path.join(
        args.outdir, "BENCH_diag.smoke.json" if args.diag_smoke else "BENCH_diag.json"
    )
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    conf = payload["curated"]["confusion"]
    print(f"[diag-bench] curated macro recall {conf['macro_recall']:.2f}, "
          f"component accuracy {conf['component_accuracy']:.2f}, "
          f"healthy FPR {conf['healthy_fpr']:.2f}")
    print(f"[diag-bench] wrote {out}")


def _list_scenarios(args) -> None:
    from ..sim.scenarios import SCENARIOS

    workload = args.workload or None
    rows = [
        (name, spec) for name, spec in SCENARIOS.items()
        if workload is None or spec.workload == workload
    ]
    if not rows:
        print(f"no scenarios pinned to workload {workload!r}")
        return
    print(f"{'scenario':24s} {'workload':10s} {'expected diagnosis':28s} description")
    for name, spec in rows:
        expected = ",".join(spec.expected_classes) or "(clean)"
        print(f"{name:24s} {spec.workload:10s} {expected:28s} {spec.description}")


def _list_mitigations() -> None:
    from ..sim.mitigation import list_mitigations, mitigation_type

    print(f"{'mitigation':22s} {'masks':32s} description")
    for name in list_mitigations():
        cls = mitigation_type(name)
        masks = ",".join(cls.masks) or "-"
        print(f"{name:22s} {masks:32s} {cls().describe()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--chips-per-pod", type=int, default=4)
    ap.add_argument("--segments", type=int, default=8)
    ap.add_argument("--slow-chip", default="", help="chip name to slow, e.g. pod1.chip02")
    ap.add_argument("--slow-factor", type=float, default=3.0)
    ap.add_argument("--scenario", default="",
                    help="run a named fault scenario from sim/scenarios.py instead")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the scenario's fault-plan seed")
    ap.add_argument("--workload", default="",
                    help="workload type driving the scenario (collective, rpc, "
                         "storage, pipeline); also filters --list-scenarios")
    ap.add_argument("--workloads", default="",
                    help="comma list: run every sweep scenario under each of "
                         "these workload types (the workload sweep axis)")
    ap.add_argument("--mitigation", default="",
                    help="remediation policy attached to the scenario "
                         "(do_nothing, retransmit, disable_and_reroute, ...)")
    ap.add_argument("--mitigations", default="",
                    help="comma list: run every sweep cell under each of "
                         "these policies and print the score_mitigations() "
                         "scoreboard (the mitigation sweep axis)")
    ap.add_argument("--magnitudes", default="",
                    help="comma list of fault magnitudes: run every sweep "
                         "cell at each scaled fault intensity (the "
                         "detection-sensitivity axis, e.g. 0.0,0.25,1.0)")
    ap.add_argument("--arrival-rate", default="",
                    help="rpc serving: open-loop Poisson arrival rate in "
                         "requests/s; a comma list under --sweep fans out "
                         "the arrival-rate axis (e.g. 1e3,1e5,2e6)")
    ap.add_argument("--queue-depth", type=int, default=0,
                    help="rpc serving: bound each backend's FIFO; arrivals "
                         "beyond it are deterministically dropped (NACKed)")
    ap.add_argument("--lb", default="",
                    help="rpc serving: frontend load-balancing policy "
                         "(round_robin, least_loaded, power_of_two_choices)")
    ap.add_argument("--list-scenarios", action="store_true")
    ap.add_argument("--list-mitigations", action="store_true")
    ap.add_argument("--diag-bench", action="store_true",
                    help="run the scored diagnosis benchmark "
                         "(benchmarks/diag_bench.py) and write BENCH_diag.json "
                         "under --outdir")
    ap.add_argument("--diag-smoke", action="store_true",
                    help="with --diag-bench: smoke sizes (the tier-1 gate)")
    ap.add_argument("--sweep", action="store_true",
                    help="run a (scenario x seed) sweep through sim/sweep.py")
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker processes for --sweep (cells are independent)")
    ap.add_argument("--seeds", default="0:4",
                    help="sweep seeds: 'lo:hi' range or comma list (default 0:4)")
    ap.add_argument("--scenarios", default="",
                    help="comma-separated sweep scenarios (default: whole library)")
    ap.add_argument("--sweep-pods", type=int, default=0,
                    help="override every sweep scenario's pod count")
    ap.add_argument("--sweep-chips-per-pod", type=int, default=0,
                    help="override every sweep scenario's chips per pod")
    ap.add_argument("--fabric", default="",
                    help="sweep topology fabric: 'mesh' or 'fat-tree'")
    ap.add_argument("--structured", action="store_true",
                    help="zero-parse fast path: simulators hand Event records "
                         "straight to the weavers (identical output, no text "
                         "log round-trip)")
    ap.add_argument("--weave", default="post",
                    help="span assembly: 'post' weaves after the run (default), "
                         "'inline' weaves during it (streaming weaver), "
                         "'sharded' adds --jobs-way parallel export, "
                         "'columnar' keeps net records in column arrays end "
                         "to end; all modes emit byte-identical SpanJSONL")
    ap.add_argument("--outdir", default="results/traces")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    args = ap.parse_args()

    from ..sim.scenarios import WEAVE_MODES

    if args.weave not in WEAVE_MODES:
        # one typed, self-describing rejection instead of a KeyError deep
        # in the weave plumbing (argparse choices would catch the CLI case
        # but not programmatic callers of main())
        raise SystemExit(
            f"unknown --weave mode {args.weave!r}; valid modes: "
            f"{', '.join(WEAVE_MODES)}"
        )
    if args.weave != "post" and args.structured:
        raise SystemExit(
            f"--structured is the post-hoc zero-parse path; --weave "
            f"{args.weave} weaves during the run and replaces it (drop one)"
        )
    if args.sweep and args.weave == "sharded":
        raise SystemExit(
            "--weave sharded parallelizes one run's export; a sweep already "
            "fans cells over --jobs workers (use --weave inline)"
        )
    if args.list_scenarios:
        _list_scenarios(args)
        return
    if args.list_mitigations:
        _list_mitigations()
        return
    if args.diag_bench:
        _run_diag_bench(args)
        return
    if args.sweep:
        _run_sweep(args)
        return
    if args.scenario:
        if args.workloads or args.mitigations or args.magnitudes:
            axis = ("--workloads" if args.workloads
                    else "--mitigations" if args.mitigations
                    else "--magnitudes")
            raise SystemExit(
                f"{axis} is a sweep axis; with --scenario use the singular "
                f"flag (or add --sweep to fan "
                f"{args.scenario!r} out across the axis)"
            )
        _run_scenario(args)
        return
    if (args.workload or args.workloads or args.mitigation
            or args.mitigations or args.magnitudes or args.arrival_rate
            or args.queue_depth or args.lb):
        # the compiled-program training path below has no workload axis;
        # dropping the flag silently would trace the wrong workload
        raise SystemExit(
            "--workload/--workloads/--mitigation/--mitigations/--magnitudes/"
            "--arrival-rate/--queue-depth/--lb require --scenario or --sweep "
            "(the default path always traces the compiled training program "
            "unmitigated)"
        )

    from ..core import (
        ChromeTraceExporter,
        JaegerJSONExporter,
        OTLPJSONExporter,
        SourceSpec,
        SpanJSONLExporter,
        TraceSpec,
        assemble_traces,
        component_breakdown,
        straggler_report,
        trace_summary,
    )
    from ..sim import run_training_sim
    from ..sim.workload import OpSpec, ProgramSpec

    # -- build the program from the dry-run artifact ---------------------------
    rec_path = os.path.join(args.dryrun_dir, f"{args.arch}.{args.shape}.16x16.json")
    if os.path.exists(rec_path):
        with open(rec_path) as f:
            rec = json.load(f)
        flops = rec["cost"]["flops"]
        hbm = rec["cost"]["bytes_accessed"]
        coll_ops = [
            (k, v["bytes"] / max(v["count"], 1), v["count"])
            for k, v in rec["collectives"]["per_kind"].items()
            if v["count"]
        ]
        print(f"[trace] program from dry-run artifact {rec_path}")
    else:
        flops, hbm, coll_ops = 2e13, 5e11, [("all-gather", 3e7, 16), ("all-reduce", 1e8, 2)]
        print("[trace] no dry-run artifact found; using a synthetic program")

    ops = []
    n_seg = args.segments
    per_seg_coll = []
    for kind, avg_bytes, count in coll_ops:
        per_seg_coll.append((kind, avg_bytes, max(1, count // n_seg)))
    for s in range(n_seg):
        ops.append(OpSpec(name=f"{args.shape}.seg{s}", kind="compute",
                          flops=flops / n_seg, bytes=hbm / n_seg))
        for kind, avg_bytes, per_seg in per_seg_coll:
            for j in range(min(per_seg, 2)):   # cap events per segment
                ops.append(OpSpec(name=f"{kind}.s{s}.{j}", kind=kind,
                                  coll_bytes=avg_bytes))
    if args.shape == "train_4k":
        ops.append(OpSpec(name="grad.sync", kind="all-reduce",
                          coll_bytes=hbm / 64, group="dcn"))
    program = ProgramSpec(name=args.shape, ops=ops)

    # -- simulate ---------------------------------------------------------------
    if args.weave == "sharded":
        raise SystemExit(
            "--weave sharded re-simulates per export shard and needs a "
            "seedable scenario (use --scenario or --sweep); the "
            "compiled-program path supports --weave inline"
        )
    os.makedirs(args.outdir, exist_ok=True)
    logdir = os.path.join(args.outdir, f"{args.arch}.{args.shape}.logs")
    scale = {args.slow_chip: args.slow_factor} if args.slow_chip else None
    sink = None
    if args.weave in ("inline", "columnar"):
        from ..core.streaming import StreamingWeaver

        sink = StreamingWeaver(columnar=(args.weave == "columnar"))
    cluster = run_training_sim(
        program, n_steps=args.steps, n_pods=args.pods,
        chips_per_pod=args.chips_per_pod,
        outdir=None if (args.structured or sink is not None) else logdir,
        compute_scale=scale,
        structured=args.structured, sink=sink,
    )
    print(f"[trace] simulated {args.steps} steps on {args.pods}x{args.chips_per_pod} chips "
          f"-> {cluster.sim.events_executed} DES events, "
          f"virtual time {cluster.sim.now/1e12:.3f}s"
          + (" [structured fast path]" if args.structured else "")
          + (f" [{args.weave} weave]" if sink is not None else ""))

    # -- Columbo: declarative spec over the tagged simulator logs (or, on the
    # fast path, over the structured event streams the sims captured; on the
    # inline path the spans are already woven) ---------------------------------
    base = os.path.join(args.outdir, f"{args.arch}.{args.shape}")
    exporters = [
        JaegerJSONExporter(base + ".jaeger.json"),
        ChromeTraceExporter(base + ".chrome.json"),
        OTLPJSONExporter(base + ".otlp.json"),
        SpanJSONLExporter(base + ".spans.jsonl"),
    ]
    if sink is not None:
        from ..core.session import stream_to

        if args.weave == "columnar":
            # the .spans.jsonl artifact renders array-natively; the other
            # formats walk Span objects, so materialize for them
            woven = sink.finish_columns()
            woven.render_jsonl(base + ".spans.jsonl")
            spans = woven.to_spans()
            stream_to(spans, exporters[:-1])
        else:
            spans = sink.finish()
            stream_to(spans, exporters)
    else:
        if args.structured:
            sources = [
                SourceSpec(sim_type=st, events=evs)
                for st, evs in cluster.structured_sources()
            ]
        else:
            sources = [
                SourceSpec(sim_type=st, paths=ps) if len(ps) > 1
                else SourceSpec(sim_type=st, path=ps[0])
                for st, ps in sorted(cluster.log_paths().items())
            ]
        spec = TraceSpec(sources=sources, exporters=exporters)
        session = spec.run()
        spans = session.spans
    print(f"[trace] {trace_summary(spans)}")
    traces = assemble_traces(spans)
    first = traces[sorted(traces)[0]]
    bd = component_breakdown(first)
    print("[trace] per-component breakdown of step 0 (us):")
    for comp, us in sorted(bd.items(), key=lambda kv: -kv[1])[:12]:
        print(f"    {comp:28s} {us:12.1f}")
    rep = straggler_report(spans)
    if rep["stragglers"]:
        print(f"[trace] stragglers detected: {rep['stragglers']}")
    from ..core import diagnose

    diag = diagnose(spans)
    if diag.findings:
        print("[trace] diagnose():")
        for f in diag.findings:
            print(f"    {f}")
    print(f"[trace] exported {base}.{{jaeger,chrome,otlp}}.json + .spans.jsonl")


if __name__ == "__main__":
    main()
