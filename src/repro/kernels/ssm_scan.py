"""Mamba-1 selective scan — Pallas TPU kernel.

h_t = a_t * h_{t-1} + bx_t (diagonal in (Di, N)); y_t = sum_N h_t * c_t.
Sequential in t, parallel in (batch, channel block): grid = (B, Di/Bd).
Tiles: a/bx (L, Bd, N) stream per time step from VMEM blocks; the state
(Bd, N) persists in registers across the fori_loop; y (L, Bd) is written
as it is produced.  The N (state) dim is small (16) and kept whole.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(a_ref, bx_ref, c_ref, h0_ref, y_ref, hT_ref, *, L: int):
    h = h0_ref[0].astype(jnp.float32)                     # (Bd, N)

    def body(t, h):
        h = a_ref[0, t].astype(jnp.float32) * h + bx_ref[0, t].astype(jnp.float32)
        y = jnp.sum(h * c_ref[0, t].astype(jnp.float32)[None, :], axis=1)
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, L, body, h)
    hT_ref[0] = h.astype(hT_ref.dtype)


def ssm_scan_fwd(
    a: jax.Array,            # (B, L, Di, N)
    bx: jax.Array,           # (B, L, Di, N)
    c: jax.Array,            # (B, L, N)
    h0: jax.Array,           # (B, Di, N)
    block_d: int = 128,
    interpret: bool = False,
):
    B, L, Di, N = a.shape
    bd = min(block_d, Di)
    assert Di % bd == 0
    nd = Di // bd
    kernel = functools.partial(_ssm_kernel, L=L)
    y, h_T = pl.pallas_call(
        kernel,
        grid=(B, nd),
        in_specs=[
            pl.BlockSpec((1, L, bd, N), lambda b, d: (b, 0, d, 0)),
            pl.BlockSpec((1, L, bd, N), lambda b, d: (b, 0, d, 0)),
            pl.BlockSpec((1, L, N), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, bd, N), lambda b, d: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, bd), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, bd, N), lambda b, d: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, Di), a.dtype),
            jax.ShapeDtypeStruct((B, Di, N), h0.dtype),
        ],
        interpret=interpret,
    )(a, bx, c, h0)
    return y, h_T
