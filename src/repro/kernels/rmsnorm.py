"""Fused RMSNorm — Pallas TPU kernel (row blocks, f32 reduction in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    n = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (n * (1.0 + s_ref[...].astype(jnp.float32))[None, :]).astype(o_ref.dtype)


def rmsnorm_fwd(
    x: jax.Array,            # (R, D)
    scale: jax.Array,        # (D,)
    eps: float = 1e-6,
    block_r: int = 256,
    interpret: bool = False,
) -> jax.Array:
    R, D = x.shape
    br = min(block_r, R)
    assert R % br == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, scale)
