"""Declarative trace composition: ``TraceSpec`` -> ``TraceSession`` ->
``ExecutionEngine``.

The paper's Columbo Scripts compose simulator-specific pipelines (parser ->
actors -> SpanWeaver -> exporter) into end-to-end traces.  This module is
the composition API's second generation:

* :class:`TraceSpec` — a declarative description (dataclass or plain dict)
  of sources, actors, exporters and execution policy.  Specs are inert
  data: build them in config files, ship them over the wire, diff them.
* :class:`TraceSession` — the fluent imperative builder (successor to
  ``ColumboScript``) with structured, exception-raising state transitions.
* :class:`ExecutionEngine` — one engine behind both, unifying offline-sync,
  threaded-online, and *sharded* execution (N time-ordered log shards per
  simulator type merge into one weaver), with streaming export: attached
  exporters consume spans incrementally instead of post-hoc lists.

Simulator types resolve through a :class:`~repro.core.registry.
SimulatorRegistry`, so custom types (storage sims, DPU sims) registered by
user code weave exactly like the built-in host/device/net trio::

    spec = TraceSpec.from_dict({
        "sources": [
            {"sim_type": "host",   "path": "logs/host-host0.log"},
            {"sim_type": "device", "paths": ["logs/dev-0.log", "logs/dev-1.log"]},
            {"sim_type": "net",    "path": "logs/net.log"},
        ],
        "policy": {"mode": "sync"},
    })
    session = spec.build()
    spans = session.run()
"""
from __future__ import annotations

import gc
import os
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from .context import ContextRegistry
from .errors import SessionNotRunError, SessionStateError, TraceSpecError
from .events import Event, sim_type_value
from .exporters import Exporter
from .pipeline import (
    Actor,
    IterableProducer,
    LineIterProducer,
    LogFileProducer,
    MergedProducer,
    Pipeline,
    Producer,
)
from .registry import DEFAULT_REGISTRY, SimulatorRegistry
from .span import Span
from .weaver import SpanWeaver, finalize_spans

# ---------------------------------------------------------------------------
# Log tagging (sim side writes "# columbo sim_type=<type>" as its first line)
# ---------------------------------------------------------------------------

SIM_TYPE_TAG = "# columbo sim_type="


def sniff_sim_type(path: Union[str, os.PathLike]) -> Optional[str]:
    """Read a log's leading lines for the simulator-type tag the component
    sims emit.  Returns None when untagged (or when ``path`` is a FIFO —
    sniffing a pipe would consume the stream)."""
    path = os.fspath(path)
    try:
        import stat

        if stat.S_ISFIFO(os.stat(path).st_mode):
            return None
        with open(path, "r") as f:
            for _ in range(5):
                line = f.readline()
                if not line:
                    break
                if line.startswith(SIM_TYPE_TAG):
                    return line[len(SIM_TYPE_TAG):].strip()
    except OSError:
        return None
    return None


# ---------------------------------------------------------------------------
# Declarative spec
# ---------------------------------------------------------------------------


@dataclass
class SourceSpec:
    """One simulator-specific pipeline, declaratively.

    Exactly one of ``path`` / ``paths`` / ``events`` / ``lines`` supplies
    the producer; ``paths`` (>= 1 shard) requests sharded execution — the
    shards merge in timestamp order into a single weaver for the type."""

    sim_type: str
    path: Optional[Union[str, os.PathLike]] = None
    paths: Optional[Sequence[Union[str, os.PathLike]]] = None
    events: Optional[Iterable[Event]] = None
    lines: Optional[Iterable[str]] = None
    actors: Sequence[Actor] = ()
    weaver: Optional[SpanWeaver] = None
    weaver_options: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        supplied = [
            name
            for name, v in (
                ("path", self.path),
                ("paths", self.paths),
                ("events", self.events),
                ("lines", self.lines),
            )
            if v is not None
        ]
        if len(supplied) != 1:
            raise TraceSpecError(
                f"SourceSpec needs exactly one of path/paths/events/lines, got {supplied or 'none'}"
            )
        self.sim_type = sim_type_value(self.sim_type)


@dataclass
class ExecutionPolicy:
    """How the engine runs the pipelines.

    * ``mode="sync"``     — single-threaded, ordered by each simulator
      type's registered sync priority (context pushes before polls).
    * ``mode="threaded"`` — one thread per pipeline, for §3.8 online mode
      (named-pipe producers block until the simulation writes).
    * ``poll_timeout``    — blocking-poll timeout for online weaving.
    """

    mode: str = "sync"
    poll_timeout: float = 0.0

    _MODES = ("sync", "threaded")

    def __post_init__(self) -> None:
        if self.mode not in self._MODES:
            raise TraceSpecError(f"unknown execution mode {self.mode!r}; one of {self._MODES}")


@dataclass
class TraceSpec:
    """Declarative description of a whole trace-creation run."""

    sources: List[SourceSpec] = field(default_factory=list)
    exporters: Sequence[Exporter] = ()
    policy: ExecutionPolicy = field(default_factory=ExecutionPolicy)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TraceSpec":
        """Build a spec from plain dicts (config files, JSON, CLI)."""
        try:
            sources = [
                s if isinstance(s, SourceSpec) else SourceSpec(**s)
                for s in d.get("sources", ())
            ]
            pol = d.get("policy", ExecutionPolicy())
            if isinstance(pol, dict):
                pol = ExecutionPolicy(**pol)
        except TypeError as e:
            raise TraceSpecError(str(e)) from e
        return cls(sources=sources, exporters=list(d.get("exporters", ())), policy=pol)

    def build(self, simulators: Optional[SimulatorRegistry] = None) -> "TraceSession":
        """Materialize the spec into a ready-to-run session."""
        session = TraceSession(
            simulators=simulators, poll_timeout=self.policy.poll_timeout
        )
        for src in self.sources:
            session.add_source(src)
        session.attach(*self.exporters)
        return session

    def run(self, simulators: Optional[SimulatorRegistry] = None) -> "TraceSession":
        """Build + run; returns the finished session (spans, stats)."""
        session = self.build(simulators)
        session.run(mode=self.policy.mode)
        return session


# ---------------------------------------------------------------------------
# Execution engine
# ---------------------------------------------------------------------------


class ExecutionEngine:
    """Runs a set of simulator-specific pipelines and streams the woven
    spans to exporters.  One code path serves offline-sync, threaded-online
    and sharded inputs; ``TraceSession`` (and the deprecated
    ``ColumboScript`` shim) sit on top."""

    def __init__(
        self,
        simulators: Optional[SimulatorRegistry] = None,
        poll_timeout: float = 0.0,
    ) -> None:
        self.simulators = simulators or DEFAULT_REGISTRY
        self.context = ContextRegistry()
        self.poll_timeout = poll_timeout
        self.pipelines: List[Pipeline] = []
        self.weavers: List[SpanWeaver] = []
        self.finalize_stats: Dict[str, int] = {}

    def add_pipeline(
        self,
        producer: Producer,
        sim_type,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_options: Any,
    ) -> Pipeline:
        value = sim_type_value(sim_type)
        if weaver is None:
            # raises UnknownSimTypeError for unregistered types — the typed
            # successor of the old bare WEAVERS[sim_type] KeyError
            weaver = self.simulators.make_weaver(
                value, self.context, poll_timeout=self.poll_timeout, **weaver_options
            )
        self.weavers.append(weaver)
        p = Pipeline(producer, actors, weaver, name=f"{value}-{len(self.pipelines)}")
        p.sim_type = value  # type: ignore[attr-defined]  # sync-ordering tag
        self.pipelines.append(p)
        return p

    # -- execution ---------------------------------------------------------------

    def execute(self, mode: str = "sync", join_timeout: Optional[float] = None) -> List[Span]:
        if mode == "threaded":
            # online mode: pipelines run in parallel with the simulation;
            # FIFO producers block until writers appear, weavers block-poll.
            # join_timeout bounds the wait on a wedged writer (the reader
            # threads are daemons); whatever was woven still finalizes.
            for p in self.pipelines:
                p.start()
            for p in self.pipelines:
                p.join(timeout=join_timeout)
        elif mode == "sync":
            # honor causal pushes before polls where possible; deferred
            # resolution covers the rest.  Stable sort keeps insertion
            # order within one simulator type.
            for p in sorted(
                self.pipelines, key=lambda p: self.simulators.sync_priority(p.sim_type)
            ):
                p.run_sync()
        else:
            raise TraceSpecError(f"unknown execution mode {mode!r}")
        spans: List[Span] = []
        for w in self.weavers:
            spans.extend(w.spans)
        self.finalize_stats = finalize_spans(spans, self.context)
        spans.sort(key=lambda s: (s.context.trace_id, s.start, s.context.span_id))
        return spans

    def stream_to(self, spans: Sequence[Span], exporters: Sequence[Exporter]) -> None:
        """Fan finished spans out to exporters (see :func:`stream_to`)."""
        stream_to(spans, exporters)


def stream_to(spans: Sequence[Span], exporters: Sequence[Exporter]) -> None:
    """Fan finished spans out to exporters incrementally.  Exporters are
    isolated from each other: one raising mid-stream still lets the rest
    write their output, and its own ``finish()`` runs so partial output
    flushes instead of sitting in an open buffer.  The first error
    re-raises after every exporter has had its chance.

    Module-level because every span-producing path shares it: the post-hoc
    :class:`TraceSession` and the inline weave's ``InlineTraceSession``.

    The cyclic GC pauses for the duration (the EventKernel.run rationale:
    encoding allocates heavily but makes no cycles, and gen-2 collections
    re-walking the multi-million-object span graph dominate export time at
    fleet scale)."""
    errors: List[Exception] = []
    paused = gc.isenabled()
    if paused:
        gc.disable()
    try:
        for e in exporters:
            try:
                e.begin()
                try:
                    for s in spans:
                        e.consume(s)
                except Exception as ex:
                    errors.append(ex)
                    try:
                        e.finish()
                    except Exception:
                        pass
                else:
                    e.finish()
            except Exception as ex:
                errors.append(ex)
    finally:
        if paused:
            gc.enable()
    if errors:
        raise errors[0]


# ---------------------------------------------------------------------------
# Session (fluent successor to ColumboScript)
# ---------------------------------------------------------------------------


class _State(Enum):
    BUILDING = "building"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


class TraceSession:
    """Fluent trace-composition session over one :class:`ExecutionEngine`.

    Lifecycle: compose (``add_*``/``attach``) -> ``run()`` -> read
    (``spans``/``stats``/``export``).  Out-of-order use raises
    :class:`SessionStateError` / :class:`SessionNotRunError` rather than
    tripping asserts.
    """

    def __init__(
        self,
        simulators: Optional[SimulatorRegistry] = None,
        poll_timeout: float = 0.0,
    ) -> None:
        self.engine = ExecutionEngine(simulators, poll_timeout=poll_timeout)
        self.poll_timeout = poll_timeout
        self._exporters: List[Exporter] = []
        self._state = _State.BUILDING
        self._spans: Optional[List[Span]] = None

    # -- backward-compatible views over the engine --------------------------------

    @property
    def simulators(self) -> SimulatorRegistry:
        return self.engine.simulators

    @property
    def registry(self) -> ContextRegistry:
        """The shared ContextRegistry (historic ColumboScript name)."""
        return self.engine.context

    @property
    def pipelines(self) -> List[Pipeline]:
        return self.engine.pipelines

    @property
    def weavers(self) -> List[SpanWeaver]:
        return self.engine.weavers

    @property
    def finalize_stats(self) -> Dict[str, int]:
        return self.engine.finalize_stats

    @property
    def state(self) -> str:
        return self._state.value

    # -- composition ------------------------------------------------------------

    def _check_building(self, what: str) -> None:
        if self._state is not _State.BUILDING:
            hint = (
                "create a fresh TraceSession"
                if self._state is _State.FAILED
                else "compose before run()"
            )
            raise SessionStateError(
                f"cannot {what}: session is {self._state.value} ({hint})"
            )

    def add_log(
        self,
        path: Union[str, os.PathLike],
        sim_type=None,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_options: Any,
    ) -> "TraceSession":
        """One simulator log file (or named pipe).  ``sim_type=None``
        auto-detects from the ``# columbo sim_type=`` tag the component
        simulators write."""
        self._check_building("add_log")
        if sim_type is None:
            sim_type = sniff_sim_type(path)
            if sim_type is None:
                raise TraceSpecError(
                    f"{os.fspath(path)!r} carries no sim-type tag; pass sim_type explicitly"
                )
        producer = LogFileProducer(path, self._parser(sim_type, weaver))
        self.engine.add_pipeline(producer, sim_type, actors, weaver, **weaver_options)
        return self

    def add_shards(
        self,
        paths: Sequence[Union[str, os.PathLike]],
        sim_type,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_options: Any,
    ) -> "TraceSession":
        """N time-ordered log shards of one simulator, merged into a single
        coherent stream feeding one weaver (multipod-scale inputs)."""
        self._check_building("add_shards")
        if not paths:
            raise TraceSpecError("add_shards needs at least one shard path")
        producer = MergedProducer(
            [LogFileProducer(p, self._parser(sim_type, weaver)) for p in paths]
        )
        self.engine.add_pipeline(producer, sim_type, actors, weaver, **weaver_options)
        return self

    def add_events(
        self,
        events: Iterable[Event],
        sim_type,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_options: Any,
    ) -> "TraceSession":
        """An in-memory event iterable (tests, replay)."""
        self._check_building("add_events")
        self.engine.add_pipeline(
            IterableProducer(events), sim_type, actors, weaver, **weaver_options
        )
        return self

    def add_lines(
        self,
        lines: Iterable[str],
        sim_type,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_options: Any,
    ) -> "TraceSession":
        """An iterable of raw log lines (sockets, decompressors)."""
        self._check_building("add_lines")
        producer = LineIterProducer(lines, self._parser(sim_type, weaver))
        self.engine.add_pipeline(producer, sim_type, actors, weaver, **weaver_options)
        return self

    def add_source(self, src: SourceSpec) -> "TraceSession":
        """Materialize one declarative :class:`SourceSpec`."""
        kw = dict(actors=src.actors, weaver=src.weaver, **src.weaver_options)
        if src.path is not None:
            return self.add_log(src.path, src.sim_type, **kw)
        if src.paths is not None:
            return self.add_shards(src.paths, src.sim_type, **kw)
        if src.events is not None:
            return self.add_events(src.events, src.sim_type, **kw)
        return self.add_lines(src.lines, src.sim_type, **kw)

    def attach(self, *exporters: Exporter) -> "TraceSession":
        """Attach streaming exporters; they consume spans as ``run()``
        finishes weaving, span by span."""
        self._check_building("attach exporters")
        self._exporters.extend(exporters)
        return self

    def _parser(self, sim_type, weaver: Optional[SpanWeaver]):
        """Parser for a source.  When an explicit weaver accompanies an
        unregistered type we still need a parser, so the lookup is strict
        only for registry-backed weaving."""
        if weaver is not None and sim_type not in self.simulators:
            raise TraceSpecError(
                f"sim type {sim_type_value(sim_type)!r} is unregistered; "
                "log/line sources need a registered parser"
            )
        return self.simulators.make_parser(sim_type)

    # -- execution ---------------------------------------------------------------

    def run(self, mode: str = "sync", join_timeout: Optional[float] = None) -> List[Span]:
        """Execute all pipelines, finalize context propagation, stream the
        spans to attached exporters, and return them.  ``join_timeout``
        bounds the per-pipeline wait in threaded mode."""
        self._check_building("run")
        self._state = _State.RUNNING
        try:
            spans = self.engine.execute(mode=mode, join_timeout=join_timeout)
        except Exception:
            # a partial run leaves woven spans inside the weavers, so a
            # retry on the same session would double-count: terminal state
            self._state = _State.FAILED
            raise
        self._spans = spans
        self._state = _State.DONE
        if self._exporters:
            self.engine.stream_to(spans, self._exporters)
        return spans

    @property
    def spans(self) -> List[Span]:
        if self._spans is None:
            raise SessionNotRunError("no spans yet: call run() first")
        return self._spans

    def export(self, *exporters: Exporter) -> None:
        """Post-hoc export (streams the finished spans through)."""
        self.engine.stream_to(self.spans, exporters)

    @property
    def late_events(self) -> int:
        """Events dropped because their span had already closed (summed
        over weavers; each drop raised a ``LateEventWarning``).  Same
        shape as ``InlineTraceSession.late_events`` so sweep cells record
        the count whichever weave path produced the run."""
        return sum(w.late_events for w in self.weavers)

    # -- stats --------------------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        return {
            "state": self._state.value,
            "pipelines": {
                p.name: {"events_in": p.events_in, "events_out": p.events_out}
                for p in self.pipelines
            },
            "context": self.registry.stats(),
            "finalize": dict(self.finalize_stats),
            "spans": sum(len(w.spans) for w in self.weavers),
            "span_types": {
                sim_type_value(w.sim_type): dict(w.span_type_counts)
                for w in self.weavers
            },
        }
