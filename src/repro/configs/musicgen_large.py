"""musicgen-large [audio] — decoder-only over EnCodec tokens.
48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048.
[arXiv:2306.05284; hf].  EnCodec frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S, d); targets are codebook tokens.
"""
from ..models.config import ModelConfig
from . import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        mlp_act="gelu",            # MusicGen uses standard transformer FFN
        frontend="audio",
        rope_theta=10_000.0,
    ),
    microbatches={"train_4k": 4},
    kv_cache_dtype={"decode_32k": "int8"},
    notes="pure global attention -> long_500k skipped per assignment rule",
)
