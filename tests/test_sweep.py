"""Scale-out engine + sweep tests: the kernel rewrite's byte-identity
contract, parallel-equals-serial sweep equivalence, aggregate() semantics,
the k·MAD degenerate-sample guards, and the engine bench's JSON schema.

The golden files under ``tests/golden/`` were recorded with the
*pre-refactor* per-module ``Sim`` kernel (sim/clock.py at commit
"PR 2"); the discrete-event kernel in sim/engine.py must reproduce them
byte for byte from the same seeds.
"""
import gzip
import importlib.util
import json
import os

import pytest

from repro.core.analysis import (
    RunStats,
    _mad_outliers,
    aggregate,
    diagnose,
    percentile,
    straggler_report,
)
from repro.core.span import Span, SpanContext
from repro.sim import EventKernel, get_scenario
from repro.sim.sweep import SweepSpec, load_sweep, run_sweep
from repro.sim.topology import fat_tree_cluster, scale

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden")


# ---------------------------------------------------------------------------
# Event kernel semantics
# ---------------------------------------------------------------------------


def test_kernel_tie_break_is_scheduling_order():
    k = EventKernel()
    fired = []
    for tag in ("a", "b", "c"):
        k.at(100, lambda t=tag: fired.append(t))
    k.at(50, lambda: fired.append("first"))
    k.run()
    assert fired == ["first", "a", "b", "c"]
    assert k.now == 100


def test_kernel_cancel_skips_without_disturbing_order():
    k = EventKernel()
    fired = []
    h = k.at(10, lambda: fired.append("dead"))
    k.at(10, lambda: fired.append("alive"))
    h.cancel()
    k.run()
    assert fired == ["alive"]
    assert k.events_cancelled == 1


def test_kernel_periodic_task_counts_and_cancels():
    k = EventKernel()
    fired = []
    task = k.every(10, fired.append, n=5)
    k.run(until=25)          # fires at 10, 20
    assert fired == [0, 1]
    task.cancel()
    k.run()
    assert fired == [0, 1]   # pending firing was cancelled, none trail
    assert k.empty()


def test_kernel_periodic_task_n_zero_never_fires():
    # parity with the pre-kernel chains, which checked i >= n before acting
    k = EventKernel()
    fired = []
    k.every(10, fired.append, n=0)
    k.run()
    assert fired == []


def test_kernel_ports_attribute_events():
    k = EventKernel()
    a, b = k.register("sim_a"), k.register("sim_b")
    a.after(5, lambda: None)
    b.after(5, lambda: None)
    b.after(6, lambda: None)
    k.run()
    stats = k.stats()
    assert stats["per_component"] == {"sim_a": 1, "sim_b": 2}
    assert stats["events_executed"] == 3


def test_kernel_rejects_scheduling_into_the_past():
    k = EventKernel()
    k.at(10, lambda: None)
    k.run()
    with pytest.raises(ValueError):
        k.at(5, lambda: None)


# ---------------------------------------------------------------------------
# Byte-identity across the kernel rewrite (golden files are pre-refactor)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "name,seed",
    [("healthy_baseline", 0), ("degraded_ici_link", 3)],
)
def test_span_jsonl_matches_prerefactor_golden(name, seed):
    path = os.path.join(GOLDEN_DIR, f"scenario.{name}.seed{seed}.spans.jsonl.gz")
    with gzip.open(path, "rb") as f:
        golden = f.read().decode()
    run = get_scenario(name).run(seed=seed)
    assert run.span_jsonl == golden, (
        f"{name} seed={seed}: SpanJSONL diverged from the pre-kernel-rewrite "
        f"golden ({len(run.span_jsonl)} vs {len(golden)} bytes)"
    )


# ---------------------------------------------------------------------------
# Sweep: parallel == serial, shards reload, from_jsonl agrees
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_sweep(tmp_path_factory):
    spec = SweepSpec(scenarios=("healthy_baseline", "throttled_chip"), seeds=(0, 5))
    base = tmp_path_factory.mktemp("sweep")
    serial = run_sweep(spec, str(base / "serial"), jobs=1)
    parallel = run_sweep(spec, str(base / "parallel"), jobs=8)
    return spec, serial, parallel


def test_sweep_parallel_equals_serial(small_sweep):
    spec, serial, parallel = small_sweep
    assert [(c.scenario, c.workload, c.mitigation, c.magnitude, c.rate, c.seed)
            for c in serial.cells] == spec.cells()
    assert [(c.scenario, c.workload, c.mitigation, c.magnitude, c.rate, c.seed)
            for c in parallel.cells] == spec.cells()
    for cs, cp in zip(serial.cells, parallel.cells):
        with open(os.path.join(serial.outdir, cs.shard), "rb") as f:
            bytes_serial = f.read()
        with open(os.path.join(parallel.outdir, cp.shard), "rb") as f:
            bytes_parallel = f.read()
        assert bytes_serial == bytes_parallel, (
            f"cell ({cs.scenario}, {cs.seed}): --jobs 8 shard differs from --jobs 1"
        )
        assert cs.ok == cp.ok
        assert cs.stats.detected == cp.stats.detected


def test_sweep_reloads_from_disk(small_sweep):
    _, serial, _ = small_sweep
    reloaded = load_sweep(serial.outdir)
    assert [(c.scenario, c.seed) for c in reloaded.cells] == [
        (c.scenario, c.seed) for c in serial.cells
    ]
    agg_live = serial.aggregate().to_dict()
    agg_reload = reloaded.aggregate().to_dict()
    assert agg_live == agg_reload


@pytest.mark.parametrize("version", [1, 2, 3, 4])
def test_load_sweep_reads_older_schema_payloads(version, tmp_path):
    """sweep.json written by the v1/v2/v3/v4 schemas (fixtures recorded
    from the shapes those releases emitted) must load through the current
    ``load_sweep`` with expected/detected round-tripping and post-hoc
    axis fields defaulting, not KeyError-ing."""
    fixture = os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "data", f"sweep_v{version}.json"
    )
    with open(fixture) as f:
        payload = json.load(f)
    with open(tmp_path / "sweep.json", "w") as f:
        json.dump(payload, f)
    result = load_sweep(str(tmp_path))
    assert len(result.cells) == len(payload["cells"])
    for cell, raw in zip(result.cells, payload["cells"]):
        assert cell.scenario == raw["scenario"]
        assert cell.seed == raw["seed"]
        assert cell.ok == raw["ok"]
        assert list(cell.stats.expected) == raw["stats"]["expected"]
        assert list(cell.stats.detected) == raw["stats"]["detected"]
        # axes that post-date the payload's schema default rather than raise
        assert cell.workload == raw.get("workload")
        assert cell.mitigation == raw.get("mitigation")
        assert cell.magnitude is None
        assert cell.rate is None          # v5's arrival-rate axis defaults
        assert cell.stats.magnitude == 1.0
        assert cell.stats.expected_components == {}
        assert cell.stats.finding_components == {}
        assert cell.stats.diag_wall_s == 0.0
    assert result.spec.arrival_rates is None
    assert result.spec.queue_depth is None and result.spec.lb is None
    # the re-hydrated result still aggregates and reports
    agg = result.aggregate()
    assert agg.n_runs == len(result.cells)
    assert result.report()


def test_sweep_arrival_rate_axis_and_serving_knobs(tmp_path):
    """The arrival-rate axis fans every cell out per rate (6-tuple cells,
    rate-tagged shards) and the scalar queue_depth/lb knobs ride through
    overrides() into the rpc workload, round-tripping via load_sweep."""
    spec = SweepSpec(
        scenarios=("healthy_baseline",), seeds=(0,), workloads=("rpc",),
        arrival_rates=(200.0, 2e6), queue_depth=2, lb="least_loaded",
        n_pods=4,
    )
    assert spec.cells() == [
        ("healthy_baseline", "rpc", None, None, 200.0, 0),
        ("healthy_baseline", "rpc", None, None, 2000000.0, 0),
    ]
    result = run_sweep(spec, str(tmp_path), jobs=1)
    assert all(c.ok for c in result.cells)
    assert [c.rate for c in result.cells] == [200.0, 2e6]
    assert [c.shard for c in result.cells] == [
        os.path.join("shards", "healthy_baseline.rpc.r200.seed0.spans.jsonl"),
        os.path.join("shards", "healthy_baseline.rpc.r2e+06.seed0.spans.jsonl"),
    ]
    assert "2 rates" in result.report()
    reloaded = load_sweep(str(tmp_path))
    assert reloaded.spec.arrival_rates == (200.0, 2e6)
    assert reloaded.spec.queue_depth == 2
    assert reloaded.spec.lb == "least_loaded"
    assert [c.rate for c in reloaded.cells] == [200.0, 2e6]
    assert reloaded.aggregate().to_dict() == result.aggregate().to_dict()


def test_load_sweep_rejects_unknown_schema(tmp_path):
    with open(tmp_path / "sweep.json", "w") as f:
        json.dump({"schema": "columbo.sweep/v999", "scenarios": [], "seeds": [],
                   "cells": []}, f)
    with pytest.raises(ValueError, match="v999"):
        load_sweep(str(tmp_path))


def test_runstats_from_jsonl_agrees_with_from_spans(small_sweep):
    _, serial, _ = small_sweep
    cell = serial.cells[0]
    from_shard = RunStats.from_jsonl(
        os.path.join(serial.outdir, cell.shard),
        scenario=cell.scenario,
        seed=cell.seed,
        expected=cell.stats.expected,
        detected=cell.stats.detected,
    )
    assert from_shard.n_spans == cell.stats.n_spans
    assert set(from_shard.component_us) == set(cell.stats.component_us)
    for comp, samples in cell.stats.component_us.items():
        assert from_shard.component_us[comp] == pytest.approx(samples, rel=1e-6)
    assert from_shard.critical_components == cell.stats.critical_components


def test_sweep_merge_shards_is_globally_ordered(small_sweep, tmp_path):
    _, serial, _ = small_sweep
    out = str(tmp_path / "merged.jsonl")
    n = serial.merge_shards(out)
    assert n == sum(c.stats.n_spans for c in serial.cells)
    keys, span_ids, parent_ok = [], set(), True
    with open(out) as f:
        for line in f:
            r = json.loads(line)
            keys.append((r["trace_id"], r["start_us"], r["span_id"]))
            span_ids.add(r["span_id"])
    assert keys == sorted(keys)
    # cells reset id counters, so without disambiguation span/trace ids
    # would collide across shards and stitch unrelated runs together
    assert len(span_ids) == n, "merged span ids must be globally unique"
    with open(out) as f:
        for line in f:
            r = json.loads(line)
            if r["parent_id"] is not None and r["parent_id"] not in span_ids:
                parent_ok = False
    assert parent_ok, "rewritten parent ids must resolve within the merged file"


# ---------------------------------------------------------------------------
# aggregate() on hand-built inputs
# ---------------------------------------------------------------------------


def _span(name, comp, sim_type, start, end, span_id, trace_id=1, parent=None):
    return Span(
        name=name, start=start, end=end,
        context=SpanContext(trace_id=trace_id, span_id=span_id),
        parent=parent, component=comp, sim_type=sim_type,
    )


def test_aggregate_hand_built():
    runs = [
        RunStats(
            scenario="s_faulty", seed=0,
            expected=("link_loss",), detected=("link_loss",),
            wall_s=1.0, events=100, n_spans=2,
            component_us={"net:l0": [10.0, 30.0]},
            critical_components=["net:l0"],
        ),
        RunStats(
            scenario="s_faulty", seed=1,
            expected=("link_loss",), detected=(),      # missed detection
            wall_s=1.0, events=100, n_spans=2,
            component_us={"net:l0": [20.0, 40.0]},
            critical_components=["net:l0"],
        ),
        RunStats(
            scenario="s_clean", seed=0,
            expected=(), detected=("link_loss",),      # false positive
            wall_s=0.5, events=50, n_spans=1,
            component_us={"net:l0": [50.0], "host:h0": [5.0]},
            critical_components=["host:h0"],
        ),
        RunStats(
            scenario="s_clean", seed=1,
            expected=(), detected=(),
            wall_s=0.5, events=50, n_spans=1,
            component_us={"host:h0": [15.0]},
            critical_components=["host:h0"],
        ),
    ]
    rep = aggregate(runs)
    assert rep.n_runs == 4
    assert rep.scenarios == ["s_faulty", "s_clean"]
    assert rep.ok_runs == 2          # one miss, one false positive
    d = rep.detection["link_loss"]
    assert d["injected_runs"] == 2 and d["detected"] == 1
    assert d["detection_rate"] == 0.5
    assert d["clean_runs"] == 2 and d["false_positives"] == 1
    assert d["false_positive_rate"] == 0.5
    lat = rep.component_latency["net:l0"]
    assert lat["n"] == 5
    assert lat["p50"] == 30.0 and lat["max"] == 50.0
    cp = rep.critical_path_freq
    assert cp["host:h0"]["count"] == 2 and cp["net:l0"]["fraction"] == 0.5
    assert rep.events_total == 300
    # report() renders every section without blowing up
    text = rep.report()
    assert "link_loss" in text and "net:l0" in text


def test_runstats_from_spans_and_roundtrip():
    spans = [
        _span("HostStep", "h0", "host", 0, 100, span_id=1),
        _span("DataLoad", "h0", "host", 0, 40, span_id=2,
              parent=SpanContext(trace_id=1, span_id=1)),
        _span("Op", "c0", "device", 40, 100, span_id=3,
              parent=SpanContext(trace_id=1, span_id=1)),
    ]
    rs = RunStats.from_spans(spans, scenario="hand", seed=7, expected=(), detected=())
    assert rs.n_spans == 3
    # durations are ps -> µs; 100 ps is 1e-4 µs
    assert rs.component_us["host:h0"] == pytest.approx([1e-4, 0.4e-4])
    assert rs.critical_components == ["host:h0"]   # largest critical-path share
    assert RunStats.from_dict(rs.to_dict()) == rs


def test_percentile_interpolates():
    assert percentile([], 99) == 0.0
    assert percentile([7.0], 50) == 7.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == 2.5
    assert percentile([1.0, 2.0, 3.0, 4.0], 100) == 4.0


# ---------------------------------------------------------------------------
# k·MAD degenerate-sample guards (bugfix regression)
# ---------------------------------------------------------------------------


def test_mad_outliers_degenerate_samples():
    # n < 3: cannot call either value an outlier
    assert _mad_outliers({"a": 1.0, "b": 100.0}, k=4.0) == []
    # all-zero medians previously divided by zero / flagged everything
    assert _mad_outliers({"a": 0.0, "b": 0.0, "c": 0.0}, k=4.0) == []
    assert _mad_outliers({"a": 0.0, "b": 0.0, "c": 5.0}, k=4.0) == []
    # healthy population with a genuine outlier still flags
    out = _mad_outliers({"a": 10.0, "b": 11.0, "c": 10.5, "d": 99.0}, k=4.0)
    assert [key for key, _, _ in out] == ["d"]


def test_straggler_report_tiny_population():
    spans = [
        _span("DeviceProgram", "c0", "device", 0, 0, span_id=1),
        _span("DeviceProgram", "c1", "device", 0, 0, span_id=2),
    ]
    rep = straggler_report(spans)   # 2 components, zero medians
    assert rep["stragglers"] == []


def test_two_pod_scenario_has_no_degenerate_findings():
    """Regression: a 2-pod x 1-chip topology (2 chips, 2 hosts — every
    population below the k·MAD minimum) must diagnose clean, not divide by
    zero or flag everything."""
    from dataclasses import replace

    spec = replace(get_scenario("healthy_baseline"), n_pods=2, chips_per_pod=1)
    run = spec.run(seed=0)
    assert run.diagnosis.findings == []
    assert run.ok
    assert straggler_report(run.spans)["stragglers"] == []


# ---------------------------------------------------------------------------
# Topology generators
# ---------------------------------------------------------------------------


def test_fat_tree_scales_linearly_and_routes():
    t64 = fat_tree_cluster(64, chips_per_pod=2)
    t128 = fat_tree_cluster(128, chips_per_pod=2)
    # linear, not quadratic: doubling pods roughly doubles links
    assert len(t128.links) < 2.5 * len(t64.links)
    # mesh comparison: 64-pod mesh has 64*63/2 = 2016 DCN links alone
    dcn_links = [l for l in t64.links if l.startswith("dcn.")]
    assert len(dcn_links) < 200
    # host -> ToR -> spine -> ToR -> host
    route = t64.route("host0", "host63")
    assert [l.split(".")[0] for l in route] == ["dcn"] * 4
    # chips in different racks reach each other through the fabric
    assert t64.route("pod0.chip00", "pod63.chip00")


def test_scale_dispatches_fabrics():
    assert scale(pods=4, fabric="mesh").name.startswith("tpu_")
    assert scale(pods=16, fabric="fat-tree").name.startswith("fattree_")
    with pytest.raises(ValueError):
        scale(pods=4, fabric="clos")


# ---------------------------------------------------------------------------
# Bench JSON schema
# ---------------------------------------------------------------------------


def _load_engine_bench():
    spec = importlib.util.spec_from_file_location(
        "engine_bench", os.path.join(REPO, "benchmarks", "engine_bench.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _validate_bench_payload(payload):
    assert payload["schema"] == "columbo.engine_bench/v7"
    assert isinstance(payload["smoke"], bool)
    assert {"python", "platform"} <= set(payload["host"])
    k = payload["kernel"]
    assert k["n_events"] > 0 and k["events_per_sec"] > 0 and k["wall_s"] >= 0
    assert payload["topology_scaling"], "needs at least one topology row"
    for row in payload["topology_scaling"]:
        assert {"pods", "chips", "links", "events", "wall_s", "events_per_sec",
                "virtual_s"} <= set(row)
        assert row["events"] > 0
    assert payload["pipeline"], "needs at least one per-stage pipeline row"
    for row in payload["pipeline"]:
        assert {"pods", "chips", "events", "log_lines", "parsed_events", "spans",
                "stages_s", "inline_stages_s", "columnar_stages_s",
                "full_sim_events_per_sec", "end_to_end_events_per_sec",
                "full_sim_speedup", "end_to_end_speedup", "inline_speedup",
                "columnar_speedup"} <= set(row)
        assert set(row["stages_s"]) == {
            "simulate", "format", "parse", "weave", "inline_weave",
            "columnar_weave", "export", "analyze"
        }
        assert all(v >= 0 for v in row["stages_s"].values())
        assert set(row["inline_stages_s"]) == {
            "sim_weave", "finish", "export", "analyze"
        }
        assert all(v >= 0 for v in row["inline_stages_s"].values())
        assert set(row["columnar_stages_s"]) == {
            "sim_weave", "finish", "export", "analyze"
        }
        assert all(v >= 0 for v in row["columnar_stages_s"].values())
        assert set(row["full_sim_events_per_sec"]) == {"text", "structured"}
        assert all(v > 0 for v in row["full_sim_events_per_sec"].values())
        ee = row["end_to_end_events_per_sec"]
        assert set(ee) == {"text", "structured", "inline", "columnar"}
        assert all(v > 0 for v in ee.values())
        # the parse stage consumes the rendered text lines: every line
        # except the per-writer "# columbo" headers parses into an event
        assert 0 < row["parsed_events"] < row["log_lines"]
        assert row["spans"] > 0
    assert payload["workloads"], "needs at least one per-workload row"
    workload_types = {r["workload"] for r in payload["workloads"]}
    assert workload_types >= {"collective", "rpc", "storage", "pipeline"}
    for row in payload["workloads"]:
        assert {"workload", "pods", "chips", "unit", "units", "events",
                "wall_s", "events_per_sec", "units_per_sec",
                "virtual_s"} <= set(row)
        assert row["events"] > 0 and row["events_per_sec"] > 0
        assert row["units"] > 0 and row["units_per_sec"] > 0
    rpc_rows = [r for r in payload["workloads"] if r["workload"] == "rpc"]
    assert all(r["unit"] == "request" for r in rpc_rows)
    mit = payload["mitigations"]
    assert {"scenario", "pods", "rows"} <= set(mit)
    policies = {r["policy"] for r in mit["rows"]}
    assert policies >= {"unmitigated", "do_nothing", "retransmit",
                        "disable_and_reroute", "evict_straggler",
                        "checkpoint_restore"}
    by_policy = {r["policy"]: r for r in mit["rows"]}
    for row in mit["rows"]:
        assert {"policy", "events", "wall_s", "events_per_sec",
                "overhead_vs_unmitigated"} <= set(row)
        assert row["events"] > 0 and row["events_per_sec"] > 0
    # the baseline policy must be inert: exactly the unmitigated event
    # count, and within the bench's own 10% kernel-overhead assertion
    assert by_policy["do_nothing"]["events"] == by_policy["unmitigated"]["events"]
    assert by_policy["do_nothing"]["overhead_vs_unmitigated"] <= 1.10
    sat = payload["saturation"]
    assert {"pods", "chips", "n_requests", "rate_rps", "min_in_flight",
            "rows"} <= set(sat)
    lbs = {r["lb"] for r in sat["rows"]}
    assert lbs >= {"round_robin", "least_loaded", "power_of_two_choices"}
    assert any(r["queue_depth"] is not None for r in sat["rows"]), (
        "needs a bounded-queue row exercising the drop/retry machinery"
    )
    for row in sat["rows"]:
        assert {"lb", "queue_depth", "timeout_us", "max_retries", "issued",
                "completed", "dropped", "timed_out", "retries",
                "max_in_flight", "goodput", "events", "wall_s",
                "events_per_sec", "requests_per_sec",
                "latency_us"} <= set(row)
        # exact request conservation: every issued request reached exactly
        # one terminal outcome (the bench itself asserts this too)
        assert row["issued"] == (row["completed"] + row["dropped"]
                                 + row["timed_out"]) == sat["n_requests"]
        assert 0.0 <= row["goodput"] <= 1.0
        assert row["max_in_flight"] >= 1
        assert row["events"] > 0 and row["events_per_sec"] > 0
        assert set(row["latency_us"]) == {"p50", "p99", "p99.9", "max"}
        lt = row["latency_us"]
        assert 0 <= lt["p50"] <= lt["p99"] <= lt["p99.9"] <= lt["max"]
        if row["queue_depth"] is None:
            # unbounded saturation rows must hold the concurrency bar
            assert row["max_in_flight"] >= sat["min_in_flight"]
            assert row["dropped"] == 0
    sw = payload["sweep"]
    assert sw["cells"] == len(sw["scenarios"]) * len(sw["seeds"])
    assert sw["wall_s_by_jobs"], "needs at least one --jobs timing"
    for jobs, wall in sw["wall_s_by_jobs"].items():
        assert int(jobs) >= 1 and wall >= 0


def test_committed_bench_json_is_valid():
    path = os.path.join(REPO, "BENCH_engine.json")
    assert os.path.exists(path), "BENCH_engine.json baseline missing from repo"
    with open(path) as f:
        payload = json.load(f)
    _validate_bench_payload(payload)
    assert payload["smoke"] is False, "committed baseline must be a full run"
    # the kernel-to-trace-gap acceptance bar: the recorded structured
    # full-sim rate at 256 pods is >= 3x the PR 3 text baseline
    PR3_FULL_SIM_EV_S = 63_779
    rows = {r["pods"]: r for r in payload["pipeline"]}
    assert 256 in rows, "committed baseline needs the 256-pod pipeline row"
    structured = rows[256]["full_sim_events_per_sec"]["structured"]
    assert structured >= 3 * PR3_FULL_SIM_EV_S, (
        f"recorded structured full-sim rate {structured} ev/s at 256 pods is "
        f"below 3x the PR 3 baseline ({PR3_FULL_SIM_EV_S} ev/s)"
    )
    # inline weaving must beat the structured post-hoc end-to-end rate on
    # every recorded row (the streaming weaver removes the format->parse->
    # weave passes; if it stops paying for itself the recording is stale)
    for pods, row in rows.items():
        ee = row["end_to_end_events_per_sec"]
        assert ee["inline"] >= ee["structured"], (
            f"pods={pods}: recorded inline e2e {ee['inline']} ev/s below "
            f"structured {ee['structured']} ev/s"
        )
        # columnar emit must in turn beat the inline object path on every
        # recorded row: it skips Span construction for every net span and
        # renders JSONL straight from the arrays
        assert ee["columnar"] >= ee["inline"], (
            f"pods={pods}: recorded columnar e2e {ee['columnar']} ev/s below "
            f"inline {ee['inline']} ev/s"
        )
    # the serving-scale acceptance bar: the recorded 256-pod open-loop
    # saturation rows sustained >= 10,000 concurrent in-flight requests
    sat = payload["saturation"]
    assert sat["pods"] == 256, "committed baseline needs the 256-pod fleet"
    assert sat["min_in_flight"] >= 10_000
    unbounded = [r for r in sat["rows"] if r["queue_depth"] is None]
    assert unbounded and all(
        r["max_in_flight"] >= 10_000 for r in unbounded
    ), "recorded saturation rows fell below 10k concurrent in-flight"


def test_engine_bench_kernel_micro_live():
    mod = _load_engine_bench()
    res = mod.bench_kernel(n_events=2_000, n_timers=16)
    assert res["n_events"] == 2_000
    assert res["events_per_sec"] > 0


# ---------------------------------------------------------------------------
# Diagnosis bench (BENCH_diag.json) schema + accuracy floors
# ---------------------------------------------------------------------------


def _validate_confusion(conf):
    assert conf["n_cells"] > 0
    assert 0 <= conf["healthy_false_positives"] <= conf["healthy_cells"]
    for key in ("healthy_fpr", "macro_precision", "macro_recall", "macro_f1",
                "micro_precision", "micro_recall", "component_accuracy"):
        assert 0.0 <= conf[key] <= 1.0, f"{key} out of [0, 1]: {conf[key]}"
    assert conf["diag_wall_s_total"] >= conf["diag_wall_s_max"] >= 0
    assert conf["classes"], "needs at least one scored fault class"
    for name, c in conf["classes"].items():
        assert c["fault_class"] == name
        assert min(c["tp"], c["fn"], c["fp"], c["tn"]) >= 0
        assert c["tp"] + c["fn"] + c["fp"] + c["tn"] == conf["n_cells"]
        for key in ("precision", "recall", "f1", "fpr", "component_accuracy"):
            assert 0.0 <= c[key] <= 1.0
        assert c["component_hits"] <= c["component_total"] <= c["tp"]


def _validate_diag_bench_payload(payload):
    assert payload["schema"] == "columbo.diag_bench/v1"
    assert isinstance(payload["smoke"], bool)
    assert {"python", "platform"} <= set(payload["host"])
    cur = payload["curated"]
    assert cur["cells"] == len(cur["scenarios"]) * len(cur["seeds"])
    _validate_confusion(cur["confusion"])
    # the accuracy floor the bench itself asserts per cell population:
    # every curated fault class fully recalled, healthy baseline silent
    for name, c in cur["confusion"]["classes"].items():
        if c["tp"] + c["fn"]:
            assert c["recall"] == 1.0, f"curated recall floor broken: {name}"
    assert cur["confusion"]["healthy_false_positives"] == 0
    grid = payload["grid"]
    assert set(grid["workloads"]) >= {"collective", "rpc", "storage", "pipeline"}
    assert grid["cells"] == (len(grid["scenarios"]) * len(grid["workloads"])
                             * len(grid["seeds"]))
    _validate_confusion(grid["confusion"])
    sens = payload["sensitivity"]
    assert sens["curves"], "needs at least one detection-sensitivity curve"
    assert 0.0 in sens["magnitudes"] and 1.0 in sens["magnitudes"]
    for curve in sens["curves"]:
        assert {"scenario", "fault_class", "points",
                "detection_threshold"} <= set(curve)
        mags = [p["magnitude"] for p in curve["points"]]
        assert mags == sorted(mags)
        assert set(mags) == set(sens["magnitudes"])
        for p in curve["points"]:
            assert 0.0 <= p["detection_rate"] <= 1.0
        rates = {p["magnitude"]: p["detection_rate"] for p in curve["points"]}
        assert rates[0.0] == 0.0, "a zero-magnitude fault must look healthy"
        assert rates[1.0] == 1.0, "full intensity must stay diagnosable"
        assert curve["detection_threshold"] is not None
    mask = payload["masking"]
    assert set(mask["policies"]) >= {"do_nothing", "retransmit",
                                     "disable_and_reroute", "evict_straggler",
                                     "checkpoint_restore"}
    assert mask["rows"]
    for row in mask["rows"]:
        assert {"scenario", "policy", "expected", "masks_expected", "cells",
                "detection_rate"} <= set(row)
        assert 0.0 <= row["detection_rate"] <= 1.0
    # the masking leaderboard must agree with the declared masks contract:
    # a policy that masks the scenario's class hides it from diagnose()
    for row in mask["rows"]:
        if row["masks_expected"]:
            assert row["detection_rate"] < 1.0, (
                f"{row['policy']} declares masking {row['expected']} on "
                f"{row['scenario']} but diagnosis still fired everywhere"
            )
        else:
            assert row["detection_rate"] == 1.0, (
                f"{row['policy']} does not declare masking on "
                f"{row['scenario']} yet detection degraded"
            )


def test_committed_diag_bench_json_is_valid():
    path = os.path.join(REPO, "BENCH_diag.json")
    assert os.path.exists(path), "BENCH_diag.json leaderboard missing from repo"
    with open(path) as f:
        payload = json.load(f)
    _validate_diag_bench_payload(payload)
    assert payload["smoke"] is False, "committed leaderboard must be a full run"
    # full-grid coverage: the whole curated library across all 4 workloads
    assert len(payload["grid"]["scenarios"]) >= 8
    assert len(payload["grid"]["seeds"]) >= 3
    assert len(payload["sensitivity"]["curves"]) >= 5


def test_diag_bench_smoke_live(tmp_path):
    """The tier-1 gate, run in-process: smoke payload passes the same
    validator as the committed full leaderboard (the bench's internal
    recall-floor asserts fire during collect())."""
    spec = importlib.util.spec_from_file_location(
        "diag_bench", os.path.join(REPO, "benchmarks", "diag_bench.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    payload = mod.collect(smoke=True, jobs=2)
    _validate_diag_bench_payload(payload)
    assert payload["smoke"] is True
