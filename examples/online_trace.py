"""§3.8 online mode: Columbo consumes simulator logs through named pipes
while the simulation runs — nothing is ever persisted to disk.

    PYTHONPATH=src python examples/online_trace.py
"""
import os
import tempfile
import threading

from repro.core import TraceSession, assemble_traces, make_fifo, trace_summary
from repro.sim import run_training_sim, synthetic_program


def main() -> None:
    prog = synthetic_program(n_layers=2, layer_flops=3e11, layer_bytes=1e8, grad_bytes=5e7)
    with tempfile.TemporaryDirectory() as d:
        names = {
            "host": [os.path.join(d, "host-host0.log")],
            "device": [os.path.join(d, "device-pod0.log")],
            "net": [os.path.join(d, "net.log")],
        }
        for ps in names.values():
            for p in ps:
                make_fifo(p)
        print("named pipes created; starting Columbo readers (they block on open)")

        session = TraceSession(poll_timeout=5.0)
        for k, ps in names.items():
            for p in ps:
                session.add_log(p, k)   # FIFOs can't be sniffed: type is explicit

        print("starting the simulation (writers connect to the pipes)")
        t = threading.Thread(
            target=lambda: run_training_sim(prog, n_steps=2, n_pods=1, chips_per_pod=4, outdir=d)
        )
        t.start()
        # threaded mode: one reader thread per pipe, running in parallel
        # with the simulation; run() joins them and finalizes the weave
        spans = session.run(mode="threaded", join_timeout=60)
        t.join()

        stats = session.finalize_stats
        print(f"\nstreamed weave complete: {trace_summary(spans)}")
        print(f"orphans: {stats['orphans']} (0 = every cross-simulator edge resolved)")
        print("log files on disk?", any(os.path.getsize(p) > 0 for ps in names.values()
                                         for p in ps if os.path.exists(p)) and "yes" or
              "no — FIFOs drained in flight")


if __name__ == "__main__":
    main()
