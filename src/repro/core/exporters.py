"""Exporters (Columbo §3.7): convert Columbo's internal span representation
into the formats of existing distributed-tracing tools.

* ``JaegerJSONExporter``  — Jaeger UI's JSON (load via "Upload" in the UI).
* ``ChromeTraceExporter`` — Chrome trace-event format; loads in Perfetto /
                            chrome://tracing; pid=component, tid=span lane.
* ``OTLPJSONExporter``    — OpenTelemetry OTLP/JSON resourceSpans.
* ``SpanJSONLExporter``   — one JSON object per span per line, written as
                            spans stream through (constant memory).
* ``ConsoleExporter``     — human-readable tree (useful in tests/examples).

Exporters are *streaming consumers*: the execution engine calls
``begin()`` once, ``consume(span)`` per span, and ``finish()`` at the end,
so an exporter never has to hold the whole trace in memory (the paper's
"100s of GB of logs" concern).  Formats that need global grouping
(Jaeger/OTLP assemble per-trace/per-resource envelopes) inherit the
buffering default; incremental formats (Chrome events, JSONL) override the
hooks.  The classic ``export(spans)`` one-shot entry point remains and is
defined in terms of the streaming hooks.
"""
from __future__ import annotations

import heapq
import json
import sys
from typing import Any, Dict, IO, Iterable, List, Optional

from .span import Span, assemble_traces

PS_PER_US = 1_000_000


class Exporter:
    """Base streaming consumer.  Subclasses either override ``_export``
    (buffered formats — they receive the full span list) or override the
    ``begin/consume/finish`` hooks directly (incremental formats)."""

    _buf: Optional[List[Span]] = None

    # -- streaming protocol -----------------------------------------------------

    def begin(self) -> None:
        self._buf = []

    def consume(self, span: Span) -> None:
        if self._buf is None:
            self.begin()
        self._buf.append(span)

    def finish(self) -> None:
        buf, self._buf = self._buf or [], None
        self._export(buf)

    # -- one-shot entry point ---------------------------------------------------

    def export(self, spans: Iterable[Span]) -> None:
        self.begin()
        for s in spans:
            self.consume(s)
        self.finish()

    def _export(self, spans: List[Span]) -> None:
        """Buffered-format hook; incremental exporters never reach it."""
        raise NotImplementedError


# ---------------------------------------------------------------------------


class JaegerJSONExporter(Exporter):
    """Jaeger UI's upload-JSON format (per-trace envelopes, buffered)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.payload: Optional[Dict[str, Any]] = None

    def _export(self, spans: List[Span]) -> None:
        procs: Dict[str, Dict[str, Any]] = {}
        proc_ids: Dict[str, str] = {}

        def proc_id(s: Span) -> str:
            key = f"{s.sim_type}:{s.component}"
            if key not in proc_ids:
                pid = f"p{len(proc_ids) + 1}"
                proc_ids[key] = pid
                procs[pid] = {
                    "serviceName": key,
                    "tags": [{"key": "sim_type", "type": "string", "value": s.sim_type}],
                }
            return proc_ids[key]

        data = []
        for tid, trace in sorted(assemble_traces(spans).items()):
            jspans = []
            for s in trace.spans:
                refs = []
                if s.parent is not None:
                    refs.append(
                        {
                            "refType": "CHILD_OF",
                            "traceID": f"{s.parent.trace_id:032x}",
                            "spanID": f"{s.parent.span_id:016x}",
                        }
                    )
                for l in s.links:
                    refs.append(
                        {
                            "refType": "FOLLOWS_FROM",
                            "traceID": f"{l.trace_id:032x}",
                            "spanID": f"{l.span_id:016x}",
                        }
                    )
                jspans.append(
                    {
                        "traceID": s.context.hex_trace(),
                        "spanID": s.context.hex_span(),
                        "operationName": s.name,
                        "references": refs,
                        "startTime": s.start / PS_PER_US,  # µs
                        "duration": max(s.duration, 1) / PS_PER_US,
                        "tags": [
                            {"key": k, "type": "string", "value": str(v)}
                            for k, v in s.attrs.items()
                        ],
                        "logs": [
                            {
                                "timestamp": ts / PS_PER_US,
                                "fields": [{"key": "event", "type": "string", "value": name}]
                                + [
                                    {"key": k, "type": "string", "value": str(v)}
                                    for k, v in attrs.items()
                                ],
                            }
                            for ts, name, attrs in s.events
                        ],
                        "processID": proc_id(s),
                    }
                )
            data.append({"traceID": f"{tid:032x}", "spans": jspans, "processes": procs})
        self.payload = {"data": data}
        if self.path:
            with open(self.path, "w") as f:
                json.dump(self.payload, f)


# ---------------------------------------------------------------------------


class ChromeTraceExporter(Exporter):
    """'X' complete events; pid = component, tid = nesting lane.

    Incremental: each span converts to its trace events in ``consume`` —
    only the converted dicts accumulate, never the spans."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.payload: Optional[Dict[str, Any]] = None
        self._events: List[Dict[str, Any]] = []
        self._pids: Dict[str, int] = {}

    def begin(self) -> None:
        self._events = []
        self._pids = {}

    def consume(self, s: Span) -> None:
        comp = f"{s.sim_type}:{s.component}"
        pid = self._pids.setdefault(comp, len(self._pids) + 1)
        self._events.append(
            {
                "name": s.name,
                "ph": "X",
                "ts": s.start / PS_PER_US,
                "dur": max(s.duration, 1) / PS_PER_US,
                "pid": pid,
                "tid": 1,
                "args": {
                    **{k: str(v) for k, v in s.attrs.items()},
                    "trace_id": s.context.hex_trace(),
                    "span_id": s.context.hex_span(),
                },
            }
        )
        for ts, name, attrs in s.events:
            self._events.append(
                {
                    "name": name,
                    "ph": "i",
                    "ts": ts / PS_PER_US,
                    "pid": pid,
                    "tid": 1,
                    "s": "t",
                    "args": {k: str(v) for k, v in attrs.items()},
                }
            )

    def finish(self) -> None:
        meta = [
            {"name": "process_name", "ph": "M", "pid": pid, "args": {"name": comp}}
            for comp, pid in self._pids.items()
        ]
        self.payload = {"traceEvents": meta + self._events, "displayTimeUnit": "ms"}
        self._events = []
        if self.path:
            with open(self.path, "w") as f:
                json.dump(self.payload, f)


# ---------------------------------------------------------------------------


class OTLPJSONExporter(Exporter):
    """OpenTelemetry OTLP/JSON resourceSpans (per-resource, buffered)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.payload: Optional[Dict[str, Any]] = None

    def _export(self, spans: List[Span]) -> None:
        by_comp: Dict[str, List[Span]] = {}
        for s in spans:
            by_comp.setdefault(f"{s.sim_type}:{s.component}", []).append(s)
        resource_spans = []
        for comp, ss in sorted(by_comp.items()):
            resource_spans.append(
                {
                    "resource": {
                        "attributes": [
                            {"key": "service.name", "value": {"stringValue": comp}}
                        ]
                    },
                    "scopeSpans": [
                        {
                            "scope": {"name": "columbo"},
                            "spans": [
                                {
                                    "traceId": s.context.hex_trace(),
                                    "spanId": s.context.hex_span(),
                                    **(
                                        {"parentSpanId": f"{s.parent.span_id:016x}"}
                                        if s.parent
                                        else {}
                                    ),
                                    "name": s.name,
                                    "kind": 1,
                                    # OTLP wants ns since epoch; ps -> ns
                                    "startTimeUnixNano": s.start // 1000,
                                    "endTimeUnixNano": max(s.end, s.start + 1000) // 1000,
                                    "attributes": [
                                        {"key": k, "value": {"stringValue": str(v)}}
                                        for k, v in s.attrs.items()
                                    ],
                                    "events": [
                                        {
                                            "timeUnixNano": ts // 1000,
                                            "name": name,
                                            "attributes": [
                                                {
                                                    "key": k,
                                                    "value": {"stringValue": str(v)},
                                                }
                                                for k, v in attrs.items()
                                            ],
                                        }
                                        for ts, name, attrs in s.events
                                    ],
                                    "links": [
                                        {
                                            "traceId": f"{l.trace_id:032x}",
                                            "spanId": f"{l.span_id:016x}",
                                        }
                                        for l in s.links
                                    ],
                                }
                                for s in ss
                            ],
                        }
                    ],
                }
            )
        self.payload = {"resourceSpans": resource_spans}
        if self.path:
            with open(self.path, "w") as f:
                json.dump(self.payload, f)


# ---------------------------------------------------------------------------


class SpanJSONLExporter(Exporter):
    """One JSON object per span per line, written incrementally.

    The constant-memory exporter for multipod-scale runs: output buffers
    at most ``flush_every`` encoded lines (never the spans themselves), so
    trace size is bounded by disk, not RAM.  Lines are self-contained and
    ingestible by log pipelines (BigQuery, DuckDB, jq).

    Lines accumulate into a list and flush with a *single* ``write`` per
    batch: at fleet scale the two-writes-per-span pattern this replaces
    spent more time in stream bookkeeping than in JSON encoding."""

    def __init__(self, path_or_stream, flush_every: int = 1024):
        if hasattr(path_or_stream, "write"):
            self.path, self._stream = None, path_or_stream
        else:
            self.path, self._stream = path_or_stream, None
        self._out: Optional[IO[str]] = None
        self._buf: List[str] = []
        self.flush_every = flush_every
        self.spans_written = 0

    def begin(self) -> None:
        self._out = self._stream or open(self.path, "w", buffering=1 << 20)
        self._buf = []
        self.spans_written = 0

    # process-wide memo of escaped JSON strings for values drawn from small
    # sets (attr keys get the ': ' glued on; names / sim types / components
    # are bounded by the topology).  Attr *values* are not memoized — chunk
    # ids are unbounded.
    _esc_keys: Dict[str, str] = {}
    _esc_names: Dict[str, str] = {}

    def consume(self, s: Span, _esc=json.encoder.encode_basestring_ascii,
                _kc=_esc_keys, _nc=_esc_names) -> None:
        # Hand-assembled JSON line, byte-identical to ``json.dumps(rec)``
        # of the reference record (see ``_consume_reference``): same key
        # order, the C escaper ``json.dumps`` itself uses, ``repr`` floats
        # (what the C encoder emits), ``null`` for a missing parent, and a
        # ``"%d"`` fast path for int attr values (bools are not ints here:
        # ``type`` check, not isinstance).  At fleet scale the per-span
        # dict/list staging for ``dumps`` cost more than the encoding;
        # this path skips the staging entirely.
        ctx = s.context
        parent = s.parent
        dur = s.end - s.start
        a = s.attrs
        if a:
            parts = []
            ap = parts.append
            for k, v in a.items():
                ks = _kc.get(k)
                if ks is None:
                    ks = _kc[k] = _esc(k) + ": "
                if type(v) is int:
                    ap('%s"%d"' % (ks, v))
                else:
                    ap(ks + _esc(str(v)))
            attrs_s = "{%s}" % ", ".join(parts)
        else:
            attrs_s = "{}"
        name_s = _nc.get(s.name)
        if name_s is None:
            name_s = _nc[s.name] = _esc(s.name)
        st_s = _nc.get(s.sim_type)
        if st_s is None:
            st_s = _nc[s.sim_type] = _esc(s.sim_type)
        comp_s = _nc.get(s.component)
        if comp_s is None:
            comp_s = _nc[s.component] = _esc(s.component)
        line = (
            '{"trace_id": "%032x", "span_id": "%016x", "parent_id": %s, '
            '"name": %s, "sim_type": %s, "component": %s, "start_us": %s, '
            '"duration_us": %s, "attrs": %s, "n_events": %d, "links": [%s]}'
            % (
                ctx.trace_id,
                ctx.span_id,
                '"%016x"' % parent.span_id if parent is not None else "null",
                name_s,
                st_s,
                comp_s,
                repr(s.start / PS_PER_US),
                repr((dur if dur > 1 else 1) / PS_PER_US),
                attrs_s,
                len(s.events),
                ", ".join(['"%016x"' % l.span_id for l in s.links]),
            )
        )
        buf = self._buf
        buf.append(line)
        buf.append("\n")
        if len(buf) >= 2 * self.flush_every:
            self._out.write("".join(buf))
            buf.clear()
        self.spans_written += 1

    def _consume_reference(self, s: Span) -> None:
        """The original ``json.dumps`` encoding of one span — kept as the
        executable spec :meth:`consume` is tested byte-for-byte against
        (``tests/test_streaming_weave.py``)."""
        ctx = s.context
        parent = s.parent
        dur = s.end - s.start
        rec = {
            "trace_id": f"{ctx.trace_id:032x}",
            "span_id": f"{ctx.span_id:016x}",
            "parent_id": f"{parent.span_id:016x}" if parent is not None else None,
            "name": s.name,
            "sim_type": s.sim_type,
            "component": s.component,
            "start_us": s.start / PS_PER_US,
            "duration_us": (dur if dur > 1 else 1) / PS_PER_US,
            "attrs": {k: str(v) for k, v in s.attrs.items()},
            "n_events": len(s.events),
            "links": [f"{l.span_id:016x}" for l in s.links],
        }
        buf = self._buf
        buf.append(json.dumps(rec))
        buf.append("\n")
        if len(buf) >= 2 * self.flush_every:
            self._out.write("".join(buf))
            buf.clear()
        self.spans_written += 1

    def finish(self) -> None:
        if self._buf:
            self._out.write("".join(self._buf))
            self._buf = []
        if self._out is not None and self._stream is None:
            self._out.close()
        self._out = None


# ---------------------------------------------------------------------------
# Array-native SpanJSONL rendering (the columnar weave's export side)
# ---------------------------------------------------------------------------


def render_woven_jsonl(woven, path_or_stream, flush_every: int = 1024) -> int:
    """Render a finished columnar weave (``streaming.WovenColumns``) to
    SpanJSONL, byte-identical to :class:`SpanJSONLExporter` over
    ``woven.to_spans()`` — without materializing the net spans.

    Object-path spans (host/device, the minority) go through the exact
    ``SpanJSONLExporter.consume`` code the byte-identity goldens pin
    down; net rows assemble their lines straight from the column arrays —
    same format string, same C escaper, same ``repr`` float encoding,
    same int-attr fast path, same shared escape memos — with attr
    coercion applied at render time (the columnar emit stores raw meta
    dicts).  Returns the number of spans written."""
    exp = SpanJSONLExporter(path_or_stream, flush_every=flush_every)
    exp.begin()
    consume = exp.consume
    _esc = json.encoder.encode_basestring_ascii
    kc = SpanJSONLExporter._esc_keys
    nc = SpanJSONLExporter._esc_names
    from .parsers import _NUM_LEAD, coerce_value

    nb = woven.nb
    obj = woven.obj_spans
    m = len(obj)
    comp_esc = []
    for c in nb.comp_pool:
        s = nc.get(c)
        if s is None:
            s = nc[c] = _esc(c)
        comp_esc.append(s)
    ks_chunk = kc.get("chunk")
    if ks_chunk is None:
        ks_chunk = kc["chunk"] = _esc("chunk") + ": "
    ks_size = kc.get("size")
    if ks_size is None:
        ks_size = kc["size"] = _esc("size") + ": "
    starts = nb.starts
    ends = nb.ends
    codes = nb.comp_codes
    chunks = nb.chunks
    sizes = nb.sizes
    metas = nb.metas
    queues = nb.queues
    drops = nb.drops
    nevs = nb.nevs
    xorders = nb.xorders
    unclosed = nb.unclosed
    tids = woven.net_tids
    psids = woven.net_psids
    s0 = woven.net_s0
    order = woven.order
    if not isinstance(order, list):
        order = order.tolist()
    buf = exp._buf
    out = exp._out
    fe2 = 2 * exp.flush_every
    join = ", ".join
    n_net_written = 0
    for j in order:
        if j < m:
            consume(obj[j])
            continue
        i = j - m
        start = starts[i]
        dur = ends[i] - start
        parts = []
        ap = parts.append
        v = chunks[i]
        if type(v) is int:
            ap('%s"%d"' % (ks_chunk, v))
        else:
            ap(ks_chunk + _esc(str(v)))
        v = sizes[i]
        if type(v) is int:
            ap('%s"%d"' % (ks_size, v))
        else:
            ap(ks_size + _esc(str(v)))
        for k, v in metas[i].items():
            ks = kc.get(k)
            if ks is None:
                ks = kc[k] = _esc(k) + ": "
            t = type(v)
            if t is int:
                ap('%s"%d"' % (ks, v))
            elif t is str and (not v or v[0] not in _NUM_LEAD):
                ap(ks + _esc(v))
            else:
                v = coerce_value(v)
                if type(v) is int:
                    ap('%s"%d"' % (ks, v))
                else:
                    ap(ks + _esc(str(v)))
        x = xorders[i]
        if x:
            for ch in x:
                if ch == "q":
                    ap('"queue_ps": "%d"' % queues[i])
                else:
                    ap('"drops": "%d"' % drops[i])
        if i in unclosed:
            ap('"unclosed": "True"')
        psid = psids[i]
        line = (
            '{"trace_id": "%032x", "span_id": "%016x", "parent_id": %s, '
            '"name": "LinkTransfer", "sim_type": "net", "component": %s, '
            '"start_us": %s, "duration_us": %s, "attrs": {%s}, '
            '"n_events": %d, "links": []}'
            % (
                tids[i],
                s0 + i + 1,
                '"%016x"' % psid if psid >= 0 else "null",
                comp_esc[codes[i]],
                repr(start / PS_PER_US),
                repr((dur if dur > 1 else 1) / PS_PER_US),
                join(parts),
                nevs[i],
            )
        )
        buf.append(line)
        buf.append("\n")
        if len(buf) >= fe2:
            out.write("".join(buf))
            buf.clear()
        n_net_written += 1
    exp.spans_written += n_net_written
    n = exp.spans_written
    exp.finish()
    return n


# ---------------------------------------------------------------------------
# SpanJSONL shard reading + merging (the sweep's output side)
# ---------------------------------------------------------------------------


def iter_span_records(paths) -> Iterable[Dict[str, Any]]:
    """Yield parsed span records from one or more SpanJSONL files, in file
    order (each shard is already sorted by ``(trace_id, start, span_id)``
    — the engine's export order)."""
    if isinstance(paths, str):
        paths = [paths]
    for path in paths:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    yield json.loads(line)


# SpanJSONLExporter's fixed line layout (what every shard writer in this
# repo produces): '{"trace_id": "' + 32 hex + '", "span_id": "' + 16 hex +
# '", "parent_id": ' + ('"' + 16 hex + '"' | 'null') + ...  The merge keys
# and id rewrites below slice these offsets directly; anything that does
# not match the layout falls back to a full json round-trip.
_TID_SLICE = slice(14, 46)
_SID_SEP = '", "span_id": "'       # line[46:61]
_PAR_SEP = '", "parent_id": '      # line[77:93]


def _span_line_key(line: str):
    """``(trace_id, start_us, span_id)`` of one SpanJSONL line — the
    shard-merge sort key — extracted by fixed-offset slicing, parsing
    nothing on the exporter-layout fast path."""
    if (
        line.startswith('{"trace_id": "')
        and line[46:61] == _SID_SEP
        and line[77:93] == _PAR_SEP
    ):
        i = line.find('"start_us": ', 93)
        if i >= 0:
            j = line.find(",", i + 12)
            if j >= 0:
                try:
                    return line[_TID_SLICE], float(line[i + 12:j]), line[61:77]
                except ValueError:  # pragma: no cover - malformed number
                    pass
    r = json.loads(line)
    return r["trace_id"], r["start_us"], r["span_id"]


def _disambiguated(line: str, prefix: str) -> str:
    """Rewrite every id's top 8 hex digits to ``prefix`` (trace, span,
    parent, links) by string surgery on the exporter layout; falls back to
    the json round-trip for foreign layouts."""
    if (
        line.startswith('{"trace_id": "')
        and line[46:61] == _SID_SEP
        and line[77:93] == _PAR_SEP
    ):
        out = [line[:14], prefix, line[22:61], prefix, line[69:93]]
        pos = 93
        if line[93] == '"':
            # parent value is '"' + 16 hex + '"'
            out.append('"')
            out.append(prefix)
            out.append(line[102:110])
            pos = 110
        k = line.find('"links": [', pos)
        if k >= 0:
            out.append(line[pos:k + 10])
            p = k + 10
            while line[p] == '"':
                # each link is '"' + 16 hex + '"', ", "-separated
                out.append('"')
                out.append(prefix)
                out.append(line[p + 9:p + 18])
                p += 18
                if line[p:p + 2] == ", ":
                    out.append(", ")
                    p += 2
            out.append(line[p:])
            return "".join(out)
    r = json.loads(line)
    r["trace_id"] = prefix + r["trace_id"][8:]
    r["span_id"] = prefix + r["span_id"][8:]
    if r.get("parent_id"):
        r["parent_id"] = prefix + r["parent_id"][8:]
    if r.get("links"):
        r["links"] = [prefix + l[8:] for l in r["links"]]
    return json.dumps(r)


def merge_span_jsonl(shard_paths, out_path: str, disambiguate: bool = True) -> int:
    """Streaming-merge N SpanJSONL shards into one file ordered by
    ``(trace_id, start_us, span_id)``.  Returns the number of spans written.

    Shards stream through buffered line iterators — one line per shard is
    resident at a time, never a whole shard — and exporter-layout lines
    are keyed (and id-rewritten) by fixed-offset slicing instead of a
    ``json.loads``/``json.dumps`` round-trip per record; foreign layouts
    fall back to the round-trip, which normalizes them exactly as the
    parse-based merge did.

    Sweep cells each reset the span/trace id counters (that is what makes
    a cell's bytes seed-reproducible), so ids *collide across shards*.
    With ``disambiguate`` (default) every id in shard ``i`` gets its top
    8 hex digits replaced by ``i`` — parents and links rewritten
    consistently — so the merged file has one coherent id space and
    ``assemble_traces``/``RunStats.from_jsonl`` over it never stitch spans
    from different cells together.  Pass ``disambiguate=False`` only for
    shards that already share one id space (e.g. a single run exported in
    pieces)."""

    def _keyed(idx, path):
        prefix = f"{idx:08x}"
        with open(path, buffering=1 << 20) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                if disambiguate:
                    line = _disambiguated(line, prefix)
                yield _span_line_key(line), line

    n = 0
    with open(out_path, "w", buffering=1 << 20) as out:
        w = out.write
        for _, line in heapq.merge(*[_keyed(i, p) for i, p in enumerate(shard_paths)]):
            w(line)
            w("\n")
            n += 1
    return n


# ---------------------------------------------------------------------------


class ConsoleExporter(Exporter):
    """Human-readable span tree on a stream (tests, examples, debugging)."""

    def __init__(self, stream: Optional[IO[str]] = None, max_spans: int = 200):
        self.stream = stream or sys.stdout
        self.max_spans = max_spans

    def _export(self, spans: List[Span]) -> None:
        w = self.stream.write
        printed = 0
        for tid, trace in sorted(assemble_traces(spans).items()):
            w(f"trace {tid} [{(trace.end - trace.start) / PS_PER_US:.3f} us, "
              f"{len(trace.spans)} spans]\n")

            def _tree(span: Span, depth: int) -> None:
                nonlocal printed
                if printed >= self.max_spans:
                    return
                printed += 1
                w(
                    "  " * depth
                    + f"- {span.name} [{span.component}] "
                    + f"{span.start / PS_PER_US:.3f}+{span.duration / PS_PER_US:.3f}us"
                    + (f" links={len(span.links)}" if span.links else "")
                    + "\n"
                )
                for c in sorted(trace.children_of(span), key=lambda s: s.start):
                    _tree(c, depth + 1)

            for root in sorted(trace.roots(), key=lambda s: s.start):
                _tree(root, 1)
            if printed >= self.max_spans:
                w("  ... (truncated)\n")
                break
