"""Architecture registry: the 10 assigned configs + their input shapes.

Every arch is selectable via ``--arch <id>`` in the launchers.  Each entry
records the exact published config (source in its module docstring), the
shape set, and per-shape execution knobs (microbatches, cache dtype).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    microbatches: Dict[str, int] = dataclasses.field(default_factory=dict)
    kv_cache_dtype: Dict[str, str] = dataclasses.field(default_factory=dict)
    notes: str = ""

    def shape_names(self) -> List[str]:
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context:
            out.append("long_500k")
        return out

    @property
    def supports_long_context(self) -> bool:
        # assignment rule: long_500k only for sub-quadratic-attention archs
        return "attn" not in self.config.block_pattern

    def config_for(self, shape: str) -> ModelConfig:
        kv = self.kv_cache_dtype.get(shape)
        if kv:
            return dataclasses.replace(self.config, kv_cache_dtype=kv)
        return self.config


_ARCH_MODULES = [
    "musicgen_large",
    "stablelm_1_6b",
    "qwen3_8b",
    "olmo_1b",
    "gemma3_27b",
    "recurrentgemma_2b",
    "falcon_mamba_7b",
    "chameleon_34b",
    "granite_moe_1b_a400m",
    "llama4_scout_17b_a16e",
]

ARCHS: Dict[str, ArchSpec] = {}
for _m in _ARCH_MODULES:
    mod = importlib.import_module(f".{_m}", __name__)
    ARCHS[mod.ARCH.config.name] = mod.ARCH


def get_arch(name: str) -> ArchSpec:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells() -> List[Tuple[str, str]]:
    """Every live (arch, shape) pair (long_500k skips already applied)."""
    return [(a, s) for a, spec in ARCHS.items() for s in spec.shape_names()]
