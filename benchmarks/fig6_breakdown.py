"""Fig. 6: per-component time breakdown for the sync request vs response.

The paper's money figure: under background traffic the *response* spends
far longer in the switch fed by the bulk flow than the request does,
explaining the NTP error.  We reproduce it from LinkTransfer spans of the
NTP packets (direction in attrs), plus the TPU-native analogue: per-
component breakdown of a training step with a straggler chip.
"""
import statistics
import tempfile
import time
from collections import defaultdict


def _ntp_breakdown(background: bool):
    from repro.core import TraceSession
    from repro.sim import run_ntp_sim

    with tempfile.TemporaryDirectory() as d:
        cl = run_ntp_sim(background=background, sim_seconds=8.0, outdir=d)
        session = TraceSession()
        for p in cl.log_paths()["host"]:
            session.add_log(p, "host")
        for p in cl.log_paths()["net"]:
            session.add_log(p, "net")
        spans = session.run()
    per = defaultdict(lambda: defaultdict(list))  # direction -> component -> [us]
    for s in spans:
        if s.name == "LinkTransfer" and s.attrs.get("proto") == "ntp":
            per[s.attrs.get("dir")][s.component].append(s.duration / 1e6)
    return {
        d: {c: statistics.mean(v) for c, v in comps.items()} for d, comps in per.items()
    }


def run():
    rows = []
    for bg in (False, True):
        t0 = time.perf_counter()
        bd = _ntp_breakdown(bg)
        us = (time.perf_counter() - t0) * 1e6
        tag = "bg" if bg else "base"
        for direction in ("req", "resp"):
            comps = bd.get(direction, {})
            desc = " ".join(f"{c.split('.')[-1]}={v:.1f}us" for c, v in sorted(comps.items()))
            rows.append((f"fig6.{tag}.{direction}", us, desc))
        if bg:
            sw = bd.get("resp", {}).get("eth.sw1_sw2", 0) / max(
                bd.get("req", {}).get("eth.sw1_sw2", 1e-9), 1e-9
            )
            rows.append(
                ("fig6.bg.resp_over_req_sw1sw2", 0.0,
                 f"{sw:.1f}x (paper: response >> request on the contended switch)")
            )

    # TPU-native analogue: straggler chip shows up in the step breakdown
    from repro.core import TraceSession, assemble_traces, component_breakdown, straggler_report
    from repro.sim import run_training_sim, synthetic_program

    t0 = time.perf_counter()
    prog = synthetic_program(n_layers=2, layer_flops=5e11, layer_bytes=2e8, grad_bytes=1e8)
    with tempfile.TemporaryDirectory() as d:
        cl = run_training_sim(prog, n_steps=1, n_pods=2, chips_per_pod=4, outdir=d,
                              compute_scale={"pod1.chip02": 3.0})
        session = TraceSession()
        for st_name, ps in cl.log_paths().items():
            for p in ps:
                session.add_log(p, st_name)
        spans = session.run()
    us = (time.perf_counter() - t0) * 1e6
    rep = straggler_report(spans, span_name="Op")
    rows.append(
        ("fig6.training_straggler", us,
         f"flagged={rep['stragglers']} median_us={rep['median_us']:.0f}")
    )
    return rows
