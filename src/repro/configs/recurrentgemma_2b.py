"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680,
vocab=256000, RG-LRU + local attention, pattern (rec, rec, attn), window
2048.  [arXiv:2402.19427; hf].  lru_width = d_model = 2560; GeGLU.

Runs long_500k (local attention + recurrent states are O(1) in context).
"""
from ..models.config import ModelConfig
from . import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        head_dim=256,
        block_pattern=("rglru", "rglru", "attn_local"),
        window=2048,
        lru_width=2560,
        conv_width=4,
        mlp_act="geglu",
        rope_theta=10_000.0,
    ),
    microbatches={"train_4k": 4},
    notes="26 = 8 (rec,rec,attn) groups + 2 remainder rec layers",
)
