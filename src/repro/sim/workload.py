"""Workloads: *what* the simulated cluster executes, pluggably.

Two layers live here:

1. **Device programs** — a :class:`ProgramSpec` is the op timeline one chip
   executes per step.  It can be built **from a compiled XLA artifact**
   (``program_from_compiled``) — aggregate FLOPs/bytes from
   ``cost_analysis()`` sliced into per-layer segments, with the *actual*
   collective schedule parsed from the optimized HLO placed at its position
   in program order — or **synthetically** (``synthetic_program``).

2. **Workloads** — a :class:`Workload` schedules work onto a running
   :class:`~repro.sim.cluster.ClusterOrchestrator` (hosts, chips, links)
   through the shared :class:`~repro.sim.engine.EventKernel`.  Workload
   types register by name (``register_workload``, mirroring
   ``core.registry.register_simulator``) so scenarios, sweeps and the CLI
   select them declaratively::

       from repro.sim.workload import make_workload

       wl = make_workload("rpc", program=handler, seed=3, n_requests=32)
       wl.drive(cluster)          # before cluster.run()

   Built-ins: ``collective`` (the classic data-parallel training step,
   this module), and — in :mod:`repro.sim.workloads` — ``rpc``
   (request/response serving with open/closed-loop arrivals and a per
   request trace-context id), ``storage`` (bulk checkpoint I/O contending
   with training traffic) and ``pipeline`` (stage-partitioned training
   with inter-stage activations over the fabric).

Reproducibility contract: every random draw a workload makes comes from a
``random.Random`` derived from its ``seed`` field, and the DES kernel is
deterministic — so one seed reproduces byte-identical simulator logs on
both the text and structured paths.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, ClassVar, Dict, List, Optional, Sequence, TYPE_CHECKING

from ..xla.hlo_stats import collective_stats, cost_summary

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .cluster import ClusterOrchestrator
    from .hostsim import HostSim


@dataclass(frozen=True)
class OpSpec:
    """One op on a chip's timeline: compute (roofline-costed), a
    collective, or a wait joining an async collective."""

    name: str
    kind: str = "compute"         # compute | all-reduce | all-gather | reduce-scatter
                                  # | all-to-all | collective-permute | wait
    flops: float = 0.0            # per device
    bytes: float = 0.0            # HBM bytes touched, per device
    coll_bytes: float = 0.0       # collective operand bytes, per device
    group: str = "ici"            # which ring group executes it: "ici" | "dcn"
    async_start: bool = False     # start collective without blocking
    wait_for: Optional[str] = None  # for kind="wait": name of async collective


@dataclass
class ProgramSpec:
    """The ordered op timeline every chip executes once per step."""

    name: str
    ops: List[OpSpec] = field(default_factory=list)

    @property
    def total_flops(self) -> float:
        return sum(o.flops for o in self.ops)

    @property
    def total_bytes(self) -> float:
        return sum(o.bytes for o in self.ops)

    @property
    def collectives(self) -> List[OpSpec]:
        return [o for o in self.ops if o.kind not in ("compute", "wait")]

    def symbols(self) -> Dict[str, str]:
        """op id -> human name (for the SymbolizeActor)."""
        return {f"op{i}": o.name for i, o in enumerate(self.ops)}


def program_from_compiled(
    compiled: Any,
    name: str = "train_step",
    n_segments: int = 16,
    dcn_axis_bytes_fraction: float = 0.0,
    hlo_text: Optional[str] = None,
) -> ProgramSpec:
    """Slice a compiled module's aggregate cost into a traceable op timeline.

    Not cycle-accurate (we do not schedule individual HLO ops): compute cost
    is spread uniformly over ``n_segments`` layer-like segments, and each
    parsed collective is placed after segment ``round(i/n_coll * n_segments)``
    preserving program order.  Aggregates (FLOPs, HBM bytes, collective bytes
    and their kinds/counts) are exactly the compiled module's.
    """
    cost = cost_summary(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = collective_stats(text)["ops"]

    seg_flops = cost["flops"] / n_segments
    seg_bytes = cost["bytes_accessed"] / n_segments

    ops: List[OpSpec] = []
    n_coll = len(colls)
    placed = 0
    for seg in range(n_segments):
        ops.append(
            OpSpec(name=f"{name}.seg{seg}", kind="compute", flops=seg_flops, bytes=seg_bytes)
        )
        # place collectives whose order position maps into this segment
        while placed < n_coll and (placed + 1) * n_segments <= (seg + 1) * n_coll:
            c = colls[placed]
            group = "dcn" if dcn_axis_bytes_fraction > 0 and placed % 2 == 1 else "ici"
            ops.append(
                OpSpec(
                    name=c["name"],
                    kind=c["kind"],
                    coll_bytes=float(c["bytes"]),
                    group=group,
                )
            )
            placed += 1
    for c in colls[placed:]:
        ops.append(OpSpec(name=c["name"], kind=c["kind"], coll_bytes=float(c["bytes"])))
    return ProgramSpec(name=name, ops=ops)


def synthetic_program(
    name: str = "train_step",
    n_layers: int = 4,
    layer_flops: float = 5e12,
    layer_bytes: float = 2e9,
    grad_bytes: float = 1e9,
    overlap_grad_reduce: bool = False,
    cross_pod: bool = True,
) -> ProgramSpec:
    """A miniature training step: n layers of compute + per-layer all-gather
    (FSDP-style) + one gradient all-reduce (optionally async/overlapped,
    optionally on the cross-pod DCN group)."""
    ops: List[OpSpec] = []
    for i in range(n_layers):
        ops.append(
            OpSpec(name=f"layer{i}.ag", kind="all-gather", coll_bytes=layer_bytes / 8)
        )
        ops.append(
            OpSpec(name=f"layer{i}.fwdbwd", kind="compute", flops=layer_flops, bytes=layer_bytes)
        )
    ar = OpSpec(
        name="grad.ar",
        kind="all-reduce",
        coll_bytes=grad_bytes,
        group="dcn" if cross_pod else "ici",
        async_start=overlap_grad_reduce,
    )
    if overlap_grad_reduce:
        # start the reduce before the optimizer segment, wait at the end
        ops.append(ar)
        ops.append(OpSpec(name="optimizer", kind="compute", flops=layer_flops / 4, bytes=grad_bytes))
        ops.append(OpSpec(name="grad.ar.wait", kind="wait", wait_for="grad.ar"))
    else:
        ops.append(ar)
        ops.append(OpSpec(name="optimizer", kind="compute", flops=layer_flops / 4, bytes=grad_bytes))
    return ProgramSpec(name=name, ops=ops)


# ---------------------------------------------------------------------------
# The pluggable workload layer
# ---------------------------------------------------------------------------


@dataclass
class Workload:
    """Base class: something that schedules work onto a running cluster.

    Subclasses implement :meth:`drive`, which arms hosts/chips/links on the
    cluster's shared :class:`~repro.sim.engine.EventKernel` **before**
    ``cluster.run()`` and arranges its own termination (bounded work, and
    ``cluster.net.stop_all_flows()`` once done so background flows drain).

    The five standard fields are the scenario-level knobs every workload
    receives from :class:`~repro.sim.scenarios.ScenarioSpec`; subclasses
    add their own (unknown knobs raise ``TypeError`` — see
    :func:`make_workload`).  ``n_steps`` is the workload's *size* dial:
    training workloads read it literally, the serving/storage workloads
    derive their request/round counts from it so sweep-level ``n_steps``
    overrides scale every cell consistently.
    """

    #: registry key; subclasses set it (e.g. "rpc") and call register_workload
    workload_name: ClassVar[str] = ""

    program: ProgramSpec = field(default_factory=synthetic_program)
    n_steps: int = 2
    seed: int = 0
    clock_read_every_ps: int = 2_000_000_000
    clock_reads: int = 30

    def drive(self, cluster: "ClusterOrchestrator") -> None:
        """Arm the workload's events on ``cluster`` (call before ``run()``)."""
        raise NotImplementedError

    def describe(self) -> str:
        """One-line summary for reports and ``--list-scenarios``."""
        return f"{self.workload_name or type(self).__name__}({self.program.name})"

    # -- shared helpers for subclasses ------------------------------------------

    def rng(self, stream: int = 0) -> random.Random:
        """A deterministic per-``(seed, stream)`` random source (same
        arithmetic-derivation scheme as :class:`~repro.sim.faults.FaultPlan`,
        offset so workload streams never collide with fault streams)."""
        return random.Random(self.seed * 1_000_003 + stream * 7_919 + 502_137)

    def serving_hosts(self, cluster: "ClusterOrchestrator") -> List["HostSim"]:
        """The chip-bearing hosts, in pod order (chipless NTP-testbed hosts
        carry no workload)."""
        return [h for h in cluster.hosts.values() if h.chips]

    def start_clock_telemetry(self, host: "HostSim") -> None:
        """Arm one host's ground-truth clock sampling (what the clock-fault
        diagnosis rules read), using the scenario's cadence knobs."""
        host.start_clock_reads(every_ps=self.clock_read_every_ps, n=self.clock_reads)


_WORKLOADS: Dict[str, type] = {}
_BUILTINS_LOADED = False


def _ensure_builtin_workloads() -> None:
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        _BUILTINS_LOADED = True
        from . import workloads  # noqa: F401  (registers rpc/storage/pipeline)


def register_workload(cls: type, replace: bool = False) -> type:
    """Class decorator: register a :class:`Workload` subclass under its
    ``workload_name`` (the workload-layer analogue of
    ``core.registry.register_simulator``)."""
    name = getattr(cls, "workload_name", "")
    if not name:
        raise ValueError(f"{cls.__name__} must set a non-empty workload_name")
    if not replace and name in _WORKLOADS:
        raise ValueError(
            f"workload {name!r} already registered; pass replace=True to override"
        )
    _WORKLOADS[name] = cls
    return cls


def list_workloads() -> List[str]:
    """Registered workload names, sorted (built-ins load on first use)."""
    _ensure_builtin_workloads()
    return sorted(_WORKLOADS)


def workload_type(name: str) -> type:
    """Look up a registered workload class (KeyError lists what exists)."""
    _ensure_builtin_workloads()
    try:
        return _WORKLOADS[name]
    except KeyError:
        raise KeyError(
            f"unknown workload {name!r}; available: {', '.join(sorted(_WORKLOADS))}"
        ) from None


def make_workload(name: str, **params: Any) -> Workload:
    """Instantiate a registered workload with ``params``.

    Unknown knobs raise ``TypeError`` naming the workload — misspelled
    parameters must never be silently ignored (the same contract
    :meth:`ScenarioSpec.run` enforces for its own kwargs)."""
    cls = workload_type(name)
    try:
        return cls(**params)
    except TypeError as e:
        raise TypeError(f"workload {name!r}: {e}") from None


@dataclass
class CollectiveTraining(Workload):
    """The classic workload: every chip-bearing host runs ``n_steps`` of the
    data-parallel ``program`` (per-layer ICI collectives + the cross-pod
    DCN gradient all-reduce), with per-host clock telemetry.

    This reproduces the exact event schedule the scenario framework drove
    before the workload layer existed — the pre-refactor goldens in
    ``tests/golden/`` hold byte for byte (asserted in
    ``tests/test_sweep.py`` / ``tests/test_structured.py``).
    """

    workload_name: ClassVar[str] = "collective"

    def drive(self, cluster: "ClusterOrchestrator") -> None:
        """Arm every chip-bearing host with the training-step loop."""
        from .cluster import drive_training_hosts  # late: cluster imports us

        drive_training_hosts(
            cluster, self.program, self.n_steps,
            per_host=self.start_clock_telemetry,
        )


register_workload(CollectiveTraining)
