#!/usr/bin/env bash
# Tier-1 verification — the exact command the builder and CI both run.
# Pins PYTHONPATH=src and the default "-m 'not slow'" pytest profile
# (from pyproject.toml), then the end-to-end smoke benchmark and the
# documentation checks (broken doc links / non-importing doc code blocks).
#
#   scripts/tier1.sh            # tier-1 tests + smoke + docs checks
#   scripts/tier1.sh --full     # include slow model/serving tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -m "" -x -q
else
    python -m pytest -x -q
fi

python -m benchmarks.run smoke

# doc'd examples can't rot: smoke-run the quickstarts end to end into a
# throwaway outdir (the README's headline paths)
EXAMPLES_TMP="$(mktemp -d)"
trap 'rm -rf "$EXAMPLES_TMP"' EXIT
QUICKSTART_OUT="$EXAMPLES_TMP/quickstart" python examples/quickstart.py > /dev/null
RPC_TRACE_OUT="$EXAMPLES_TMP/rpc_trace" python examples/rpc_request_trace.py > /dev/null
python examples/mitigation_comparison.py --seeds 1 > /dev/null
echo "[tier1] examples smoke: quickstart.py + rpc_request_trace.py + mitigation_comparison.py OK"

# engine perf harness pre-flight: tiny sizes, validates that the bench
# itself still runs end to end (schema is asserted in tests/test_sweep.py)
mkdir -p results
python -m benchmarks.engine_bench --smoke --out results/BENCH_engine.smoke.json

# diagnosis accuracy gate: the curated library must stay fully recalled
# (recall == 1.0 per fault class, zero healthy false positives — asserted
# inside the bench; schema is validated in tests/test_sweep.py)
python -m benchmarks.diag_bench --smoke --out results/BENCH_diag.smoke.json
python - <<'PY'
import json

with open("results/BENCH_diag.smoke.json") as f:
    payload = json.load(f)
conf = payload["curated"]["confusion"]
assert conf["macro_recall"] == 1.0, (
    f"curated library macro recall {conf['macro_recall']} != 1.0"
)
assert conf["healthy_false_positives"] == 0
print(f"[tier1] diag smoke: curated recall 1.00 over "
      f"{payload['curated']['cells']} cells, healthy FPR "
      f"{conf['healthy_fpr']:.2f}")
PY

# perf smoke: the events/sec order must hold — columnar >= inline >=
# structured >= text (ratio checks, not absolute bars, so loaded CI hosts
# don't flake — the committed full run shows the real multiples; the
# committed-recording order is asserted without guards in
# tests/test_sweep.py).  Simulate/fused-weave walls are best-of-3 inside
# the bench, but the other stage walls are single-shot: a pair is
# skipped when any stage wall feeding either side is under 10ms, where
# one scheduler blip flips the order regardless of the code.
python - <<'PY'
import json

with open("results/BENCH_engine.smoke.json") as f:
    payload = json.load(f)

def check(row, rates, fast, slow, what, walls):
    if min(walls) < 0.01:
        print(f"[tier1] perf smoke: pods={row['pods']} {fast}/{slow} {what} "
              f"has stage walls under 10ms — order check skipped")
        return
    assert rates[fast] >= rates[slow], (
        f"pods={row['pods']}: {fast} {what} path ({rates[fast]} ev/s) "
        f"fell below the {slow} path ({rates[slow]} ev/s)"
    )

for row in payload["pipeline"]:
    ev, st = row["events"], row["stages_s"]
    fs = row["full_sim_events_per_sec"]
    check(row, fs, "structured", "text", "full-sim",
          [ev / fs["text"], ev / fs["structured"]])
    ee = row["end_to_end_events_per_sec"]
    post = [st[k] for k in ("simulate", "format", "parse", "weave",
                            "export", "analyze")]
    inl = list(row["inline_stages_s"].values())
    col = list(row["columnar_stages_s"].values())
    check(row, ee, "structured", "text", "end-to-end", post)
    check(row, ee, "inline", "structured", "end-to-end", inl + post)
    check(row, ee, "columnar", "inline", "end-to-end", col + inl)
print("[tier1] perf smoke: columnar >= inline >= structured >= text "
      "on all pipeline rows (sub-10ms pairs skipped)")
PY

scripts/docs_check.sh
