"""End-to-end behaviour tests: full-system simulation -> Columbo -> traces.

These exercise the paper's complete loop — component simulators writing
ad-hoc logs, type-specific pipelines, weaving with cross-simulator context
propagation, export, and the analyses of §5.
"""
import json
import os
import threading

import pytest

from repro.core import (
    ChromeTraceExporter,
    ColumboScript,
    JaegerJSONExporter,
    SimType,
    assemble_traces,
    clock_offset_series,
    component_breakdown,
    critical_path,
    make_fifo,
    ntp_estimated_offsets,
    straggler_report,
    trace_summary,
)
from repro.sim import (
    FailurePlan,
    run_ntp_sim,
    run_training_sim,
    synthetic_program,
)


def _weave(cluster, sim_types=("host", "device", "net")):
    script = ColumboScript()
    paths = cluster.log_paths()
    for st_name in sim_types:
        for p in paths[st_name]:
            script.add_log(p, SimType(st_name))
    return script, script.run()


@pytest.fixture(scope="module")
def train_run(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("trainsim"))
    prog = synthetic_program(n_layers=2, layer_flops=5e11, layer_bytes=2e8, grad_bytes=1e8)
    cluster = run_training_sim(prog, n_steps=2, n_pods=2, chips_per_pod=4, outdir=d)
    script, spans = _weave(cluster)
    return cluster, script, spans


def test_training_sim_one_trace_per_step(train_run):
    _, _, spans = train_run
    traces = assemble_traces(spans)
    step_traces = [
        t for t in traces.values() if any(s.name == "HostStep" for s in t.spans)
    ]
    # one end-to-end trace per training step (idle-heartbeat HostTimeline
    # traces are separate roots by design)
    assert len(step_traces) == 2


def test_training_sim_no_orphans(train_run):
    _, script, _ = train_run
    assert script.finalize_stats["orphans"] == 0


def test_training_sim_cross_simulator_causality(train_run):
    _, _, spans = train_run
    by_id = {s.context.span_id: s for s in spans}
    # every DeviceProgram hangs under a host Dispatch (PCIe boundary)
    progs = [s for s in spans if s.name == "DeviceProgram"]
    assert progs
    for p in progs:
        assert p.parent is not None and by_id[p.parent.span_id].name == "Dispatch"
    # every collective-caused LinkTransfer hangs under a device Collective
    links = [s for s in spans if s.name == "LinkTransfer" and "coll" in s.attrs]
    assert links
    for l in links:
        assert l.parent is not None and by_id[l.parent.span_id].name == "Collective"


def test_training_sim_breakdown_and_critical_path(train_run):
    _, _, spans = train_run
    traces = assemble_traces(spans)
    t0 = traces[min(traces)]
    bd = component_breakdown(t0)
    assert sum(bd.values()) > 0
    assert any(k.startswith("device:") for k in bd)
    cp = critical_path(t0)
    assert cp and cp[0].name == "HostStep"
    assert all(
        cp[i + 1].parent and cp[i + 1].parent.span_id == cp[i].context.span_id
        for i in range(len(cp) - 1)
    )


def test_straggler_detection_via_traces(tmp_path):
    prog = synthetic_program(n_layers=2, layer_flops=5e11, layer_bytes=2e8, grad_bytes=1e8)
    cluster = run_training_sim(
        prog, n_steps=1, n_pods=2, chips_per_pod=4, outdir=str(tmp_path),
        compute_scale={"pod1.chip02": 3.0},
    )
    _, spans = _weave(cluster)
    rep = straggler_report(spans, span_name="Op")
    assert rep["stragglers"] == ["pod1.chip02"]


def test_failure_injection_visible_in_trace(tmp_path):
    prog = synthetic_program(n_layers=1, layer_flops=2e11, layer_bytes=1e8, grad_bytes=1e8)
    cluster = run_training_sim(
        prog, n_steps=2, n_pods=2, chips_per_pod=2, outdir=str(tmp_path),
        failure=FailurePlan(host="host1", fail_at_ps=int(3e9), restart_after_ps=int(8e10)),
    )
    _, spans = _weave(cluster, sim_types=("host",))
    failed = [s for s in spans if s.attrs.get("failed")]
    assert failed and failed[0].component == "host1"
    # failure marks the in-flight step span; the restart lands on host1's
    # timeline (the step it un-parks is a fresh span)
    names = [n for s in spans if s.component == "host1" for _, n, _ in s.events]
    assert "host_failure" in names and "host_restart" in names


def test_checkpoint_spans_appear(tmp_path):
    prog = synthetic_program(n_layers=1, layer_flops=2e11, layer_bytes=1e8, grad_bytes=1e8)
    cluster = run_training_sim(
        prog, n_steps=2, n_pods=1, chips_per_pod=2, outdir=str(tmp_path), ckpt_every=1,
    )
    _, spans = _weave(cluster, sim_types=("host",))
    ckpts = [s for s in spans if s.name == "Checkpoint"]
    assert len(ckpts) == 2
    assert all(any(n == "shard_write" for _, n, _ in s.events) for s in ckpts)


# ---------------------------------------------------------------------------
# §5 case study: clock sync under background traffic
# ---------------------------------------------------------------------------


def test_ntp_case_study_reproduces_paper_phenomenon(tmp_path):
    base = run_ntp_sim(background=False, sim_seconds=8.0, outdir=str(tmp_path / "base"))
    _, spans_b = _weave(base, sim_types=("host", "net"))
    bg = run_ntp_sim(background=True, sim_seconds=8.0, outdir=str(tmp_path / "bg"))
    _, spans_g = _weave(bg, sim_types=("host", "net"))

    skew_b = [abs(o) for _, o in clock_offset_series(spans_b, "client", "server")[2:]]
    skew_g = [abs(o) for _, o in clock_offset_series(spans_g, "client", "server")[2:]]
    assert skew_b and skew_g
    # Fig. 4: background traffic makes synchronization substantially worse
    assert max(skew_g) > 2.0 * max(skew_b)

    # Fig. 5: chrony's own estimates exist in both scenarios
    assert len(ntp_estimated_offsets(spans_b, "client")) >= 5
    assert len(ntp_estimated_offsets(spans_g, "client")) >= 5


def test_ntp_breakdown_blames_contended_link(tmp_path):
    bg = run_ntp_sim(background=True, sim_seconds=6.0, outdir=str(tmp_path))
    _, spans = _weave(bg, sim_types=("host", "net"))
    # queueing delay on the inter-switch link dominates NTP packet transfers
    ntp_links = [s for s in spans if s.name == "LinkTransfer" and s.attrs.get("proto") == "ntp"]
    assert ntp_links
    q = {}
    for s in ntp_links:
        q.setdefault(s.component, []).append(s.attrs.get("queue_ps", 0))
    mean_q = {c: sum(v) / len(v) for c, v in q.items()}
    worst = max(mean_q, key=mean_q.get)
    assert worst == "eth.sw1_sw2"  # the link the bulk flow saturates


# ---------------------------------------------------------------------------
# §3.8 online mode: named pipes, Columbo running in parallel
# ---------------------------------------------------------------------------


def test_online_mode_with_named_pipes(tmp_path):
    d = str(tmp_path)
    prog = synthetic_program(n_layers=1, layer_flops=2e11, layer_bytes=1e8, grad_bytes=5e7)
    pipe_paths = {
        "host": [os.path.join(d, "host-host0.log")],
        "device": [os.path.join(d, "device-pod0.log")],
        "net": [os.path.join(d, "net.log")],
    }
    for ps in pipe_paths.values():
        for p in ps:
            make_fifo(p)

    script = ColumboScript(poll_timeout=5.0)
    for st_name, ps in pipe_paths.items():
        for p in ps:
            script.add_log(p, SimType(st_name))
    for p in script.pipelines:
        p.start()

    def _simulate():
        run_training_sim(prog, n_steps=1, n_pods=1, chips_per_pod=2, outdir=d)

    t = threading.Thread(target=_simulate)
    t.start()
    t.join(timeout=120)
    for p in script.pipelines:
        p.join(timeout=60)
    spans = []
    for w in script.weavers:
        spans.extend(w.spans)
    from repro.core import finalize_spans

    stats = finalize_spans(spans, script.registry)
    assert len(spans) > 10
    assert stats["orphans"] == 0
    traces = assemble_traces(spans)
    step_traces = [t for t in traces.values() if any(s.name == "HostStep" for s in t.spans)]
    assert len(step_traces) == 1


def test_exporters_from_full_run(train_run, tmp_path):
    _, script, spans = train_run
    jp = str(tmp_path / "t.jaeger.json")
    cp = str(tmp_path / "t.chrome.json")
    JaegerJSONExporter(jp).export(spans)
    ChromeTraceExporter(cp).export(spans)
    jd = json.load(open(jp))
    assert len(jd["data"]) >= 2   # 2 step traces (+ idle-heartbeat timelines)
    cd = json.load(open(cp))
    assert len(cd["traceEvents"]) > len(spans)
