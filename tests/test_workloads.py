"""Pluggable workload layer: registry semantics, per-workload text-vs
structured byte identity, workload x scenario reproducibility, the RPC
one-root-span-per-request property, the ScenarioSpec.run kwargs contract,
and the sweep's workload axis.
"""
import os
import re

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.analysis import (
    request_latency_stats,
    request_report,
    rpc_requests,
    slowest_request,
)
from repro.sim import (
    CollectiveTraining,
    RpcServing,
    ScenarioSpec,
    Workload,
    get_scenario,
    list_scenarios,
    list_workloads,
    make_workload,
    register_workload,
    rpc_handler_program,
    workload_type,
)
from repro.sim.scenarios import SCENARIOS
from repro.sim.sweep import SweepSpec, run_sweep
from repro.sim.workloads.pipeline import split_stages
from repro.sim.workload import synthetic_program

WORKLOAD_SCENARIOS = ("rpc_tail_latency", "ckpt_slow_dcn", "pipeline_stall_host1")


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------


def test_builtin_workloads_registered():
    assert set(list_workloads()) >= {"collective", "rpc", "storage", "pipeline"}
    assert workload_type("rpc") is RpcServing
    assert workload_type("collective") is CollectiveTraining


def test_workload_type_unknown_name():
    with pytest.raises(KeyError, match="unknown workload"):
        workload_type("batch_inference")


def test_register_workload_rejects_duplicates_and_anonymous():
    with pytest.raises(ValueError, match="already registered"):
        register_workload(RpcServing)

    class NoName(Workload):
        pass

    with pytest.raises(ValueError, match="workload_name"):
        register_workload(NoName)


def test_make_workload_unknown_knob_raises_typeerror():
    """Misspelled workload knobs must never be silently ignored."""
    with pytest.raises(TypeError, match="rpc"):
        make_workload("rpc", n_request=5)        # typo: n_requests
    wl = make_workload("rpc", n_requests=5, arrival="closed")
    assert wl.total_requests == 5


def test_rpc_rejects_unknown_arrival_mode():
    with pytest.raises(ValueError, match="arrival"):
        RpcServing(arrival="batch")


def test_scenario_run_rejects_unknown_kwargs():
    """Bugfix contract: ScenarioSpec.run(unknown=...) raises TypeError
    (extra kwargs are field overrides, never silently dropped)."""
    spec = get_scenario("healthy_baseline")
    with pytest.raises(TypeError, match="workloadz"):
        spec.run(workloadz="rpc")
    with pytest.raises(TypeError, match="n_podz"):
        spec.run(n_podz=4)


def test_scenario_run_field_overrides_apply():
    run = get_scenario("healthy_baseline").run(
        workload="rpc", workload_params=(("n_requests", 2),), structured=True
    )
    assert len(rpc_requests(run.spans)) == 2


def test_scenario_make_workload_rejects_bad_params():
    spec = ScenarioSpec(
        name="x", description="", workload="rpc",
        workload_params=(("n_request", 3),),
    )
    with pytest.raises(TypeError, match="rpc"):
        spec.make_workload()


# ---------------------------------------------------------------------------
# Per-workload byte identity + reproducibility across workload x scenario
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", WORKLOAD_SCENARIOS)
def test_workload_scenarios_structured_equals_text(name):
    """Every workload-pinned library scenario weaves byte-identically on
    the text and zero-parse structured paths."""
    spec = get_scenario(name)
    assert spec.run(seed=9).span_jsonl == spec.run(seed=9, structured=True).span_jsonl


@pytest.mark.parametrize(
    "workload,scenario",
    [
        ("rpc", "degraded_ici_link"),
        ("rpc", "gc_pause_host0"),
        ("storage", "lossy_dcn"),
        ("pipeline", "throttled_chip"),
        ("collective", "rpc_tail_latency"),   # axis override in reverse, too
    ],
)
def test_workload_scenario_cells_reproduce_and_match_structured(workload, scenario):
    """Same seed -> byte-identical SpanJSONL for arbitrary workload x
    scenario cells, on both paths; a different seed changes the trace."""
    spec = get_scenario(scenario)
    a = spec.run(seed=3, workload=workload)
    b = spec.run(seed=3, workload=workload)
    c = spec.run(seed=3, workload=workload, structured=True)
    assert a.span_jsonl == b.span_jsonl == c.span_jsonl
    assert a.span_jsonl        # produced something


def test_rpc_different_seed_changes_arrivals():
    spec = get_scenario("rpc_tail_latency")
    assert spec.run(seed=0).span_jsonl != spec.run(seed=1).span_jsonl


def test_workload_faults_compose():
    """All-fault-classes-compose spot checks: host_pause drains at an RPC
    subrequest boundary, device_slowdown shows under pipeline load."""
    run = get_scenario("gc_pause_host0").run(workload="rpc", structured=True)
    assert "host_pause" in run.detected
    run = get_scenario("throttled_chip").run(workload="pipeline", structured=True)
    assert "device_slowdown" in run.detected


# ---------------------------------------------------------------------------
# RPC: every request id in any log appears as exactly one root span
# ---------------------------------------------------------------------------


def _rids_in_logs(cluster) -> set:
    """Request ids appearing anywhere in the simulator logs (text files,
    in-memory lines, or the structured capture rendered back to text)."""
    rids = set()
    pat = re.compile(r"\brid=(\S+)")
    for lw in cluster._logs:
        if lw.structured:
            lines = lw.render_lines()
        elif lw.path is not None:
            with open(lw.path) as f:
                lines = f.read().splitlines()
        else:
            lines = lw.lines
        for line in lines:
            rids.update(pat.findall(line))
    return rids


def test_every_rpc_request_id_has_exactly_one_root_span(tmp_path):
    run = get_scenario("rpc_tail_latency").run(outdir=str(tmp_path / "logs"))
    rids = _rids_in_logs(run.cluster)
    assert rids, "rpc scenario logged no request ids"
    roots = [s for s in run.spans if s.name == "RpcRequest"]
    assert all(s.parent is None for s in roots)
    by_rid = {}
    for s in roots:
        by_rid.setdefault(s.attrs.get("rid"), []).append(s)
    assert set(by_rid) == rids
    assert all(len(v) == 1 for v in by_rid.values())


@given(
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    arrival=st.sampled_from(["open", "closed"]),
)
@settings(max_examples=6, deadline=None)
def test_rpc_root_span_property_any_seed(seed, arrival):
    """Property: for any seed and arrival mode, every rid in the
    (structured) logs weaves into exactly one parentless RpcRequest span."""
    spec = ScenarioSpec(
        name="rpc_prop",
        description="rpc root-span property probe",
        workload="rpc",
        workload_params=(("n_requests", 5), ("arrival", arrival)),
        program=rpc_handler_program,
        chips_per_pod=2,
        clock_reads=4,
    )
    run = spec.run(seed=seed, structured=True)
    rids = _rids_in_logs(run.cluster)
    roots = [s for s in run.spans if s.name == "RpcRequest"]
    assert sorted(s.attrs.get("rid") for s in roots) == sorted(rids)
    assert len(roots) == 5 and all(s.parent is None for s in roots)


# ---------------------------------------------------------------------------
# Per-request analysis
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def rpc_run():
    return get_scenario("rpc_tail_latency").run(structured=True)


def test_request_latency_stats_and_slowest(rpc_run):
    stats = request_latency_stats(rpc_run.spans)
    assert stats["n"] == 10
    assert 0 < stats["p50"] <= stats["p90"] <= stats["p99"] <= stats["max"]
    trace = slowest_request(rpc_run.spans)
    root = rpc_requests(trace.spans)[0]
    assert root.duration / 1e6 == pytest.approx(stats["max"], rel=1e-9)
    # the tree spans all three simulator types (host -> device -> net)
    assert {s.sim_type for s in trace.spans} == {"host", "device", "net"}


def test_request_report_names_degraded_link(rpc_run):
    """Acceptance: diagnose() on the slowest request's own trace names the
    degraded link."""
    report = request_report(rpc_run.spans)
    assert "slowest request" in report
    assert "link_degradation" in report and "ici.pod0.l1" in report


def test_request_report_without_requests():
    assert "no RpcRequest spans" in request_report([])


# ---------------------------------------------------------------------------
# Sweep workload axis
# ---------------------------------------------------------------------------


def test_sweep_workload_axis(tmp_path):
    spec = SweepSpec(
        scenarios=("degraded_ici_link",),
        seeds=(0,),
        workloads=("collective", "rpc"),
        chips_per_pod=2,
    )
    assert spec.cells() == [
        ("degraded_ici_link", "collective", None, None, None, 0),
        ("degraded_ici_link", "rpc", None, None, None, 0),
    ]
    result = run_sweep(spec, str(tmp_path), jobs=1, structured=True)
    assert [c.workload for c in result.cells] == ["collective", "rpc"]
    shards = [c.shard for c in result.cells]
    assert shards == [
        os.path.join("shards", "degraded_ici_link.collective.seed0.spans.jsonl"),
        os.path.join("shards", "degraded_ici_link.rpc.seed0.spans.jsonl"),
    ]
    agg = result.aggregate()
    assert len(result.cells[1].stats.request_us) > 0
    assert agg.request_latency["n"] == len(result.cells[1].stats.request_us)
    assert "request latency" in agg.report()
    # default-workload sweeps keep their pre-axis shard names
    legacy = SweepSpec(scenarios=("healthy_baseline",), seeds=(1,))
    r2 = run_sweep(legacy, str(tmp_path / "legacy"), jobs=1, structured=True)
    assert r2.cells[0].shard == os.path.join(
        "shards", "healthy_baseline.seed1.spans.jsonl"
    )


def test_list_scenarios_workload_filter():
    assert list_scenarios("rpc") == ["rpc_tail_latency", "link_loss_rpc"]
    assert "healthy_baseline" in list_scenarios("collective")
    assert set(list_scenarios()) == set(SCENARIOS)


# ---------------------------------------------------------------------------
# Stage splitting (pipeline)
# ---------------------------------------------------------------------------


def test_split_stages_rehomes_dcn_and_names_stages():
    prog = synthetic_program(n_layers=3, cross_pod=True)
    stages = split_stages(prog, 3)
    assert [s.name for s in stages] == [
        "train_step.stage0", "train_step.stage1", "train_step.stage2",
    ]
    all_ops = [o for s in stages for o in s.ops]
    assert len(all_ops) == len(prog.ops)
    assert all(o.group == "ici" for o in all_ops)   # dcn grad.ar re-homed


def test_split_stages_more_stages_than_ops():
    prog = synthetic_program(n_layers=1)
    stages = split_stages(prog, 8)
    assert sum(len(s.ops) for s in stages) == len(prog.ops)
