"""Trainer loop, checkpointing (atomic/keep-k/async/resume), elastic
restart across device counts, compression, data determinism."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

# trainer loops, checkpoint round-trips and multi-device subprocesses:
# excluded from the tier-1 profile (pyproject addopts -m "not slow")
pytestmark = pytest.mark.slow

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.distributed.compression import (
    dequantize_int8,
    ef_compress,
    quantize_int8,
)
from repro.models import ModelConfig
from repro.training import AdamWConfig, TrainConfig
from repro.training.trainer import Trainer, TrainerConfig

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16, remat="none",
)


def _trainer(tmp, steps=6, ckpt_every=0, **kw):
    return Trainer(
        TINY,
        TrainConfig(adamw=AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=steps)),
        TrainerConfig(total_steps=steps, ckpt_every=ckpt_every,
                      ckpt_dir=str(tmp), log_every=0, **kw),
    )


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, steps=10)
    tr.run()
    losses = [m["loss"] for m in tr.metrics_log]
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_checkpoint_save_restore_resume(tmp_path):
    tr = _trainer(tmp_path / "ck", steps=6, ckpt_every=2, ckpt_async=False)
    state = tr.run()
    mgr = tr.ckpt
    assert mgr.all_steps() == [2, 4, 6]

    # restore equals live state at the final step
    restored, _ = mgr.restore(state)
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # resume: a fresh trainer continues from step 6 (runs 6..9)
    tr2 = _trainer(tmp_path / "ck", steps=10, ckpt_every=2, ckpt_async=False)
    tr2.run()
    assert len(tr2.metrics_log) == 4


def test_checkpoint_keep_k_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))}}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert not glob.glob(os.path.join(str(tmp_path), ".tmp_ckpt_*"))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.full((16,), 3.0)}
    mgr.save_async(1, tree)
    mgr.wait()
    got, _ = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_preemption_checkpoint(tmp_path):
    flag = str(tmp_path / "preempt")
    tr = _trainer(tmp_path / "ck", steps=50, ckpt_every=5, preemption_file=flag)
    tr.hooks.append(lambda step, m: open(flag, "w").close() if step == 7 else None)
    tr.run()
    assert len(tr.metrics_log) <= 9
    assert tr.ckpt.latest_step() == 8  # preemption checkpoint at break step


def test_elastic_restart_reshards_across_device_counts(subproc):
    """Save on a (4,2) mesh, restore onto (2,1) — values identical."""
    out = subproc(
        """
import os, jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh
from repro.models import ModelConfig, init_params, model_pspecs, make_rules, partition_specs
from jax.sharding import NamedSharding, PartitionSpec as P
import tempfile

cfg = ModelConfig(name='t', family='dense', n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16, remat='none')
pspecs = model_pspecs(cfg)
params = init_params(jax.random.PRNGKey(0), pspecs)
d = tempfile.mkdtemp()

mesh_a = make_mesh((4, 2), ('data', 'model'))
rules_a = make_rules(mesh_a)
sh_a = jax.tree_util.tree_map(lambda s: NamedSharding(mesh_a, s),
                              partition_specs(pspecs, rules_a),
                              is_leaf=lambda x: isinstance(x, P))
params_a = jax.device_put(params, sh_a)
mgr = CheckpointManager(d)
mgr.save(1, params_a)

mesh_b = make_mesh((2, 1), ('data', 'model'))
rules_b = make_rules(mesh_b)
sh_b = jax.tree_util.tree_map(lambda s: NamedSharding(mesh_b, s),
                              partition_specs(pspecs, rules_b),
                              is_leaf=lambda x: isinstance(x, P))
restored, _ = mgr.restore(params, shardings=sh_b)
for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('ELASTIC_OK', len(jax.tree_util.tree_leaves(restored)))
""",
        devices=8,
    )
    assert "ELASTIC_OK" in out


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=2000))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_bounded_error(n):
    x = np.random.default_rng(n).standard_normal(n).astype(np.float32)
    q, s = quantize_int8(jnp.asarray(x))
    back = np.asarray(dequantize_int8(q, s, x.shape))
    blockmax = np.abs(x).max() if n else 0
    assert np.abs(back - x).max() <= blockmax / 127.0 + 1e-6


def test_error_feedback_converges():
    """SGD on a quadratic with int8-EF gradients reaches the optimum."""
    w = jnp.full((256,), 5.0)
    err = jnp.zeros_like(w)
    for _ in range(60):
        g = 2 * w                                # grad of ||w||^2
        q, s, err = ef_compress(g, err)
        g_hat = dequantize_int8(q, s, g.shape)
        w = w - 0.05 * g_hat
    assert float(jnp.abs(w).max()) < 1e-2


def test_compressed_psum_matches_exact(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.compat import shard_map
from repro.distributed.compression import compressed_psum
mesh = jax.make_mesh((4,), ('d',))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 128))
f = shard_map(lambda x: compressed_psum(x[0], 'd'), mesh=mesh,
              in_specs=P('d'), out_specs=P(), check_vma=False)
approx = f(x)
exact = x.sum(0)
rel = float(jnp.max(jnp.abs(approx - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
assert rel < 0.05, rel
print('PSUM_OK', rel)
""",
        devices=4,
    )
    assert "PSUM_OK" in out


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    a = SyntheticLM(cfg, host_index=0, host_count=2)
    b = SyntheticLM(cfg, host_index=0, host_count=2)
    c = SyntheticLM(cfg, host_index=1, host_count=2)
    np.testing.assert_array_equal(a.batch_at(5)["tokens"], b.batch_at(5)["tokens"])
    assert not np.array_equal(a.batch_at(5)["tokens"], c.batch_at(5)["tokens"])
    t = a.batch_at(0)["tokens"]
    assert t.min() >= 0 and t.max() < 1000
    # labels are next-token shifted
    full_a = a.batch_at(7)
    assert full_a["tokens"].shape == full_a["labels"].shape


def test_pipeline_parallel_matches_sequential(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp
from repro.distributed.pipeline import pipeline_apply
mesh = jax.make_mesh((4,), ('stage',))
Ws = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 16))
out = pipeline_apply(mesh, 'stage', lambda W, h: jnp.tanh(h @ W), Ws, x)
ref = x
for i in range(4): ref = jnp.tanh(ref @ Ws[i])
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-6, err
g = jax.grad(lambda Ws: pipeline_apply(mesh, 'stage', lambda W,h: jnp.tanh(h @ W), Ws, x).sum())(Ws)
gr = jax.grad(lambda Ws: _ref(Ws))(Ws) if False else None
print('PP_OK', err)
""",
        devices=4,
    )
    assert "PP_OK" in out
