"""Diagnosis accuracy benchmark — the repo's scoring baseline (BENCH_diag.json).

Four sections, each a ``run_sweep`` grid scored by
``repro.core.evaluation``:

* ``curated``     — the curated scenario library under its own pinned
                    workloads × seeds: the regression gate.  Per-fault-class
                    recall must be 1.0 and the healthy baseline must score
                    zero findings (asserted inside the bench, smoke and
                    full alike — tier-1 runs ``--smoke``).
* ``grid``        — the full scenario × workload × seed cross product:
                    every fault class re-run under every workload type
                    (``collective`` / ``rpc`` / ``storage`` / ``pipeline``).
                    Cross-workload attribution is *reported*, not gated —
                    this is the leaderboard future detector work moves.
* ``sensitivity`` — the fault-magnitude axis (``SweepSpec(magnitudes=...)``
                    scaling every fault via ``FaultSpec.scaled``):
                    detection rate vs fraction-of-published-intensity per
                    scenario, i.e. at what magnitude each rule stops
                    firing.  Magnitude 0 must detect nothing (the healthy
                    edge) and magnitude 1 everything (the curated gate
                    re-stated) — both asserted.
* ``masking``     — does remediation hide the fault from the detector?
                    Scenarios × every registered mitigation policy; each
                    row reports the policy's detection rate next to its
                    declared ``masks`` contract (PR 6's
                    ``MitigationConflictError`` semantics, measured).

Results land in ``BENCH_diag.json`` (schema ``columbo.diag_bench/v1``,
validated in ``tests/test_sweep.py`` alongside the engine bench); the
evaluation cookbook is ``docs/evaluation.md``.

    python -m benchmarks.diag_bench                 # full leaderboard (~2 min)
    python -m benchmarks.diag_bench --smoke         # tier-1 recall gate (~15 s)
    python -m benchmarks.diag_bench --out my.json --jobs 4
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time

SCHEMA = "columbo.diag_bench/v1"

WORKLOADS = ("collective", "rpc", "storage", "pipeline")

FULL_SEEDS = (0, 1, 2)
SMOKE_SEEDS = (0,)

FULL_MAGNITUDES = (0.0, 0.05, 0.1, 0.25, 0.5, 1.0)
SMOKE_MAGNITUDES = (0.0, 0.25, 1.0)
FULL_SENSITIVITY_SCENARIOS = (
    "degraded_ici_link", "lossy_dcn", "gc_pause_host0",
    "throttled_chip", "drifting_clock_host1",
)
SMOKE_SENSITIVITY_SCENARIOS = ("degraded_ici_link",)
FULL_SENSITIVITY_SEEDS = (0, 1)

FULL_MASKING_SCENARIOS = ("link_loss_rpc", "throttled_chip")
SMOKE_MASKING_SCENARIOS = ("throttled_chip",)

SMOKE_GRID_SCENARIOS = ("healthy_baseline", "degraded_ici_link", "gc_pause_host0")


def _sweep_stats(spec, jobs: int):
    """Run one sweep into a throwaway dir; returns (stats, wall_s)."""
    from repro.sim.sweep import run_sweep

    with tempfile.TemporaryDirectory(prefix="diag-bench-") as d:
        t0 = time.perf_counter()
        result = run_sweep(spec, d, jobs=jobs, structured=True)
        wall = time.perf_counter() - t0
        return result.run_stats(), wall


def bench_curated(seeds=FULL_SEEDS, jobs: int = 1) -> dict:
    """The regression gate: curated library × seeds, pinned workloads.

    Asserts per-class recall == 1.0 and zero healthy false positives —
    the library is published as fully diagnosable, so any rule or weaver
    change that breaks the round-trip fails here (and in tier-1, which
    runs this at smoke sizes).
    """
    from repro.core.evaluation import evaluate_diagnosis
    from repro.sim.sweep import SweepSpec

    spec = SweepSpec.library(seeds=tuple(seeds))
    stats, wall = _sweep_stats(spec, jobs)
    ev = evaluate_diagnosis(stats)
    for name, c in sorted(ev.classes.items()):
        assert c.recall == 1.0, (
            f"curated library recall regression: {name} recalled "
            f"{c.tp}/{c.injected} injected cells"
        )
    assert ev.healthy_false_positives == 0, (
        f"healthy baseline produced findings in "
        f"{ev.healthy_false_positives}/{ev.healthy_cells} cells"
    )
    return {
        "scenarios": list(spec.scenarios),
        "seeds": list(seeds),
        "cells": len(stats),
        "wall_s": round(wall, 3),
        "confusion": ev.to_dict(),
    }


def bench_grid(scenarios=None, workloads=WORKLOADS, seeds=FULL_SEEDS,
               jobs: int = 1) -> dict:
    """The full cross product: every scenario × every workload type × seeds.

    Faults compose with every workload, but their *signatures* differ by
    driver (an ICI collapse stretches collectives; under ``rpc`` it shows
    up in request tails), so cross-workload cells measure how portable
    each rule is.  Reported, not asserted — the leaderboard to beat.
    """
    from repro.core.evaluation import evaluate_diagnosis
    from repro.sim.sweep import SweepSpec

    if scenarios is None:
        spec = SweepSpec.library(seeds=tuple(seeds), workloads=tuple(workloads))
    else:
        spec = SweepSpec(scenarios=tuple(scenarios), seeds=tuple(seeds),
                         workloads=tuple(workloads))
    stats, wall = _sweep_stats(spec, jobs)
    ev = evaluate_diagnosis(stats)
    return {
        "scenarios": list(spec.scenarios),
        "workloads": list(workloads),
        "seeds": list(seeds),
        "cells": len(stats),
        "wall_s": round(wall, 3),
        "confusion": ev.to_dict(),
    }


def bench_sensitivity(scenarios=FULL_SENSITIVITY_SCENARIOS,
                      magnitudes=FULL_MAGNITUDES,
                      seeds=FULL_SENSITIVITY_SEEDS, jobs: int = 1) -> dict:
    """Detection-sensitivity curves over the fault-magnitude axis.

    Each scenario re-runs with every fault scaled to ``magnitude`` times
    its published intensity; the curve is the fraction of seeds whose
    diagnosis still names the injected class.  The interesting part is
    the middle — where each rule's k-MAD/threshold floor actually sits.
    """
    from repro.core.evaluation import sensitivity_curves
    from repro.sim.sweep import SweepSpec

    spec = SweepSpec(scenarios=tuple(scenarios), seeds=tuple(seeds),
                     magnitudes=tuple(magnitudes))
    stats, wall = _sweep_stats(spec, jobs)
    curves = sensitivity_curves(stats)
    for c in curves:
        rates = dict(c.points)
        if 0.0 in rates:
            assert rates[0.0] == 0.0, (
                f"{c.scenario}: fault class {c.fault_class} detected at "
                f"magnitude 0 (a scaled-to-nothing fault must be healthy)"
            )
        if 1.0 in rates:
            assert rates[1.0] == 1.0, (
                f"{c.scenario}: fault class {c.fault_class} missed at "
                f"magnitude 1 (full intensity must stay diagnosable)"
            )
    return {
        "scenarios": list(scenarios),
        "magnitudes": list(magnitudes),
        "seeds": list(seeds),
        "cells": len(stats),
        "wall_s": round(wall, 3),
        "curves": [c.to_dict() for c in curves],
    }


def bench_masking(scenarios=FULL_MASKING_SCENARIOS, seeds=FULL_SEEDS,
                  jobs: int = 1) -> dict:
    """Mitigation-masking measurement: detection rate per policy.

    For each scenario, every registered policy runs on the same fault
    trace (the sweep's mitigations axis bypasses ``run()``'s
    ``MitigationConflictError`` check by design — here we *measure* the
    masking that check guards against).  ``masks_expected`` is the
    policy's declared contract; ``detection_rate`` is what actually
    happened, so a declared-masking policy with rate 1.0 (or vice versa)
    is a contract bug surfaced by the leaderboard.
    """
    from repro.sim.mitigation import list_mitigations, mitigation_type
    from repro.sim.scenarios import get_scenario
    from repro.sim.sweep import SweepSpec

    policies = tuple(list_mitigations())
    spec = SweepSpec(scenarios=tuple(scenarios), seeds=tuple(seeds),
                     mitigations=policies)
    stats, wall = _sweep_stats(spec, jobs)
    rows = []
    for scenario in scenarios:
        expected = set(get_scenario(scenario).expected_classes)
        for policy in policies:
            cells = [s for s in stats
                     if s.scenario == scenario and s.mitigation == policy]
            hits = sum(1 for s in cells if expected <= set(s.detected))
            rows.append({
                "scenario": scenario,
                "policy": policy,
                "expected": sorted(expected),
                "masks_expected": bool(
                    expected & set(mitigation_type(policy).masks)
                ),
                "cells": len(cells),
                "detection_rate": hits / len(cells) if cells else 0.0,
            })
    return {
        "scenarios": list(scenarios),
        "policies": list(policies),
        "seeds": list(seeds),
        "cells": len(stats),
        "wall_s": round(wall, 3),
        "rows": rows,
    }


def collect(smoke: bool = False, jobs: int = 0) -> dict:
    """Run all four sections and assemble the BENCH_diag.json payload."""
    if jobs <= 0:
        jobs = min(8, os.cpu_count() or 1)
    if smoke:
        curated = bench_curated(SMOKE_SEEDS, jobs=jobs)
        grid = bench_grid(SMOKE_GRID_SCENARIOS, WORKLOADS, SMOKE_SEEDS,
                          jobs=jobs)
        sensitivity = bench_sensitivity(SMOKE_SENSITIVITY_SCENARIOS,
                                        SMOKE_MAGNITUDES, SMOKE_SEEDS,
                                        jobs=jobs)
        masking = bench_masking(SMOKE_MASKING_SCENARIOS, SMOKE_SEEDS,
                                jobs=jobs)
    else:
        curated = bench_curated(jobs=jobs)
        grid = bench_grid(jobs=jobs)
        sensitivity = bench_sensitivity(jobs=jobs)
        masking = bench_masking(jobs=jobs)
    return {
        "schema": SCHEMA,
        "smoke": smoke,
        "host": {
            "python": sys.version.split()[0],
            "platform": platform.platform(),
        },
        "curated": curated,
        "grid": grid,
        "sensitivity": sensitivity,
        "masking": masking,
    }


def run():
    """``benchmarks.run`` harness hook: smoke-sized rows (name, us, derived)."""
    payload = collect(smoke=True)
    cur = payload["curated"]["confusion"]
    yield ("diag.curated", payload["curated"]["wall_s"] * 1e6,
           f"recall={cur['macro_recall']:.2f} "
           f"comp={cur['component_accuracy']:.2f}")
    g = payload["grid"]["confusion"]
    yield ("diag.grid", payload["grid"]["wall_s"] * 1e6,
           f"prec={g['macro_precision']:.2f} rec={g['macro_recall']:.2f}")
    for c in payload["sensitivity"]["curves"]:
        thr = c["detection_threshold"]
        yield (f"diag.sensitivity.{c['scenario']}",
               payload["sensitivity"]["wall_s"] * 1e6,
               f"threshold={'-' if thr is None else thr}")
    masked = sum(1 for r in payload["masking"]["rows"]
                 if r["masks_expected"] and r["detection_rate"] < 1.0)
    yield ("diag.masking", payload["masking"]["wall_s"] * 1e6,
           f"{masked} masked policy rows")


def main() -> None:
    """CLI entry: write the leaderboard payload and print a summary."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the tier-1 recall gate (~15 s)")
    ap.add_argument("--out", default="BENCH_diag.json",
                    help="where to write the JSON payload")
    ap.add_argument("--jobs", type=int, default=0,
                    help="sweep worker processes (0 = min(8, cores))")
    args = ap.parse_args()
    payload = collect(smoke=args.smoke, jobs=args.jobs)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    for section in ("curated", "grid"):
        conf = payload[section]["confusion"]
        print(f"[diag_bench] {section}: {payload[section]['cells']} cells in "
              f"{payload[section]['wall_s']}s — "
              f"macro P={conf['macro_precision']:.2f} "
              f"R={conf['macro_recall']:.2f} F1={conf['macro_f1']:.2f}, "
              f"component acc {conf['component_accuracy']:.2f}, "
              f"healthy FPR {conf['healthy_fpr']:.2f}")
    for c in payload["sensitivity"]["curves"]:
        pts = " ".join(f"{p['magnitude']:g}:{p['detection_rate']:.2f}"
                       for p in c["points"])
        thr = c["detection_threshold"]
        print(f"[diag_bench] sensitivity {c['scenario']}/{c['fault_class']}: "
              f"{pts} (threshold {'-' if thr is None else f'{thr:g}'})")
    for r in payload["masking"]["rows"]:
        flag = "MASKS" if r["masks_expected"] else "     "
        print(f"[diag_bench] masking {r['scenario']:16s} "
              f"{r['policy']:20s} {flag} "
              f"detection {r['detection_rate']:.2f}")
    print(f"[diag_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
