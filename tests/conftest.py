"""Test config.  NOTE: no XLA_FLAGS here — tests must see the real (single)
CPU device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(script: str, devices: int = 0, timeout: int = 300) -> str:
    """Run a python snippet in a fresh interpreter (optionally with N forced
    host devices) and return stdout; raises on nonzero exit."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}\nstdout:\n{out.stdout[-2000:]}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
