"""Parallel multi-seed scenario sweeps: fleets of ``(scenario, seed)`` cells.

One simulated run answers "what happened here"; a sweep runs a grid of
scenarios × workloads × seeds and feeds
:func:`repro.core.analysis.aggregate` so the question becomes "how does
the fleet behave" — detection rates per fault class, latency percentiles
per component, end-to-end request-latency tails, critical-path frequency —
the aggregate-driven reading of traces rather than eyeballing single runs.

The workload axis (``workloads=("collective", "rpc", ...)``) re-runs every
scenario under each listed workload type; the default (``None``) keeps
each scenario's own pinned workload, so the curated library sweeps exactly
as published.  The mitigations axis (``mitigations=("do_nothing",
"retransmit", ...)``) re-runs every cell under each listed remediation
policy so :func:`repro.core.analysis.score_mitigations` can rank them
against the ``do_nothing`` baseline on the *same* fault trace.

Execution model: each cell runs the existing
:class:`~repro.sim.scenarios.ScenarioSpec` → ``TraceSpec``/``ExecutionEngine``
path end to end in its own process (``jobs > 1`` uses a multiprocessing
pool) and streams its SpanJSONL to a per-cell shard under
``<outdir>/shards/``.  Cells are fully independent and individually seeded,
so:

* ``--jobs 8`` produces byte-identical shard files to ``--jobs 1`` (only
  completion order differs — shard *content* is pinned by the cell's seed);
* a sweep is resumable/auditable: ``sweep.json`` records every cell's
  verdict and pre-reduced :class:`~repro.core.analysis.RunStats`, and
  :func:`load_sweep` re-hydrates a finished sweep without re-simulating.

CLI: ``python -m repro.launch.trace --sweep --jobs 8`` (see docs/sweeps.md).
"""
from __future__ import annotations

import atexit
import json
import multiprocessing
import os
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .scenarios import SCENARIOS, ScenarioSpec, get_scenario

SWEEP_SCHEMA = "columbo.sweep/v5"
_SWEEP_SCHEMAS = (
    "columbo.sweep/v1", "columbo.sweep/v2", "columbo.sweep/v3",
    "columbo.sweep/v4", SWEEP_SCHEMA
)


@dataclass(frozen=True)
class SweepSpec:
    """A grid of ``(scenario, workload, mitigation, magnitude, rate, seed)``
    cells plus topology overrides.

    Inert and declarative like :class:`~repro.sim.scenarios.ScenarioSpec`:
    build once, run with any ``--jobs``, get the same shards.
    ``workloads`` (when set) re-runs every scenario under each listed
    workload type; ``None`` keeps each scenario's own pinned workload.
    ``mitigations`` (when set) re-runs every cell under each listed
    remediation policy (``None`` keeps each scenario's own — normally the
    ``do_nothing`` baseline).
    ``magnitudes`` (when set) re-runs every cell at each listed
    fault-magnitude (scaling every fault via
    :meth:`~repro.sim.faults.FaultSpec.scaled`) — the axis detection-
    sensitivity curves are traced over; ``None`` keeps each scenario's own
    ``fault_magnitude`` (normally full intensity, 1.0).
    ``arrival_rates`` (when set) re-runs every cell at each listed open-loop
    arrival rate (rps) — the saturation axis; combined with ``n_pods`` it
    traces arrival-rate × fleet-size load curves.  It sets the rpc
    workload's ``rate_rps`` knob, so rate cells must resolve to the ``rpc``
    workload (pin ``workloads=("rpc",)`` or sweep rpc scenarios).
    ``queue_depth`` / ``lb`` (scalars, not axes) pass the bounded-FIFO and
    load-balancer-policy knobs to every rate cell.
    ``n_pods``/``chips_per_pod``/``fabric``/``n_steps`` (when not ``None``)
    override every scenario in the grid — e.g. re-running the curated
    library on a 64-pod fat-tree.
    """

    scenarios: Tuple[str, ...]
    seeds: Tuple[int, ...]
    workloads: Optional[Tuple[str, ...]] = None   # None -> scenario's own
    mitigations: Optional[Tuple[str, ...]] = None  # None -> scenario's own
    magnitudes: Optional[Tuple[float, ...]] = None  # None -> scenario's own
    arrival_rates: Optional[Tuple[float, ...]] = None  # None -> workload's own
    n_pods: Optional[int] = None
    chips_per_pod: Optional[int] = None
    fabric: Optional[str] = None
    n_steps: Optional[int] = None
    queue_depth: Optional[int] = None   # rpc bounded-FIFO knob for rate cells
    lb: Optional[str] = None            # rpc LB-policy knob for rate cells

    def overrides(self) -> Dict[str, Any]:
        """The non-``None`` grid-wide overrides for every cell.  The
        topology/size keys are ScenarioSpec fields; ``queue_depth``/``lb``
        are rpc workload knobs the cell runner folds into
        ``workload_params``."""
        out: Dict[str, Any] = {}
        for k in ("n_pods", "chips_per_pod", "fabric", "n_steps",
                  "queue_depth", "lb"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        return out

    def cells(
        self,
    ) -> List[Tuple[str, Optional[str], Optional[str], Optional[float],
                    Optional[float], int]]:
        """The full ``(scenario, workload, mitigation, magnitude, rate,
        seed)`` grid, scenario-major (deterministic order).  ``workload`` /
        ``mitigation`` / ``magnitude`` / ``rate`` are ``None`` when the
        cell keeps its scenario's own pinned type/policy/intensity/rate."""
        wls: Tuple[Optional[str], ...] = self.workloads or (None,)
        mits: Tuple[Optional[str], ...] = self.mitigations or (None,)
        mags: Tuple[Optional[float], ...] = self.magnitudes or (None,)
        rates: Tuple[Optional[float], ...] = self.arrival_rates or (None,)
        return [
            (s, w, m, g, r, seed)
            for s in self.scenarios for w in wls for m in mits for g in mags
            for r in rates for seed in self.seeds
        ]

    @classmethod
    def library(cls, seeds: Sequence[int] = (0,), **overrides: Any) -> "SweepSpec":
        """The whole curated scenario library × ``seeds``."""
        return cls(scenarios=tuple(SCENARIOS), seeds=tuple(seeds), **overrides)


@dataclass
class CellResult:
    """One finished ``(scenario, workload, mitigation, magnitude, rate,
    seed)`` cell."""

    scenario: str
    seed: int
    ok: bool                    # expected fault classes ⊆ diagnosed classes
    shard: str                  # SpanJSONL shard path, relative to the sweep outdir
    stats: "Any"                # core.analysis.RunStats (pre-reduced spans)
    workload: Optional[str] = None    # explicit sweep-axis workload (None = own)
    mitigation: Optional[str] = None  # explicit sweep-axis policy (None = own)
    magnitude: Optional[float] = None  # explicit sweep-axis magnitude (None = own)
    rate: Optional[float] = None      # explicit sweep-axis arrival rate (rps)


def _shard_name(
    scenario: str,
    workload: Optional[str],
    mitigation: Optional[str],
    magnitude: Optional[float],
    rate: Optional[float],
    seed: int,
) -> str:
    # axis values only appear in the name when the sweep axis set them, so
    # default-library shard names stay exactly as they were pre-axis
    mid = f".{workload}" if workload else ""
    mit = f".{mitigation}" if mitigation else ""
    mag = f".m{magnitude:g}" if magnitude is not None else ""
    rps = f".r{rate:g}" if rate is not None else ""
    return os.path.join(
        "shards", f"{scenario}{mid}{mit}{mag}{rps}.seed{seed}.spans.jsonl"
    )


# grid-wide override keys that are rpc workload knobs, not ScenarioSpec
# fields — the cell runner folds them into the cell's workload_params
_WORKLOAD_OVERRIDE_KEYS = ("queue_depth", "lb")


def _run_cell(
    args: Tuple[str, Optional[str], Optional[str], Optional[float],
                Optional[float], int, Dict[str, Any], str, bool, str]
) -> Dict[str, Any]:
    """Worker: run one cell end to end (simulate → weave → diagnose),
    write its SpanJSONL shard, return a JSON-serializable summary.

    Top-level (picklable) so multiprocessing pools can dispatch it; every
    random draw inside comes from the cell's seeded fault plan, workload,
    and mitigation streams, so the result is independent of which worker
    runs it.  ``structured`` cells take the zero-parse fast path;
    ``weave="inline"``/``"columnar"`` cells assemble spans during the
    simulation and reduce them through the columnar
    ``RunStats.from_columns`` path (columnar cells build the columns at
    emit, no Span round-trip for the reduction); shard bytes are
    identical whichever path ran.
    """
    from ..core.analysis import RunStats

    (scenario, workload, mitigation, magnitude, rate, seed,
     overrides, outdir, structured, weave) = args
    spec: ScenarioSpec = get_scenario(scenario)
    if workload is not None and workload != spec.workload:
        # cross-type axis override: the pinned type's knobs don't transfer
        spec = replace(spec, workload=workload, workload_params=())
    if mitigation is not None and mitigation != spec.mitigation:
        # axis cells bypass run()'s masking check by design: a mitigation
        # sweep *scores* policies; it does not assert diagnosis
        spec = replace(spec, mitigation=mitigation, mitigation_params=())
    if magnitude is not None:
        spec = replace(spec, fault_magnitude=magnitude)
    if overrides:
        overrides = dict(overrides)
        wl_knobs = {k: overrides.pop(k) for k in _WORKLOAD_OVERRIDE_KEYS
                    if k in overrides}
        if overrides:
            spec = replace(spec, **overrides)
    else:
        wl_knobs = {}
    if rate is not None:
        # the saturation axis: rate_rps is an rpc workload knob (a non-rpc
        # cell raises make_workload's TypeError — never silently ignored)
        wl_knobs["rate_rps"] = rate
    if wl_knobs:
        params = dict(spec.workload_params)
        params.update(wl_knobs)
        spec = replace(spec, workload_params=tuple(params.items()))
    t0 = time.perf_counter()
    run = spec.run(seed=seed, structured=structured, weave=weave)
    wall = time.perf_counter() - t0
    shard = _shard_name(scenario, workload, mitigation, magnitude, rate, seed)
    with open(os.path.join(outdir, shard), "w", buffering=1 << 20) as f:
        f.write(run.span_jsonl)
    kwargs = dict(
        scenario=scenario,
        seed=run.plan.seed,
        expected=spec.expected_classes,
        detected=run.detected,
        wall_s=wall,
        events=run.cluster.sim.events_executed,
        mitigation=spec.mitigation,
        findings=run.diagnosis.findings,
        expected_components=spec.expected_components,
        diag_wall_s=run.diag_wall_s,
        magnitude=spec.fault_magnitude,
        late_events=run.session.late_events,
    )
    if weave == "post":
        stats = RunStats.from_spans(run.spans, **kwargs)
    else:
        # inline runs reduce through the columnar span records; values are
        # identical to from_spans (asserted in tests/test_streaming_weave.py)
        stats = RunStats.from_columns(
            run.session.columns(), spans=run.spans, **kwargs
        )
    return {"scenario": scenario, "workload": workload,
            "mitigation": mitigation, "magnitude": magnitude, "rate": rate,
            "seed": seed, "ok": run.ok, "shard": shard,
            "stats": stats.to_dict()}


# ---------------------------------------------------------------------------
# Persistent worker pool
# ---------------------------------------------------------------------------
#
# Pool startup is what made wall_s_by_jobs flat (9.4/8.2/8.7 s at 1/4/8
# jobs): every run_sweep() paid worker spawn + interpreter warm-up, which
# dominates small sweeps.  The pool is now a module-level singleton keyed by
# (jobs, start_method): repeated sweeps — the bench's per-jobs timings, a
# notebook's iterate-on-a-sweep loop — reuse warm workers whose imports and
# registries are already paid for.  Shard bytes depend only on the cell's
# seed (ids reset per run), so worker reuse cannot leak state across cells.

_POOL: Optional[Any] = None
_POOL_KEY: Optional[Tuple[int, str]] = None


def _worker_warm() -> None:
    """Pool initializer: pay each worker's heavy imports and registry
    builds once at pool creation instead of inside its first cell."""
    from ..core import analysis, parsers, pipeline  # noqa: F401
    from . import mitigation, workload  # noqa: F401

    workload.list_workloads()       # load + register builtin workloads
    mitigation.list_mitigations()   # load + register builtin mitigations


def _pool_for(jobs: int) -> Any:
    """The persistent worker pool for ``jobs`` (created or reused)."""
    global _POOL, _POOL_KEY
    methods = multiprocessing.get_all_start_methods()
    method = "fork" if "fork" in methods else "spawn"
    key = (jobs, method)
    if _POOL is not None and _POOL_KEY == key:
        return _POOL
    shutdown_pool()
    ctx = multiprocessing.get_context(method)
    _POOL = ctx.Pool(jobs, initializer=_worker_warm)
    _POOL_KEY = key
    return _POOL


def shutdown_pool() -> None:
    """Tear down the persistent sweep pool (idempotent; also runs at
    interpreter exit).  Call between benchmarks that must not share warm
    workers."""
    global _POOL, _POOL_KEY
    if _POOL is not None:
        _POOL.close()
        _POOL.join()
        _POOL = None
        _POOL_KEY = None


atexit.register(shutdown_pool)


@dataclass
class SweepResult:
    """Everything a sweep produced (or re-loaded via :func:`load_sweep`)."""

    outdir: str
    jobs: int
    spec: SweepSpec
    cells: List[CellResult] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        """True when every cell's diagnosis matched its injection."""
        return all(c.ok for c in self.cells)

    def run_stats(self) -> List["Any"]:
        """The per-cell :class:`~repro.core.analysis.RunStats` list."""
        return [c.stats for c in self.cells]

    def aggregate(self) -> "Any":
        """Merge all cells into an :class:`~repro.core.analysis.AggregateReport`."""
        from ..core.analysis import aggregate

        return aggregate(self.run_stats())

    def shard_paths(self) -> List[str]:
        """Absolute paths of every cell's SpanJSONL shard."""
        return [os.path.join(self.outdir, c.shard) for c in self.cells]

    def merge_shards(self, out_path: str) -> int:
        """Merge every shard into one globally ordered SpanJSONL file."""
        from ..core.exporters import merge_span_jsonl

        return merge_span_jsonl(self.shard_paths(), out_path)

    def score_mitigations(self, baseline: str = "do_nothing") -> "Any":
        """Rank the sweep's policies against ``baseline`` on the shared
        fault trace (:func:`repro.core.analysis.score_mitigations`)."""
        from ..core.analysis import score_mitigations

        return score_mitigations(self.run_stats(), baseline=baseline)

    def report(self, aggregate_report: Optional["Any"] = None) -> str:
        """Cell verdict table + the aggregate rollup (pass a precomputed
        ``aggregate()`` result to avoid pooling the samples twice).  When
        the sweep set a ``mitigations`` axis, the per-policy scoreboard is
        appended."""
        wl_axis = (f" x {len(self.spec.workloads)} workloads"
                   if self.spec.workloads else "")
        mit_axis = (f" x {len(self.spec.mitigations)} mitigations"
                    if self.spec.mitigations else "")
        mag_axis = (f" x {len(self.spec.magnitudes)} magnitudes"
                    if self.spec.magnitudes else "")
        rate_axis = (f" x {len(self.spec.arrival_rates)} rates"
                     if self.spec.arrival_rates else "")
        lines = [
            f"sweep: {len(self.cells)} cells "
            f"({len(self.spec.scenarios)} scenarios{wl_axis}{mit_axis}"
            f"{mag_axis}{rate_axis} x {len(self.spec.seeds)} seeds, "
            f"jobs={self.jobs}) -> {self.outdir}",
        ]
        for c in self.cells:
            verdict = "OK    " if c.ok else "MISSED"
            wl = f" [{c.workload}]" if c.workload else ""
            mit = f" [{c.mitigation}]" if c.mitigation else ""
            mag = f" [m={c.magnitude:g}]" if c.magnitude is not None else ""
            rps = f" [r={c.rate:g}]" if c.rate is not None else ""
            lines.append(f"  {verdict} {c.scenario:24s}{wl}{mit}{mag}{rps} "
                         f"seed={c.seed:<4d} "
                         f"spans={c.stats.n_spans:<5d} wall={c.stats.wall_s:.2f}s")
        lines.append((aggregate_report or self.aggregate()).report())
        if self.spec.mitigations:
            lines.append(self.score_mitigations().report())
        return "\n".join(lines)


def run_sweep(
    spec: SweepSpec, outdir: str, jobs: int = 1, structured: bool = False,
    weave: str = "post",
) -> SweepResult:
    """Run every cell of ``spec``, streaming shards into ``outdir``.

    ``jobs > 1`` distributes cells over the persistent warm pool
    (:func:`shutdown_pool` tears it down); results are collected in grid
    order regardless of completion order, and each shard's bytes depend
    only on its cell coordinates — the parallel-equals-serial equivalence
    asserted in ``tests/test_sweep.py``.  Small cells are batched with a
    chunksize so per-task dispatch overhead doesn't dominate.  Writes
    ``sweep.json`` (cells + RunStats) next to the shards.

    ``structured=True`` runs every cell on the zero-parse structured fast
    path (no text logs are formatted or parsed); shard bytes stay
    identical to text-path shards — only the wall clock moves — so the
    flag is pure execution policy, recorded in ``sweep.json`` for audit.
    ``weave="inline"`` goes further: each cell's spans assemble *during*
    its simulation (``core.streaming.StreamingWeaver``) and reduce through
    the columnar analysis path — still byte-identical shards.
    ``weave="columnar"`` keeps the net span records in column arrays end
    to end and renders each cell's shard array-natively — byte-identical
    again.  The ``"sharded"`` mode is per-run export parallelism and would
    fight the sweep's own per-cell workers, so it is rejected here.
    """
    from ..core.analysis import RunStats

    if weave not in ("post", "inline", "columnar"):
        raise ValueError(
            f"run_sweep weave must be 'post', 'inline', or 'columnar', got "
            f"{weave!r} (sharded export parallelizes a single run; a sweep "
            f"already parallelizes across cells via jobs=)"
        )
    if weave != "post" and structured:
        raise ValueError(
            "structured=True is the post-hoc fast path; "
            "weave='inline'/'columnar' replaces it (pick one)"
        )
    os.makedirs(os.path.join(outdir, "shards"), exist_ok=True)
    work = [
        (s, w, m, g, r, seed, spec.overrides(), outdir, structured, weave)
        for s, w, m, g, r, seed in spec.cells()
    ]
    if jobs <= 1 or len(work) <= 1:
        raw = [_run_cell(w) for w in work]
    else:
        pool = _pool_for(jobs)
        raw = pool.map(_run_cell, work,
                       chunksize=max(1, len(work) // (jobs * 4)))
    cells = [
        CellResult(
            scenario=r["scenario"], seed=r["seed"], ok=r["ok"], shard=r["shard"],
            stats=RunStats.from_dict(r["stats"]), workload=r.get("workload"),
            mitigation=r.get("mitigation"), magnitude=r.get("magnitude"),
            rate=r.get("rate"),
        )
        for r in raw
    ]
    result = SweepResult(outdir=outdir, jobs=jobs, spec=spec, cells=cells)
    payload = {
        "schema": SWEEP_SCHEMA,
        "scenarios": list(spec.scenarios),
        "seeds": list(spec.seeds),
        "workloads": list(spec.workloads) if spec.workloads else None,
        "mitigations": list(spec.mitigations) if spec.mitigations else None,
        "magnitudes": list(spec.magnitudes) if spec.magnitudes else None,
        "arrival_rates": (list(spec.arrival_rates)
                          if spec.arrival_rates else None),
        "overrides": spec.overrides(),
        "jobs": jobs,
        "structured": structured,
        "weave": weave,
        "cells": raw,
    }
    with open(os.path.join(outdir, "sweep.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return result


def load_sweep(outdir: str) -> SweepResult:
    """Re-hydrate a finished sweep from its ``sweep.json`` (no simulation).

    The pre-reduced RunStats come straight from the summary; shard files
    remain on disk for deeper re-analysis
    (:meth:`SweepResult.merge_shards`, ``RunStats.from_jsonl``).
    """
    from ..core.analysis import RunStats

    with open(os.path.join(outdir, "sweep.json")) as f:
        payload = json.load(f)
    if payload.get("schema") not in _SWEEP_SCHEMAS:
        raise ValueError(
            f"{outdir}/sweep.json has schema {payload.get('schema')!r}, "
            f"expected one of {_SWEEP_SCHEMAS!r}"
        )
    workloads = payload.get("workloads")
    mitigations = payload.get("mitigations")
    magnitudes = payload.get("magnitudes")
    arrival_rates = payload.get("arrival_rates")
    spec = SweepSpec(
        scenarios=tuple(payload["scenarios"]),
        seeds=tuple(payload["seeds"]),
        workloads=tuple(workloads) if workloads else None,
        mitigations=tuple(mitigations) if mitigations else None,
        magnitudes=tuple(magnitudes) if magnitudes else None,
        arrival_rates=tuple(arrival_rates) if arrival_rates else None,
        **payload.get("overrides", {}),
    )
    cells = [
        CellResult(
            scenario=r["scenario"], seed=r["seed"], ok=r["ok"], shard=r["shard"],
            stats=RunStats.from_dict(r["stats"]), workload=r.get("workload"),
            mitigation=r.get("mitigation"), magnitude=r.get("magnitude"),
            rate=r.get("rate"),
        )
        for r in payload["cells"]
    ]
    return SweepResult(outdir=outdir, jobs=int(payload.get("jobs", 1)), spec=spec, cells=cells)
