"""RPC request/response serving workload — one span tree per request.

The frontend (the first chip-bearing host) admits requests under an
**open-loop** Poisson arrival process (seeded, so byte-reproducible) or a
**closed-loop** fixed-concurrency process, fans each request out across
every serving pod over the interconnect, and fans the replies back in.
Every log event of a request carries its trace-context id (``rid`` /
``sub``), so the weave produces one end-to-end tree per request::

    RpcRequest r3                         (frontend host)
    ├── RpcCall r3.host0                  (local pod, no wire hop)
    │   └── RpcWork r3.host0
    │       └── Dispatch ×chips → DeviceProgram → Op / Collective
    │           └── LinkTransfer ×ICI ring chunks
    └── RpcCall r3.host1                  (remote pod)
        ├── LinkTransfer dcn.h0h1         (request leg)
        └── RpcWork r3.host1
            ├── Dispatch ×chips → DeviceProgram → ...
            └── LinkTransfer dcn.h0h1     (reply leg, "<sub>.r")

Serving is **serial per host** (one subrequest at a time, FIFO queue), so
queueing delay under open-loop overload shows up as RpcCall-minus-RpcWork
time — the tail-latency signal ``core.analysis.request_latency_stats``
summarizes and ``slowest_request`` drills into.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import ClassVar, Optional, TYPE_CHECKING

from ..hostsim import _short
from ..workload import OpSpec, ProgramSpec, Workload, register_workload

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..cluster import ClusterOrchestrator
    from ..hostsim import HostSim

PS_PER_S = 1_000_000_000_000


def rpc_handler_program(
    name: str = "rpc_infer",
    tp_bytes: float = 1 << 20,
    flops: float = 2e11,
    hbm_bytes: float = 1e8,
) -> ProgramSpec:
    """The default per-request handler: a tensor-parallel inference step
    over the serving pod's ICI ring (all-gather → compute → all-reduce).
    Cross-pod (DCN-group) ops are deliberately absent: a request is served
    entirely inside one pod."""
    return ProgramSpec(name, [
        OpSpec(name="tp.ag", kind="all-gather", coll_bytes=tp_bytes),
        OpSpec(name="infer.ffn", kind="compute", flops=flops, bytes=hbm_bytes),
        OpSpec(name="tp.ar", kind="all-reduce", coll_bytes=tp_bytes),
    ])


def _ici_only(program: ProgramSpec) -> ProgramSpec:
    """Strip cross-pod (DCN-group) ops and their waits from a program.

    A request is served by one pod; a DCN-group op would rendezvous with
    homologue chips in pods that never join this request's collective and
    stall the request forever.  Sweeping ``workload=rpc`` over scenarios
    whose program is a training step therefore serves the ICI-only part.
    """
    dcn_names = {o.name for o in program.ops if o.group == "dcn"}
    ops = [
        o for o in program.ops
        if o.group != "dcn" and not (o.kind == "wait" and o.wait_for in dcn_names)
    ]
    if ops == program.ops:
        return program
    return ProgramSpec(name=program.name, ops=ops)


@dataclass
class _PodServer:
    """Per-host serving state: FIFO of pending subrequests + busy flag."""

    host: "HostSim"
    queue: deque = field(default_factory=deque)
    busy: bool = False


@register_workload
@dataclass
class RpcServing(Workload):
    """Open/closed-loop request serving with per-request trace contexts.

    Knobs beyond the standard five:

    * ``n_requests``    — total requests (default ``4 * n_steps`` so sweep
      size overrides scale serving cells too);
    * ``arrival``       — ``"open"`` (Poisson at ``rate_rps``, seeded) or
      ``"closed"`` (``concurrency`` outstanding requests, next issued on
      completion);
    * ``rate_rps`` / ``concurrency`` — the two loops' intensity dials;
    * ``request_bytes`` / ``reply_bytes`` — wire payloads per fan-out leg;
    * ``dequeue_ps``    — fixed host-runtime cost to pick up a subrequest.

    The handler program is ``program`` with any DCN-group ops stripped
    (see :func:`_ici_only`); scenarios that mean serving from the start
    pass :func:`rpc_handler_program` directly.
    """

    workload_name: ClassVar[str] = "rpc"

    n_requests: Optional[int] = None
    arrival: str = "open"                 # "open" | "closed"
    rate_rps: float = 2000.0
    concurrency: int = 4
    request_bytes: int = 32 << 10
    reply_bytes: int = 64 << 10
    dequeue_ps: int = 200_000             # 0.2 us runtime pickup cost

    def __post_init__(self) -> None:
        if self.arrival not in ("open", "closed"):
            raise ValueError(
                f"arrival must be 'open' or 'closed', got {self.arrival!r}"
            )

    @property
    def total_requests(self) -> int:
        """The effective request count (``n_requests`` or ``4 * n_steps``)."""
        return self.n_requests if self.n_requests is not None else 4 * self.n_steps

    def describe(self) -> str:
        loop = (f"open {self.rate_rps:g} rps" if self.arrival == "open"
                else f"closed x{self.concurrency}")
        return f"rpc({self.total_requests} reqs, {loop})"

    # -- driving -----------------------------------------------------------------

    def drive(self, cluster: "ClusterOrchestrator") -> None:
        """Arm arrivals at the frontend + serial per-pod serving queues."""
        hosts = self.serving_hosts(cluster)
        if not hosts:
            raise ValueError("rpc workload needs at least one chip-bearing host")
        frontend = hosts[0]
        handler = _ici_only(self.program)
        servers = {h.name: _PodServer(h) for h in hosts}
        sub_steps = itertools.count()     # unique dispatch-step int per sub
        n_total = self.total_requests
        state = {"issued": 0, "completed": 0}

        for h in hosts:
            self.start_clock_telemetry(h)

        def serve_next(srv: _PodServer) -> None:
            if not srv.queue:
                srv.busy = False
                return
            srv.busy = True
            sub, rid, reply = srv.queue.popleft()
            srv.host.sim.call_after(
                self.dequeue_ps, lambda: begin_work(srv, sub, rid, reply)
            )

        def begin_work(srv: _PodServer, sub: str, rid: str, reply) -> None:
            h = srv.host
            h.log_event("rpc_work_begin", sub=sub, rid=rid)
            # an injected HostPause stall drains at the subrequest boundary,
            # *after* rpc_work_begin so the gc_stall event lands inside this
            # request's RpcWork span (per-request diagnosis sees it)
            stall = h.consume_stall(sub=sub, rid=rid)
            if stall:
                h.sim.call_after(stall, lambda: run_handler(srv, sub, rid, reply))
            else:
                run_handler(srv, sub, rid, reply)

        def run_handler(srv: _PodServer, sub: str, rid: str, reply) -> None:
            h = srv.host
            step = next(sub_steps)
            pending = {"n": len(h.chips)}

            def chip_done(chip: str, _t: int) -> None:
                h.log_event("program_retire", chip=_short(chip), step=step,
                            program=handler.name)
                pending["n"] -= 1
                if pending["n"] == 0:
                    h.log_event("rpc_work_end", sub=sub, rid=rid)
                    reply()
                    serve_next(srv)

            for chip in h.chips:
                h.log_event("program_enqueue", chip=_short(chip), step=step,
                            program=handler.name)
                cluster.dispatch(h, chip, handler, step, chip_done)

        def enqueue(srv: _PodServer, sub: str, rid: str, reply) -> None:
            srv.queue.append((sub, rid, reply))
            if not srv.busy:
                serve_next(srv)

        def admit(i: int) -> None:
            rid = f"r{i}"
            t0 = frontend.sim.now
            frontend.log_event("rpc_recv", rid=rid, bytes=self.request_bytes)
            pending = {"n": len(hosts)}

            def fan_in(sub: str) -> None:
                frontend.log_event("rpc_reply", rid=rid, sub=sub)
                pending["n"] -= 1
                if pending["n"] == 0:
                    frontend.log_event(
                        "rpc_done", rid=rid, lat=frontend.sim.now - t0,
                        fanout=len(hosts),
                    )
                    state["completed"] += 1
                    if self.arrival == "closed" and state["issued"] < n_total:
                        issue_now()
                    if state["completed"] == n_total:
                        cluster.net.stop_all_flows()

            for h in hosts:
                sub = f"{rid}.{h.name}"
                frontend.log_event("rpc_send", rid=rid, sub=sub, dst=h.name,
                                   bytes=self.request_bytes)
                if h is frontend:
                    # local pod: no wire hop, reply is a local fan-in
                    enqueue(servers[h.name], sub, rid,
                            lambda s=sub: fan_in(s))
                else:
                    def deliver(_t: int, hh=h, s=sub) -> None:
                        enqueue(servers[hh.name], s, rid,
                                lambda: send_reply(hh, s))

                    def send_reply(hh: "HostSim", s: str) -> None:
                        cluster.net.transfer(
                            hh.name, frontend.name, self.reply_bytes,
                            meta={"rpc": f"{s}.r"},
                            on_delivered=lambda _t, s=s: fan_in(s),
                        )

                    cluster.net.transfer(
                        frontend.name, h.name, self.request_bytes,
                        meta={"rpc": sub}, on_delivered=deliver,
                    )

        def issue_now() -> None:
            i = state["issued"]
            state["issued"] += 1
            admit(i)

        if self.arrival == "open":
            # pre-draw the whole Poisson arrival schedule (deterministic)
            rng = self.rng(stream=0)
            t = 0.0
            for i in range(n_total):
                t += rng.expovariate(self.rate_rps) * PS_PER_S
                frontend.sim.at(int(t), issue_now)
        else:
            for _ in range(min(self.concurrency, n_total)):
                issue_now()
