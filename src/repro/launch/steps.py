"""Step functions lowered by the dry-run, trainer, and server."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import decode_step, prefill
from ..training.train_step import TrainConfig, make_train_step
from .specs import Cell


def make_step_fn(cell: Cell, tc: Optional[TrainConfig] = None) -> Callable:
    cfg = cell.cfg
    if cell.kind == "train":
        tc = tc or TrainConfig(microbatches=cell.microbatches)
        return make_train_step(cfg, tc)

    if cell.kind == "prefill":

        def prefill_step(params, batch):
            logits, cache = prefill(
                cfg, params, tokens=batch.get("tokens"), embeds=batch.get("embeds")
            )
            # serving returns last-position logits + populated cache
            return logits[:, -1, :], cache

        return prefill_step

    def serve_step(params, batch, cache, pos):
        logits, new_cache = decode_step(cfg, params, batch["tokens"], cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, new_cache

    return serve_step
