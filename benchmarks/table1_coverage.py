"""Table 1: supported simulator types and event/span type counts.

Paper: host 16/6, NIC 9/4, network 3/1.  Ours maps gem5->device (chip),
NIC->host runtime, ns3->net interconnect.
"""
import time

PAPER = {"host": (16, 6), "device": (9, 4), "net": (3, 1)}


def run():
    from repro.core import event_type_counts, span_type_counts

    t0 = time.perf_counter()
    ev = event_type_counts()
    sp = span_type_counts()
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for k in ("host", "device", "net"):
        pe, ps = PAPER[k]
        rows.append(
            (
                f"table1.{k}",
                us,
                f"events={ev[k]}/paper{pe} spans={sp[k]}/paper{ps} ok={ev[k] >= pe and sp[k] >= ps}",
            )
        )
    return rows
