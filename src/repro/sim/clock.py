"""Global virtual clock + log plumbing for the component simulators.

The DES kernel itself lives in :mod:`repro.sim.engine` (``EventKernel``);
this module keeps the historic ``Sim`` name importable and owns the per
simulator *log sinks*.  The kernel's global clock is the "true and precise
global clock for all events" the paper highlights as a key advantage of
simulation (§1 advantage iii).  Times are integer picoseconds.

Two sinks implement one emit interface (``emit_host`` / ``emit_device`` /
``emit_net``, one method per ad-hoc log flavour):

* :class:`LogWriter` — the compatibility default: formats each event into
  the simulator's ad-hoc text line (SimBricks / gem5 / ns3 flavour) and
  writes it to a file, named pipe, or in-memory line list.  This is the
  paper's world: text logs are the only interface Columbo consumes.
* :class:`StructuredLogWriter` — the zero-parse fast path: captures each
  emit as a compact record (no f-string work on the simulation's hot path)
  and materializes typed :class:`~repro.core.events.Event` objects on
  demand, bypassing the format -> parse round-trip entirely.  The weave is
  byte-identical to the text path (asserted against ``tests/golden/`` and
  property-tested across the scenario library in
  ``tests/test_structured.py``).
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional

from .engine import EventHandle, EventKernel, PeriodicTask, Sim, SimPort

__all__ = [
    "EventHandle", "EventKernel", "InlineWeaveWriter", "LogWriter",
    "PeriodicTask", "Sim", "SimPort", "StructuredLogWriter",
]

PS_PER_S = 1_000_000_000_000


def _fmt_s(ps: int) -> str:
    # ns3 ascii traces carry seconds with 12 decimals (= ps resolution)
    return f"{ps / PS_PER_S:.12f}"


class LogWriter:
    """Collects one simulator instance's ad-hoc log lines.

    Lines buffer in memory and flush to a file (or named pipe for §3.8
    online mode) — simulators in the paper write files; ours do too.

    The three ``emit_*`` methods own the ad-hoc text formats (one per
    simulator type); component sims call them instead of formatting
    inline, so :class:`StructuredLogWriter` can override them and skip
    text entirely while the formats themselves stay byte-identical.
    """

    #: True on sinks that capture events structurally instead of as text.
    structured = False

    def __init__(self, path: Optional[str] = None, stream=None) -> None:
        self.path = path
        self.lines: List[str] = []
        self._stream = stream
        if path is not None and stream is None:
            self._stream = open(path, "w", buffering=1 << 20)

    def write(self, line: str) -> None:
        if self._stream is not None:
            self._stream.write(line)
            self._stream.write("\n")
        else:
            self.lines.append(line)

    # -- per-simulator-type emit interface -----------------------------------
    #
    # SimBricks nicbm flavour / gem5 flavour / ns3 ascii-trace flavour; the
    # exact f-strings the sims historically produced, byte for byte.  Each
    # emit takes ONE pre-built record tuple so the structured sink can bind
    # ``emit_* = records.append`` and capture with zero Python frames.

    def emit_host(self, rec: tuple) -> None:
        ts, host, kind, attrs = rec
        kv = " ".join(f"{k}={v}" for k, v in attrs.items())
        self.write(f"main_time = {ts}: hostsim-{host}: ev={kind} {kv}")

    def emit_device(self, rec: tuple) -> None:
        ts, chip, name, attrs = rec
        kv = " ".join(f"{k}={v}" for k, v in attrs.items())
        self.write(f"{ts}: system.{chip}: {name}: {kv}")

    def emit_net(self, rec: tuple) -> None:
        ts, mark, link, chunk, size, meta = rec
        extra = " ".join(f"{k}={v}" for k, v in meta.items())
        self.write(
            f"{mark} {_fmt_s(ts)} /{link.replace('.', '/')} "
            f"chunk={chunk} size={size}" + (f" {extra}" if extra else "")
        )

    def close(self) -> None:
        if self._stream is not None:
            self._stream.close()
            self._stream = None

    def __enter__(self) -> "LogWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class StructuredLogWriter(LogWriter):
    """Zero-parse event sink: the structured fast path's capture side.

    ``emit_*`` appends one compact tuple per log event — no f-string
    formatting, no file I/O — so the simulation's hot path pays a list
    append instead of text assembly.  :meth:`events` then materializes the
    typed :class:`~repro.core.events.Event` stream the weavers consume,
    using the *same* kind/name/mark lookup tables the text parsers use and
    normalizing attr values through
    :func:`~repro.core.parsers.coerce_value`, so the woven SpanJSONL is
    byte-identical to the text path's.

    :meth:`render_lines` replays the captured records through the base
    class's text formatting — the exact ad-hoc log the simulator would have
    written — which the benchmarks use to price the format stage and tests
    use to prove the round-trip.
    """

    structured = True

    def __init__(self, sim_type: str) -> None:
        super().__init__()
        self.sim_type = sim_type
        self.records: List[tuple] = []
        # the capture fast path IS list.append: callers pass the record
        # tuple, so a captured event costs one C-level append, no frames
        self.emit_host = self.emit_device = self.emit_net = self.records.append

    def __len__(self) -> int:
        return len(self.records)

    def events(self) -> Iterator["Any"]:
        """Materialize the captured records as typed ``Event`` objects.

        Emitted in capture order (simulators log in virtual-time order, so
        the stream is time-ordered like a parsed log).  Records whose
        kind/name has no registered event class are dropped, exactly as the
        text parsers drop unparseable lines.
        """
        from ..core.parsers import (
            DEVICE_NAME_TO_CLASS,
            HOST_KIND_TO_CLASS,
            NET_MARK_TO_CLASS,
            coerce_value,
        )

        sim_type = self.sim_type
        if sim_type == "host" or sim_type == "device":
            table = HOST_KIND_TO_CLASS if sim_type == "host" else DEVICE_NAME_TO_CLASS
            get = table.get
            for ts, source, kind, attrs in self.records:
                cls = get(kind)
                if cls is None:
                    continue
                # coercion is the identity for ints and non-numeric strings
                # (the overwhelming majority), so the record's dict is
                # reused untouched unless a value actually changes — the
                # capture stays pristine for render_lines() replay
                coerced = None
                for k, v in attrs.items():
                    if type(v) is not int:
                        cv = coerce_value(v)
                        if cv is not v:
                            if coerced is None:
                                coerced = dict(attrs)
                            coerced[k] = cv
                yield cls(ts=ts, source=source,
                          attrs=attrs if coerced is None else coerced)
        elif sim_type == "net":
            get = NET_MARK_TO_CLASS.get
            for ts, mark, link, chunk, size, meta in self.records:
                cls = get(mark)
                if cls is None:
                    continue
                attrs = {"chunk": chunk, "size": size}
                for k, v in meta.items():
                    attrs[k] = v if type(v) is int else coerce_value(v)
                yield cls(ts=ts, source=link, attrs=attrs)
        else:
            raise ValueError(
                f"StructuredLogWriter has no materializer for sim type {sim_type!r}; "
                "custom types need a text parser (the compatibility path)"
            )

    def render_lines(self) -> List[str]:
        """The text log this writer *would* have produced (header included).

        Replays every captured record through :class:`LogWriter`'s emit
        formatting — used by benchmarks to price the format stage in
        isolation and by tests to prove text/structured equivalence.
        """
        out = LogWriter()
        out.lines.extend(self.lines)      # e.g. the "# columbo sim_type=" tag
        emit = getattr(out, f"emit_{self.sim_type}")
        for rec in self.records:
            emit(rec)
        return out.lines


class InlineWeaveWriter(LogWriter):
    """Log sink that weaves spans *during* the simulation (inline path).

    Instead of buffering text (:class:`LogWriter`) or records
    (:class:`StructuredLogWriter`) for a later weave pass, every emit goes
    straight into a :class:`~repro.core.streaming.StreamingWeaver` — the
    third point on the capture spectrum: no format, no parse, no replay, no
    retained event buffer.  The sink stays on the sim side of the layering
    line (``repro.core`` never imports ``repro.sim``): it just binds all
    three ``emit_*`` slots to the callable the weaver's ``attach`` returns
    for this writer, so a captured event costs one closure call.

    Headers and free-form ``write`` lines are discarded — they carry no
    events (the text parsers drop them too).
    """

    structured = False

    def __init__(self, sim_type: str, sink) -> None:
        super().__init__()
        self.sim_type = sim_type
        self.sink = sink
        self.emit_host = self.emit_device = self.emit_net = sink.attach(sim_type)

    def write(self, line: str) -> None:
        pass
