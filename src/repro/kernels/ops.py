"""Public jit'd wrappers around the Pallas kernels.

* ``impl="pallas"`` runs the TPU kernel (``interpret=True`` automatically on
  CPU, which executes the kernel body for correctness validation).
* ``impl="reference"`` runs the pure-jnp oracle (XLA-native; what the
  dry-runs lower so HLO stays representative).

``flash_attention`` is differentiable under impl="pallas": a custom_vjp
runs the kernel forward and takes the backward through the reference
formula (recompute strategy — the classic flash backward; writing dq/dkv
as Pallas kernels is kernels/flash_attention.py's TODO and does not change
the numerics validated here).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention_fwd
from .flash_attention import flash_attention_fwd
from .rglru_scan import rglru_scan_fwd
from .rmsnorm import rmsnorm_fwd
from .ssm_scan import ssm_scan_fwd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_pallas(q, k, v, causal, window, scale, block_q, block_k):
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


def _flash_fwd_rule(q, k, v, causal, window, scale, block_q, block_k):
    o = _flash_pallas(q, k, v, causal, window, scale, block_q, block_k)
    return o, (q, k, v)


def _flash_bwd_rule(causal, window, scale, block_q, block_k, res, do):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_, causal, window, scale),
        q, k, v,
    )
    return vjp(do)


_flash_pallas.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    impl: str = "pallas",
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    if impl == "reference":
        return ref.flash_attention_ref(q, k, v, causal, window, scale)
    return _flash_pallas(q, k, v, causal, window, scale, block_q, block_k)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    valid_len: jax.Array,
    scale: Optional[float] = None,
    impl: str = "pallas",
    block_s: int = 512,
) -> jax.Array:
    if impl == "reference":
        return ref.decode_attention_ref(q, k, v, valid_len, scale)
    return decode_attention_fwd(
        q, k, v, valid_len, scale=scale, block_s=block_s, interpret=_interpret()
    )


# ---------------------------------------------------------------------------
# recurrences
# ---------------------------------------------------------------------------


def rglru_scan(
    a: jax.Array, x: jax.Array, h0: jax.Array, impl: str = "pallas",
    block_w: int = 512,
) -> Tuple[jax.Array, jax.Array]:
    if impl == "reference":
        return ref.rglru_scan_ref(a, x, h0)
    return rglru_scan_fwd(a, x, h0, block_w=block_w, interpret=_interpret())


def ssm_scan(
    a: jax.Array, bx: jax.Array, c: jax.Array, h0: jax.Array,
    impl: str = "pallas", block_d: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    if impl == "reference":
        return ref.ssm_scan_ref(a, bx, c, h0)
    return ssm_scan_fwd(a, bx, c, h0, block_d=block_d, interpret=_interpret())


def rmsnorm(
    x: jax.Array, scale: jax.Array, eps: float = 1e-6, impl: str = "pallas",
    block_r: int = 256,
) -> jax.Array:
    if impl == "reference":
        return ref.rmsnorm_ref(x, scale, eps)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = rmsnorm_fwd(x2, scale, eps=eps, block_r=min(block_r, x2.shape[0]),
                      interpret=_interpret())
    return out.reshape(shape)
