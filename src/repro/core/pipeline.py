"""Simulator-specific pipelines (Columbo §3.5).

A pipeline = Producer -> [Actor...] -> Consumer.

* Producers read+parse one simulator's log (file, named pipe, or an in-memory
  iterable) into the type-specific event stream.
* Actors are optional stream operators (filter / modify / enrich).
* The Consumer is a SpanWeaver (core/weaver.py) that coalesces events into
  spans and performs context propagation.

Stages communicate through bounded message queues (paper: "message queues
that may be distributed over the network").  Two execution modes:

* ``run_sync()``   — single-threaded generator pull; fastest, used by
                     benchmarks and most tests.
* ``start()/join()`` — one thread per pipeline, queue-decoupled from the
                     producer; this is what online mode (§3.8, named pipes)
                     uses so Columbo runs *in parallel* with the simulation.
"""
from __future__ import annotations

import heapq
import os
import queue
import threading
from typing import Callable, Iterable, Iterator, List, Optional, Sequence, Union

from .events import Event
from .parsers import LogParser

_SENTINEL = object()


# ---------------------------------------------------------------------------
# Producers
# ---------------------------------------------------------------------------


class Producer:
    """Yields a type-specific event stream."""

    def events(self) -> Iterator[Event]:
        raise NotImplementedError


class LogFileProducer(Producer):
    """Reads a simulator log file *or named pipe* line by line and parses it.

    Works unchanged for §3.8 online mode: opening a FIFO blocks until the
    simulator opens the write end, and ``readline`` streams until EOF —
    no persistence of the log is ever required.
    """

    def __init__(self, path: Union[str, os.PathLike], parser: LogParser):
        self.path = os.fspath(path)
        self.parser = parser
        self.lines_read = 0
        self.events_emitted = 0

    def events(self) -> Iterator[Event]:
        # newline="" keeps line endings raw, so CRLF logs (Windows-written
        # shards, object-store downloads) reach the rstrip below intact —
        # stripping only "\n" used to leave a trailing "\r" in the last
        # k=v token and silently corrupt that attr's value.  Counters are
        # accumulated in locals and published once: per-line attribute
        # writes were measurable at multi-GB log sizes.
        parse = self.parser
        lines = 0
        emitted = 0
        try:
            with open(self.path, "r", buffering=1 << 20, newline="") as f:
                for line in f:
                    lines += 1
                    ev = parse(line.rstrip("\r\n"))
                    if ev is not None:
                        emitted += 1
                        yield ev
        finally:
            self.lines_read += lines
            self.events_emitted += emitted


class MergedProducer(Producer):
    """Timestamp-ordered k-way merge over shard producers.

    Sharded execution (multipod-scale inputs): one simulator type's log may
    arrive as N shards — per-pod files, rotated segments, object-store
    chunks.  Each shard is internally time-ordered (simulators log in
    virtual-time order), so a heap merge reconstructs the single coherent
    stream one weaver can consume; span output is identical to weaving the
    unsharded log.

    Tie-break contract (``heapq.merge`` semantics, relied on by the
    structured fast path's shard merge in
    ``ClusterOrchestrator.structured_sources``): events with *equal
    timestamps* are emitted in shard-list order — all of shard 0's events
    at time t before any of shard 1's at time t — which preserves original
    order for contiguous splits and is deterministic for interleaved
    shards (asserted in ``tests/test_structured.py``).
    """

    def __init__(self, producers: Sequence[Producer]):
        self.producers = list(producers)

    def events(self) -> Iterator[Event]:
        yield from heapq.merge(
            *(p.events() for p in self.producers), key=lambda ev: ev.ts
        )


class IterableProducer(Producer):
    """Wraps an in-memory iterable of events (tests, replay)."""

    def __init__(self, items: Iterable[Event]):
        self._items = items

    def events(self) -> Iterator[Event]:
        yield from self._items


class LineIterProducer(Producer):
    """Parses an iterable of raw lines (e.g. a socket, a decompressor)."""

    def __init__(self, lines: Iterable[str], parser: LogParser):
        self.lines = lines
        self.parser = parser

    def events(self) -> Iterator[Event]:
        parse = self.parser
        for line in self.lines:
            ev = parse(line)
            if ev is not None:
                yield ev


# ---------------------------------------------------------------------------
# Actors
# ---------------------------------------------------------------------------


class Actor:
    """Stream operator.  ``process`` returns an iterable of events (possibly
    empty) for each input event; ``flush`` may emit trailing events."""

    def process(self, ev: Event) -> Iterable[Event]:
        raise NotImplementedError

    def flush(self) -> Iterable[Event]:
        return ()


class Consumer:
    """Terminal stage (SpanWeaver implements this)."""

    def consume(self, ev: Event) -> None:
        raise NotImplementedError

    def consume_many(self, events: Iterable[Event]) -> int:
        """Batched entry point: drain ``events`` and return how many were
        consumed.  The base implementation loops over :meth:`consume`;
        hot consumers (``SpanWeaver``) override it with a dispatch loop
        that hoists the handler table out of the per-event path."""
        n = 0
        consume = self.consume
        for ev in events:
            consume(ev)
            n += 1
        return n

    def on_finish(self) -> None:
        pass


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


class Pipeline:
    """producer -> actors -> consumer for one simulator's event stream,
    runnable synchronously or as a thread (§3.8 online mode)."""

    def __init__(
        self,
        producer: Producer,
        actors: Sequence[Actor] = (),
        consumer: Optional[Consumer] = None,
        name: str = "",
        queue_size: int = 65536,
    ):
        self.producer = producer
        self.actors = list(actors)
        self.consumer = consumer
        self.name = name or f"pipeline-{id(self):x}"
        self.queue_size = queue_size
        self.events_in = 0
        self.events_out = 0
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- shared stage logic -------------------------------------------------

    def _apply_actors(self, ev: Event) -> Iterator[Event]:
        stack = [ev]
        for actor in self.actors:
            nxt: List[Event] = []
            for e in stack:
                nxt.extend(actor.process(e))
            stack = nxt
            if not stack:
                return iter(())
        return iter(stack)

    def _flush_actors(self) -> Iterator[Event]:
        # flush each actor, feeding its trailing events through later actors
        for i, actor in enumerate(self.actors):
            for ev in actor.flush():
                stack = [ev]
                for later in self.actors[i + 1 :]:
                    nxt: List[Event] = []
                    for e in stack:
                        nxt.extend(later.process(e))
                    stack = nxt
                yield from stack

    # -- sync mode ------------------------------------------------------------

    def run_sync(self) -> None:
        # fast path: no actors means the producer stream feeds the
        # consumer's batched entry point directly — no per-event pipeline
        # bookkeeping, one Python frame per batch.  getattr keeps
        # duck-typed consumers (not derived from Consumer) working.
        consume_many = (
            getattr(self.consumer, "consume_many", None) if not self.actors else None
        )
        if consume_many is not None:
            n = consume_many(self.producer.events())
            self.events_in += n
            self.events_out += n
            self.consumer.on_finish()
            return
        consume = self.consumer.consume if self.consumer else (lambda e: None)
        for ev in self.producer.events():
            self.events_in += 1
            for out in self._apply_actors(ev):
                self.events_out += 1
                consume(out)
        for out in self._flush_actors():
            self.events_out += 1
            consume(out)
        if self.consumer:
            self.consumer.on_finish()

    # -- threaded mode (online analysis, §3.8) --------------------------------

    def start(self) -> "Pipeline":
        def _run() -> None:
            try:
                self.run_sync()
            except BaseException as e:  # surfaced in join()
                self._error = e

        self._thread = threading.Thread(target=_run, name=self.name, daemon=True)
        self._thread.start()
        return self

    def join(self, timeout: Optional[float] = None) -> None:
        assert self._thread is not None, "start() first"
        self._thread.join(timeout)
        if self._error is not None:
            raise self._error


def make_fifo(path: Union[str, os.PathLike]) -> str:
    """Create a named pipe for §3.8 online mode (idempotent)."""
    path = os.fspath(path)
    if os.path.exists(path):
        os.remove(path)
    os.mkfifo(path)
    return path
