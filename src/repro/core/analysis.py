"""Trace analysis (Columbo §3.2 'Trace analysis', §5 case study figures).

Operates on finalized spans (weaver output).  Provides the analyses used by
the paper's evaluation plus the straggler/fault diagnostics the training
framework exposes as telemetry:

* per-component time breakdown of a trace (Fig. 6);
* clock-offset series from host clock_read events vs. the simulation's
  ground-truth global clock (Fig. 4) and NTP-estimated offsets (Fig. 5);
* critical path through a trace;
* straggler detection across per-chip/per-pod spans (k·MAD outliers).
"""
from __future__ import annotations

import statistics
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .span import Span, Trace, assemble_traces

PS_PER_US = 1_000_000


# ---------------------------------------------------------------------------
# Fig. 6 analogue: where did the time go, per component?
# ---------------------------------------------------------------------------


def component_breakdown(trace: Trace, leaf_only: bool = True) -> Dict[str, float]:
    """Map component -> µs of span time in this trace.

    With ``leaf_only`` (default), a span only contributes the parts of its
    duration not covered by its children, and a component's total is the
    *merged union* of those leaf intervals — overlapping sibling spans
    (async collectives, queued link transfers) count their overlap once, so
    each component's number is the wall-clock time it was busy instead of a
    double-counted sum.
    """
    if not leaf_only:
        out: Dict[str, float] = defaultdict(float)
        for s in trace.spans:
            out[f"{s.sim_type}:{s.component}"] += s.duration / PS_PER_US
        return dict(out)
    children: Dict[int, List[Span]] = defaultdict(list)
    for s in trace.spans:
        if s.parent is not None:
            children[s.parent.span_id].append(s)
    leaf_ivals: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
    for s in trace.spans:
        kids = children.get(s.context.span_id)
        if kids:
            covered = _merge_ivals([(c.start, c.end) for c in kids], s.start, s.end)
            leaf_ivals[f"{s.sim_type}:{s.component}"].extend(
                _subtract_ivals((s.start, s.end), covered)
            )
        else:
            leaf_ivals[f"{s.sim_type}:{s.component}"].append((s.start, s.end))
    return {
        comp: sum(b - a for a, b in _merge_ivals(ivals)) / PS_PER_US
        for comp, ivals in leaf_ivals.items()
    }


def span_name_breakdown(trace: Trace) -> Dict[str, float]:
    out: Dict[str, float] = defaultdict(float)
    for s in trace.spans:
        out[s.name] += s.duration / PS_PER_US
    return dict(out)


def _merge_ivals(
    ivals: Iterable[Tuple[int, int]],
    lo: Optional[int] = None,
    hi: Optional[int] = None,
) -> List[Tuple[int, int]]:
    """Sorted, disjoint union of intervals, optionally clamped to [lo, hi]."""
    clamped = (
        (a if lo is None else max(a, lo), b if hi is None else min(b, hi))
        for a, b in ivals
    )
    merged: List[Tuple[int, int]] = []
    for a, b in sorted(clamped):
        if b <= a:
            continue
        if merged and a <= merged[-1][1]:
            if b > merged[-1][1]:
                merged[-1] = (merged[-1][0], b)
        else:
            merged.append((a, b))
    return merged


def _subtract_ivals(
    span: Tuple[int, int], covered: List[Tuple[int, int]]
) -> List[Tuple[int, int]]:
    """Parts of ``span`` not covered by the merged intervals ``covered``."""
    out: List[Tuple[int, int]] = []
    cur = span[0]
    for a, b in covered:
        if a > cur:
            out.append((cur, min(a, span[1])))
        cur = max(cur, b)
        if cur >= span[1]:
            break
    if cur < span[1]:
        out.append((cur, span[1]))
    return out


# ---------------------------------------------------------------------------
# Critical path
# ---------------------------------------------------------------------------


def critical_path(trace: Trace) -> List[Span]:
    """Longest chain of child spans ending at the latest-finishing leaf.

    Walks from each root to the descendant that determines its end time.
    """
    children: Dict[int, List[Span]] = defaultdict(list)
    for s in trace.spans:
        if s.parent is not None:
            children[s.parent.span_id].append(s)

    path: List[Span] = []
    roots = trace.roots()
    if not roots:
        return path
    cur: Optional[Span] = max(roots, key=lambda s: s.end)
    seen = set()
    while cur is not None and cur.context.span_id not in seen:
        seen.add(cur.context.span_id)
        path.append(cur)
        kids = children.get(cur.context.span_id, [])
        # the child on the critical path is the one finishing last
        cur = max(kids, key=lambda s: s.end) if kids else None
    return path


# ---------------------------------------------------------------------------
# Clock analysis (Fig. 4 / Fig. 5)
# ---------------------------------------------------------------------------


def clock_offset_series(spans: Iterable[Span], host_a: str, host_b: str) -> List[Tuple[float, float]]:
    """Measured host_a - host_b system-clock difference over global time.

    clock_read events carry ``local`` (the host's system clock, ps) and are
    timestamped with the simulation's ground-truth global clock; the sim's
    global clock plays the paper's "true and precise global clock" role.
    Returns [(global_time_us, offset_us)].
    """
    reads: Dict[str, List[Tuple[int, int]]] = {host_a: [], host_b: []}
    for s in spans:
        if s.sim_type != "host" or s.component not in reads:
            continue
        for ts, name, attrs in s.events:
            if name == "clock_read" and "local" in attrs:
                reads[s.component].append((ts, int(attrs["local"])))
    for v in reads.values():
        v.sort()
    out: List[Tuple[float, float]] = []
    bi = 0
    b = reads[host_b]
    for ts, local_a in reads[host_a]:
        # nearest host_b read at (or before) the same global instant
        while bi + 1 < len(b) and b[bi + 1][0] <= ts:
            bi += 1
        if not b:
            break
        ts_b, local_b = b[bi]
        # correct for the sampling-instant difference using the global clock
        offset = (local_a - ts) - (local_b - ts_b)
        out.append((ts / PS_PER_US, offset / PS_PER_US))
    return out


def ntp_estimated_offsets(spans: Iterable[Span], host: str) -> List[Tuple[float, float]]:
    """Chrony-style estimated offsets from NtpSync spans: ((t2-t1)+(t3-t4))/2."""
    out = []
    for s in spans:
        if s.name == "NtpSync" and s.component == host:
            a = s.attrs
            if all(k in a for k in ("t1", "t2", "t3", "t4")):
                off = ((a["t2"] - a["t1"]) + (a["t3"] - a["t4"])) / 2
                out.append((s.start / PS_PER_US, off / PS_PER_US))
    out.sort()
    return out


def ntp_path_asymmetry(spans: Iterable[Span], host: str) -> List[Tuple[float, float, float]]:
    """(t_us, req_us, resp_us) one-way delays per NTP exchange — the quantity
    whose asymmetry under background traffic explains Fig. 4/6."""
    out = []
    for s in spans:
        if s.name == "NtpSync" and s.component == host:
            a = s.attrs
            if all(k in a for k in ("t1", "t2", "t3", "t4", "true_off")):
                # with ground truth offset we can compute true one-way delays
                req = (a["t2"] - a["true_off"]) - a["t1"]
                resp = a["t4"] - (a["t3"] - a["true_off"])
                out.append((s.start / PS_PER_US, req / PS_PER_US, resp / PS_PER_US))
    out.sort()
    return out


# ---------------------------------------------------------------------------
# Straggler / fault diagnostics (framework telemetry on top of Columbo)
# ---------------------------------------------------------------------------


def straggler_report(
    spans: Iterable[Span],
    span_name: str = "DeviceProgram",
    k: float = 4.0,
) -> Dict[str, Any]:
    """Flag components whose span durations are > median + k * MAD."""
    durs: Dict[str, List[int]] = defaultdict(list)
    for s in spans:
        if s.name == span_name:
            durs[s.component].append(s.duration)
    if not durs:
        return {"stragglers": [], "median_us": 0.0, "per_component_us": {}}
    per_comp = {c: statistics.median(v) / PS_PER_US for c, v in durs.items()}
    med = statistics.median(per_comp.values())
    mad = statistics.median(abs(v - med) for v in per_comp.values()) or max(med * 0.01, 1e-9)
    stragglers = sorted(
        (c for c, v in per_comp.items() if v > med + k * mad),
        key=lambda c: -per_comp[c],
    )
    return {"stragglers": stragglers, "median_us": med, "per_component_us": per_comp}


def trace_summary(spans: Sequence[Span]) -> Dict[str, Any]:
    traces = assemble_traces(spans)
    return {
        "n_spans": len(spans),
        "n_traces": len(traces),
        "span_types": sorted({s.name for s in spans}),
        "components": sorted({f"{s.sim_type}:{s.component}" for s in spans}),
        "linked_spans": sum(1 for s in spans if s.links),
        "parented_spans": sum(1 for s in spans if s.parent is not None),
    }


# ---------------------------------------------------------------------------
# diagnose(): attribute trace anomalies to fault classes
# ---------------------------------------------------------------------------
#
# The detection half of the fault-injection loop (sim/faults.py is the
# injection half).  Every rule works purely from the woven spans — no access
# to the injected ground truth — and emits findings tagged with the same
# fault-class names the faults carry, so a scenario can assert the
# round-trip: inject F, weave, diagnose, find F's class.


@dataclass
class Finding:
    """One attributed anomaly: a fault class pinned to a component."""

    fault_class: str          # one of sim.faults.FAULT_CLASSES
    component: str            # "ici.pod0.l1", "pod1.chip02", "host0", ...
    rule: str                 # which detector fired
    severity: float           # rule-specific magnitude; bigger = worse
    evidence: Dict[str, Any] = field(default_factory=dict)

    def __str__(self) -> str:
        ev = ", ".join(f"{k}={v}" for k, v in self.evidence.items())
        return (
            f"[{self.fault_class}] {self.component} (rule={self.rule}, "
            f"severity={self.severity:.2f}{'; ' + ev if ev else ''})"
        )


@dataclass
class Diagnosis:
    """diagnose() output: ranked findings + trace-level context."""

    findings: List[Finding] = field(default_factory=list)
    critical_paths: Dict[int, str] = field(default_factory=dict)  # trace -> top component

    @property
    def fault_classes(self) -> List[str]:
        out: List[str] = []
        for f in self.findings:
            if f.fault_class not in out:
                out.append(f.fault_class)
        return out

    def __contains__(self, fault_class: str) -> bool:
        return fault_class in self.fault_classes

    def summary(self) -> str:
        if not self.findings:
            return "no anomalies attributed (healthy trace)"
        return "\n".join(str(f) for f in self.findings)


def diagnose(
    spans: Sequence[Span],
    k: float = 4.0,
    clock_threshold_us: float = 1.0,
    reorder_min_samples: int = 8,
    reorder_min_fraction: float = 0.05,
) -> Diagnosis:
    """Attribute anomalies in a woven trace set back to fault classes.

    Rules (each independent, all trace-derived):

    * **device stragglers** — per-chip k-MAD outliers over ``Op`` span
      durations -> ``device_slowdown``; a pod whose chips are uniformly
      slow (pod-level k-MAD, >= 3 pods) -> ``straggler_pod``.
    * **link service time** — per-link median wire time per byte (measured
      from the ``wire_tx`` span event to span end, i.e. excluding queueing),
      k-MAD outliers within a link family (ici/dcn/pcie/eth) ->
      ``link_degradation``.
    * **drops** — ``chunk_drop`` span events on a link -> ``link_loss``.
    * **arrival inversions** — a link whose transfers complete out of
      enqueue order (impossible on a healthy FIFO link) -> ``link_reorder``.
    * **host stalls** — ``gc_stall`` span events -> ``host_pause``.
    * **clock excursions** — host clock_read offsets vs the simulation's
      ground-truth global clock exceed ``clock_threshold_us`` ->
      ``clock_fault`` (classified step vs drift).

    Critical-path context: for each step trace, the component owning the
    largest share of the critical path is recorded in
    ``Diagnosis.critical_paths``; findings on a component that also
    dominates a critical path get their evidence annotated (the
    "critical-path shift" signal).
    """
    d = Diagnosis()
    d.findings.extend(_diagnose_device(spans, k))
    d.findings.extend(_diagnose_links(spans, k, reorder_min_samples, reorder_min_fraction))
    d.findings.extend(_diagnose_host_stalls(spans))
    d.findings.extend(_diagnose_clocks(spans, clock_threshold_us))
    d.critical_paths = _critical_path_components(spans)
    cp_components = set(d.critical_paths.values())
    for f in d.findings:
        for comp in cp_components:
            if f.component in comp:
                f.evidence["on_critical_path"] = comp
    d.findings.sort(key=lambda f: -f.severity)
    return d


def _mad_outliers(
    per_key: Dict[str, float], k: float, min_keys: int = 3
) -> List[Tuple[str, float, float]]:
    """(key, value, median) for values > median + k * MAD.  MAD degenerates
    to 1% of the median when all values agree, so identical-by-construction
    healthy populations never flag."""
    if len(per_key) < min_keys:
        return []
    med = statistics.median(per_key.values())
    mad = statistics.median(abs(v - med) for v in per_key.values()) or max(med * 0.01, 1e-9)
    return sorted(
        ((c, v, med) for c, v in per_key.items() if v > med + k * mad),
        key=lambda t: -t[1],
    )


def _diagnose_device(spans: Sequence[Span], k: float) -> List[Finding]:
    durs: Dict[str, List[int]] = defaultdict(list)
    for s in spans:
        if s.name == "Op":
            durs[s.component].append(s.duration)
    if not durs:
        return []
    per_chip = {c: statistics.median(v) / PS_PER_US for c, v in durs.items()}
    findings = [
        Finding(
            "device_slowdown", chip, "op_kmad", v / med,
            {"median_op_us": round(v, 1), "fleet_median_us": round(med, 1)},
        )
        for chip, v, med in _mad_outliers(per_chip, k)
    ]
    # pod-level: median of each pod's chip medians ("pod1.chip02" -> "pod1")
    pods: Dict[str, List[float]] = defaultdict(list)
    for chip, v in per_chip.items():
        if "." in chip:
            pods[chip.split(".", 1)[0]].append(v)
    per_pod = {p: statistics.median(v) for p, v in pods.items()}
    for pod, v, med in _mad_outliers(per_pod, k):
        findings.append(
            Finding(
                "straggler_pod", pod, "pod_kmad", v / med,
                {"pod_median_op_us": round(v, 1), "fleet_median_us": round(med, 1),
                 "chips": sum(1 for c in per_chip if c.startswith(pod + "."))},
            )
        )
    return findings


def _link_family(link: str) -> str:
    return link.split(".", 1)[0]


def _diagnose_links(
    spans: Sequence[Span], k: float, reorder_min_samples: int, reorder_min_fraction: float
) -> List[Finding]:
    findings: List[Finding] = []
    per_link: Dict[str, List[Span]] = defaultdict(list)
    for s in spans:
        if s.name == "LinkTransfer":
            per_link[s.component].append(s)

    # -- service time per byte (k-MAD within a link family) -------------------
    per_byte: Dict[str, Dict[str, float]] = defaultdict(dict)   # family -> link -> med
    for link, ss in per_link.items():
        samples = []
        for s in ss:
            size = s.attrs.get("size")
            if not isinstance(size, int) or size < 4096:
                continue
            wire_start = next((ts for ts, n, _ in s.events if n == "wire_tx"), s.start)
            wire_ps = s.end - wire_start
            if wire_ps > 0:
                samples.append(wire_ps / size)
        if samples:
            per_byte[_link_family(link)][link] = statistics.median(samples)
    for family, links in per_byte.items():
        for link, v, med in _mad_outliers(links, k):
            findings.append(
                Finding(
                    "link_degradation", link, "wire_time_kmad", v / med,
                    {"ps_per_byte": round(v, 3), "family_median": round(med, 3),
                     "family": family},
                )
            )

    # -- drops -> loss ---------------------------------------------------------
    for link, ss in per_link.items():
        n_drops = sum(int(s.attrs.get("drops", 0)) for s in ss)
        if n_drops:
            findings.append(
                Finding(
                    "link_loss", link, "chunk_drops", n_drops / len(ss),
                    {"drops": n_drops, "transfers": len(ss)},
                )
            )

    # -- arrival inversions -> reordering -------------------------------------
    for link, ss in per_link.items():
        ordered = sorted(ss, key=lambda s: (s.start, s.context.span_id))
        if len(ordered) < reorder_min_samples:
            continue
        inversions = sum(
            1
            for a, b in zip(ordered, ordered[1:])
            if a.start < b.start and b.end < a.end
        )
        frac = inversions / (len(ordered) - 1)
        if frac >= reorder_min_fraction:
            findings.append(
                Finding(
                    "link_reorder", link, "arrival_inversions", frac,
                    {"inversions": inversions, "transfers": len(ordered)},
                )
            )
    return findings


def _diagnose_host_stalls(spans: Sequence[Span]) -> List[Finding]:
    stalls: Dict[str, List[Tuple[int, Dict[str, Any]]]] = defaultdict(list)
    for s in spans:
        if s.sim_type != "host":
            continue
        for ts, name, attrs in s.events:
            if name == "gc_stall":
                stalls[s.component].append((ts, attrs))
    return [
        Finding(
            "host_pause", host, "gc_stall_events",
            sum(int(a.get("dur", 0)) for _, a in evs) / PS_PER_US,
            {"stalls": len(evs),
             "total_stall_us": round(sum(int(a.get("dur", 0)) for _, a in evs) / PS_PER_US, 1),
             "causes": sorted({str(a.get("cause", "?")) for _, a in evs})},
        )
        for host, evs in stalls.items()
    ]


def _diagnose_clocks(spans: Sequence[Span], threshold_us: float) -> List[Finding]:
    reads: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
    for s in spans:
        if s.sim_type != "host":
            continue
        for ts, name, attrs in s.events:
            if name == "clock_read" and "local" in attrs:
                reads[s.component].append((ts, int(attrs["local"])))
    findings = []
    for host, rr in sorted(reads.items()):
        rr.sort()
        offsets = [(ts, (local - ts) / PS_PER_US) for ts, local in rr]
        max_abs = max((abs(o) for _, o in offsets), default=0.0)
        if max_abs < threshold_us or len(offsets) < 2:
            continue
        jumps = [abs(b[1] - a[1]) for a, b in zip(offsets, offsets[1:])]
        span_ps = offsets[-1][0] - offsets[0][0]
        # ppm = (delta offset ps) / (elapsed ps) * 1e6
        slope_ppm = (
            (offsets[-1][1] - offsets[0][1]) * PS_PER_US / span_ps * 1e6 if span_ps else 0.0
        )
        kind = "step" if max(jumps) > 0.5 * max_abs else "drift"
        findings.append(
            Finding(
                "clock_fault", host, f"clock_{kind}", max_abs,
                {"max_offset_us": round(max_abs, 2), "slope_ppm": round(slope_ppm, 1),
                 "kind": kind},
            )
        )
    return findings


def _critical_path_components(spans: Sequence[Span]) -> Dict[int, str]:
    """trace_id -> 'sim_type:component' owning the largest critical-path
    share, for step traces (the paper's critical-path-shift signal)."""
    out: Dict[int, str] = {}
    for tid, trace in assemble_traces(spans).items():
        if not any(s.name == "HostStep" for s in trace.spans):
            continue
        share: Dict[str, int] = defaultdict(int)
        for s in critical_path(trace):
            share[f"{s.sim_type}:{s.component}"] += s.duration
        if share:
            out[tid] = max(share, key=share.get)
    return out
