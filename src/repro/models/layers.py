"""Core layers: norms, RoPE, attention (global/local, GQA, qk-norm, KV cache,
int8 cache), MLP (SwiGLU/GeLU).  Pure functions over param pytrees.

Attention uses a *block-causal* reference implementation: a python loop over
query blocks, each attending only the statically-known prefix (or local
window) of KV blocks.  This keeps compiled HLO FLOPs equal to the true
causal FLOPs (no masked-half waste) — which matters because the roofline
analysis reads FLOPs from the compiled artifact — and bounds the live score
tensor to (block × block) instead of (seq × seq).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import PSpec

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if scale is not None:
        x = x * (1.0 + scale.astype(jnp.float32))
    return x.astype(dt)


def layernorm_nonparametric(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo: LayerNorm without scale/bias."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm_pspec(cfg: ModelConfig, width: Optional[int] = None) -> Optional[PSpec]:
    if cfg.nonparametric_ln:
        return None
    return PSpec((width or cfg.d_model,), ("embed_nr",), init="zeros")


def apply_norm(cfg: ModelConfig, x: jax.Array, scale: Optional[jax.Array]) -> jax.Array:
    if cfg.nonparametric_ln:
        return layernorm_nonparametric(x)
    return rmsnorm(x, scale)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, head_dim); positions: (S,)"""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[:, None].astype(jnp.float32) * freqs   # (S, hd/2)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_pspecs(cfg: ModelConfig) -> Params:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    p: Params = {
        "wq": PSpec((d, H, hd), ("embed", "heads", None), init="lecun"),
        "wk": PSpec((d, K, hd), ("embed", "kv_heads", None), init="lecun"),
        "wv": PSpec((d, K, hd), ("embed", "kv_heads", None), init="lecun"),
        "wo": PSpec((H, hd, d), ("heads", None, "embed"), init="lecun"),
    }
    if cfg.qk_norm:
        p["q_norm"] = PSpec((hd,), (None,), init="zeros")
        p["k_norm"] = PSpec((hd,), (None,), init="zeros")
    return p


def _online_block_attn(
    q: jax.Array,              # (B, K, g, Bq, hd) f32-scaled queries
    kv_blocks_k: jax.Array,    # (nb, B, K, Bk, hd)
    kv_blocks_v: jax.Array,
    mask_fn,
    unroll: bool = False,
) -> jax.Array:
    """Online-softmax over a static stack of KV blocks via lax.scan
    (or a python loop when ``unroll`` — cost-measurement mode)."""
    B, K, g, Bq, hd = q.shape

    def step(carry, kv):
        m, l, acc, idx = carry
        kb, vb = kv
        s = jnp.einsum("bkgqh,bkth->bkgqt", q, kb.astype(q.dtype))
        s = mask_fn(s, idx)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqt,bkth->bkgqh", p, vb.astype(q.dtype)
        )
        return (m_new, l, acc, idx + 1), None

    m0 = jnp.full((B, K, g, Bq), -jnp.inf, dtype=q.dtype)
    l0 = jnp.zeros((B, K, g, Bq), dtype=q.dtype)
    a0 = jnp.zeros((B, K, g, Bq, hd), dtype=q.dtype)
    carry = (m0, l0, a0, 0)
    if unroll:
        for j in range(kv_blocks_k.shape[0]):
            carry, _ = step(carry, (kv_blocks_k[j], kv_blocks_v[j]))
    else:
        carry, _ = jax.lax.scan(step, carry, (kv_blocks_k, kv_blocks_v))
    m, l, acc, _ = carry
    return acc / jnp.maximum(l, 1e-30)[..., None]


def prefill_kv_cache(
    cfg: ModelConfig,
    k: jax.Array,                 # (B, K, S, hd) rope'd keys
    v: jax.Array,
    local: bool,
    max_seq: int,
) -> Dict[str, jax.Array]:
    """Build a decode cache from prefill K/V (ring-buffer layout for local)."""
    B, K, S, hd = k.shape
    entry = init_kv_cache(cfg, B, local, max_seq)
    W = entry["k"].shape[2]
    if local:
        n = min(W, S)
        kw, vw = k[:, :, S - n :], v[:, :, S - n :]
        slots = (S - n + jnp.arange(n)) % W
        write = lambda c, x: c.at[:, :, slots].set(x)
    else:
        kw, vw = k, v
        write = lambda c, x: jax.lax.dynamic_update_slice(c, x, (0, 0, 0, 0))
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(kw)
        vq, vs = _quantize_kv(vw)
        entry["k"] = write(entry["k"], kq)
        entry["v"] = write(entry["v"], vq)
        entry["k_scale"] = write(entry["k_scale"], ks)
        entry["v_scale"] = write(entry["v_scale"], vs)
    else:
        entry["k"] = write(entry["k"], kw.astype(entry["k"].dtype))
        entry["v"] = write(entry["v"], vw.astype(entry["v"].dtype))
    return entry


def multihead_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                       # (B, S, d)
    positions: jax.Array,               # (S,)
    local: bool,
    block_q: Optional[int] = None,
    cache_max_seq: Optional[int] = None,  # build a decode cache when set
) -> Any:
    """Training/prefill attention (block-causal, exact-FLOPs)."""
    if block_q is None:
        block_q = cfg.attn_block_q
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = H // K
    dt = x.dtype

    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cfg.attention_impl == "pallas":
        from ..kernels import ops as kops

        qh = q.transpose(0, 2, 1, 3)                         # (B, H, S, hd)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        o = kops.flash_attention(
            qh, kh, vh, causal=True, window=cfg.window if local else None,
            block_q=min(512, S), block_k=min(512, S),
        )
        o = o.transpose(0, 2, 1, 3).astype(dt)               # (B, S, H, hd)
        y = jnp.einsum("bsnh,nhd->bsd", o, p["wo"].astype(dt))
        if cache_max_seq is not None:
            return y, prefill_kv_cache(
                cfg, kh, vh, local, cache_max_seq
            )
        return y

    q = q * (hd ** -0.5)

    # (B, K, g, S, hd) / (B, K, S, hd)
    q = q.reshape(B, S, K, g, hd).transpose(0, 2, 3, 1, 4)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    Bq = min(block_q, S)
    n_q = max(S // Bq, 1)
    window_blocks = max(1, -(-cfg.window // Bq)) if local else None

    outs = []
    for i in range(n_q):                     # python loop: static trip counts
        qi = q[:, :, :, i * Bq : (i + 1) * Bq, :].astype(jnp.float32)
        lo = 0 if not local else max(0, i - window_blocks)
        hi = i + 1
        kb = k[:, :, lo * Bq : hi * Bq, :]
        vb = v[:, :, lo * Bq : hi * Bq, :]
        nb = hi - lo
        kb = kb.reshape(B, K, nb, Bq, hd).transpose(2, 0, 1, 3, 4)
        vb = vb.reshape(B, K, nb, Bq, hd).transpose(2, 0, 1, 3, 4)

        q_pos = i * Bq + jnp.arange(Bq)

        def mask_fn(s, idx, lo=lo, q_pos=q_pos):
            k_pos = (lo + idx) * Bq + jnp.arange(Bq)
            m = q_pos[:, None] >= k_pos[None, :]
            if local:
                m &= q_pos[:, None] - k_pos[None, :] < cfg.window
            return jnp.where(m[None, None, None], s, -jnp.inf)

        o = _online_block_attn(qi, kb, vb, mask_fn, unroll=cfg.unroll_inner)
        outs.append(o)                                       # (B,K,g,Bq,hd)

    out = jnp.concatenate(outs, axis=3)                      # (B,K,g,S,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd).astype(dt)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(dt))
    if cache_max_seq is not None:
        return y, prefill_kv_cache(cfg, k, v, local, cache_max_seq)
    return y


# -- decode (KV cache) --------------------------------------------------------


def kv_cache_pspec(cfg: ModelConfig, batch: int, local: bool, max_seq: int) -> Dict[str, Any]:
    """Abstract cache entry for one attention layer."""
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    S = min(cfg.window, max_seq) if local else max_seq
    cdt = {"int8": jnp.int8, "bfloat16": jnp.bfloat16, "float32": jnp.float32}[
        cfg.kv_cache_dtype
    ]
    entry = {
        "k": jax.ShapeDtypeStruct((batch, K, S, hd), cdt),
        "v": jax.ShapeDtypeStruct((batch, K, S, hd), cdt),
    }
    if cfg.kv_cache_dtype == "int8":
        entry["k_scale"] = jax.ShapeDtypeStruct((batch, K, S, 1), jnp.float32)
        entry["v_scale"] = jax.ShapeDtypeStruct((batch, K, S, 1), jnp.float32)
    return entry


def init_kv_cache(cfg: ModelConfig, batch: int, local: bool, max_seq: int) -> Dict[str, jax.Array]:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), kv_cache_pspec(cfg, batch, local, max_seq)
    )


def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                  # (B, 1, d)
    cache: Dict[str, jax.Array],
    pos: jax.Array,                # scalar int32: number of tokens already cached
    local: bool,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, _, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = H // K
    dt = x.dtype
    S = cache["k"].shape[2]

    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    pos_arr = pos[None] if pos.ndim == 0 else pos
    q = apply_rope(q, pos_arr, cfg.rope_theta) * (hd ** -0.5)
    k = apply_rope(k, pos_arr, cfg.rope_theta)

    # write position: ring-buffer for local windows, linear otherwise
    slot = pos % S if local else pos
    k_new = k.transpose(0, 2, 1, 3)        # (B, K, 1, hd)
    v_new = v.transpose(0, 2, 1, 3)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, slot, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, slot, 0))
        cache["k_scale"] = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, 0, slot, 0))
        cache["v_scale"] = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, 0, slot, 0))
        # dequantize to bf16 (int8 values are exact in bf16) and keep the
        # attention dots in bf16 with f32 accumulation — avoids an f32
        # materialization of the whole cache.  The Pallas decode kernel
        # (kernels/decode_attention.py) streams int8 directly on TPU.
        keys = cache["k"].astype(jnp.bfloat16) * cache["k_scale"].astype(jnp.bfloat16)
        vals = cache["v"].astype(jnp.bfloat16) * cache["v_scale"].astype(jnp.bfloat16)
    else:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, 0, slot, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, 0, slot, 0))
        keys = cache["k"]
        vals = cache["v"]

    qh = q.reshape(B, 1, K, g, hd).transpose(0, 2, 3, 1, 4).astype(keys.dtype)  # (B,K,g,1,hd)
    s = jnp.einsum(
        "bkgqh,bkth->bkgqt", qh, keys, preferred_element_type=jnp.float32
    )

    t_idx = jnp.arange(S)
    if local:
        # valid ring-buffer entries: the last min(pos+1, S) written slots
        valid = t_idx[None, :] < jnp.minimum(pos + 1, S)
    else:
        valid = t_idx[None, :] <= pos
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgqt,bkth->bkgqh", w.astype(vals.dtype), vals,
        preferred_element_type=jnp.float32,
    )
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, hd).astype(dt)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"].astype(dt)), cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_pspecs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "w_gate": PSpec((d, f), ("embed", "mlp"), init="lecun"),
            "w_up": PSpec((d, f), ("embed", "mlp"), init="lecun"),
            "w_down": PSpec((f, d), ("mlp", "embed"), init="lecun"),
        }
    return {
        "w_up": PSpec((d, f), ("embed", "mlp"), init="lecun"),
        "w_down": PSpec((f, d), ("mlp", "embed"), init="lecun"),
    }


def mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    elif cfg.mlp_act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)
