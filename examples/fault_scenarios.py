"""Fault-injection scenario sweep — inject, trace, diagnose, verify.

Runs every named scenario in the curated library (sim/scenarios.py), or a
chosen subset, through the full Columbo loop: the fault plan schedules
itself onto the simulated cluster, the component simulators write their
ad-hoc logs, a declarative TraceSpec weaves them into end-to-end traces,
and ``diagnose()`` attributes the trace anomalies back to fault classes —
which are then checked against what the scenario actually injected.

    PYTHONPATH=src python examples/fault_scenarios.py
    PYTHONPATH=src python examples/fault_scenarios.py throttled_chip lossy_dcn
    FAULT_SCENARIOS_OUT=results/scenarios PYTHONPATH=src \\
        python examples/fault_scenarios.py     # keep logs + Chrome traces
"""
import os
import sys

from repro.core import ChromeTraceExporter
from repro.sim.scenarios import SCENARIOS, get_scenario


def main() -> int:
    names = sys.argv[1:] or list(SCENARIOS)
    outdir = os.environ.get("FAULT_SCENARIOS_OUT", "")
    failures = 0
    for name in names:
        spec = get_scenario(name)
        if outdir:
            os.makedirs(outdir, exist_ok=True)
            base = os.path.join(outdir, name)
            run = spec.run(
                outdir=base + ".logs",
                exporters=(ChromeTraceExporter(base + ".chrome.json"),),
            )
        else:
            run = spec.run()
        print(run.report())
        print()
        if not run.ok:
            failures += 1
    print(f"{len(names) - failures}/{len(names)} scenarios round-tripped "
          f"(injected fault class named by diagnose())")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
