"""Model assembly: pattern-cyclic blocks, scan-over-layer-groups, embeddings,
LM head; forward (train/prefill) and decode paths with caches.

Layers are grouped by the config's block pattern period and stacked so a
single ``lax.scan`` executes all full groups (HLO size O(pattern period),
not O(depth)); remainder layers run unscanned.  Caches mirror the same
grouping so decode scans carry them as scan xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    apply_norm,
    attention_pspecs,
    decode_attention,
    init_kv_cache,
    kv_cache_pspec,
    mlp,
    mlp_pspecs,
    multihead_attention,
    norm_pspec,
)
from .moe import moe_block, moe_pspecs
from .params import PSpec, is_pspec
from .rglru import rglru_block, rglru_decode, rglru_pspecs, rglru_state_specs
from .sharding import constrain
from .ssm import mamba_block, mamba_decode, mamba_pspecs, mamba_state_specs

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


def block_pspecs(cfg: ModelConfig, kind: str) -> Params:
    p: Params = {}
    n1 = norm_pspec(cfg)
    if n1 is not None:
        p["norm1"] = n1
    if kind in ("attn", "attn_local"):
        p["attn"] = attention_pspecs(cfg)
    elif kind == "rglru":
        p["rglru"] = rglru_pspecs(cfg)
    elif kind == "mamba":
        p["mamba"] = mamba_pspecs(cfg)
        return p  # mamba blocks have no separate MLP
    n2 = norm_pspec(cfg)
    if n2 is not None:
        p["norm2"] = n2
    if cfg.is_moe:
        p["moe"] = moe_pspecs(cfg)
    else:
        p["mlp"] = mlp_pspecs(cfg)
    return p


def _stack(tree: Any, n: int) -> Any:
    def f(p: PSpec) -> PSpec:
        return PSpec((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale, p.dtype)

    return jax.tree_util.tree_map(f, tree, is_leaf=is_pspec)


def model_pspecs(cfg: ModelConfig) -> Params:
    p: Params = {
        "embed": {"tok": PSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="lecun")}
    }
    return _with_param_dtype(_model_pspecs_body(cfg, p), cfg)


def _with_param_dtype(tree: Params, cfg: ModelConfig) -> Params:
    """Store parameters in cfg.param_dtype (bf16 halves FSDP gathers and
    gradient buffers; the optimizer then keeps an f32 master copy)."""
    if cfg.param_dtype == "float32":
        return tree
    dt = jnp.dtype(cfg.param_dtype)

    def f(p: PSpec) -> PSpec:
        return PSpec(p.shape, p.axes, p.init, p.scale, dt)

    return jax.tree_util.tree_map(f, tree, is_leaf=is_pspec)


def _model_pspecs_body(cfg: ModelConfig, p: Params) -> Params:
    G, P_ = cfg.n_groups, cfg.pattern_period
    if cfg.scan_layers and G > 0:
        p["groups"] = {
            f"b{i}": _stack(block_pspecs(cfg, kind), G)
            for i, kind in enumerate(cfg.block_pattern)
        }
        rest_kinds = [cfg.block_kind(G * P_ + j) for j in range(cfg.n_rest_layers)]
    else:
        rest_kinds = [cfg.block_kind(i) for i in range(cfg.n_layers)]
    p["rest"] = [block_pspecs(cfg, k) for k in rest_kinds]
    fn = norm_pspec(cfg)
    if fn is not None:
        p["final_norm"] = fn
    if not cfg.tie_embeddings:
        p["lm_head"] = PSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), init="lecun")
    return p


# ---------------------------------------------------------------------------
# Blocks (train / prefill)
# ---------------------------------------------------------------------------


def apply_block(
    cfg: ModelConfig, kind: str, p: Params, x: jax.Array, positions: jax.Array,
    train: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, x, p.get("norm1"))
    if kind in ("attn", "attn_local"):
        x = x + multihead_attention(cfg, p["attn"], h, positions, local=(kind == "attn_local"))
    elif kind == "rglru":
        x = x + rglru_block(cfg, p["rglru"], h)
    elif kind == "mamba":
        return x + mamba_block(cfg, p["mamba"], h), aux
    x = constrain(x, "batch", None, None)
    h2 = apply_norm(cfg, x, p.get("norm2"))
    if "moe" in p:
        y, aux = moe_block(cfg, p["moe"], h2, train=train)
    else:
        y = mlp(cfg, p["mlp"], h2)
    x = x + y
    return constrain(x, "batch", None, None), aux


def _group_body(cfg: ModelConfig, carry, group_params, positions, train=False):
    x, aux = carry
    for i, kind in enumerate(cfg.block_pattern):
        x, a = apply_block(cfg, kind, group_params[f"b{i}"], x, positions, train=train)
        aux = aux + a
    return (x, aux)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: Optional[jax.Array] = None,       # (B, S) int32
    embeds: Optional[jax.Array] = None,       # (B, S, d) modality-frontend stub
    positions: Optional[jax.Array] = None,    # (S,)
    train: bool = False,                      # capacity-drop MoE tokens (train only)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V), moe_aux)."""
    if embeds is not None:
        x = embeds.astype(cfg.dtype)
        S = x.shape[1]
    else:
        x = params["embed"]["tok"].astype(cfg.dtype)[tokens]
        S = tokens.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    x = constrain(x, "batch", None, None)

    aux = jnp.zeros((), jnp.float32)
    if cfg.scan_layers and cfg.n_groups > 0 and "groups" in params:
        body = _remat(cfg, functools.partial(_group_body, cfg, positions=positions, train=train))

        def scan_fn(carry, gp):
            return body(carry, gp), None

        (x, aux), _ = jax.lax.scan(scan_fn, (x, aux), params["groups"])
    # remainder layers (and the no-scan path) run unrolled:
    rest_start = cfg.n_groups * cfg.pattern_period if (cfg.scan_layers and "groups" in params) else 0
    for j, p_rest in enumerate(params["rest"]):
        kind = cfg.block_kind(rest_start + j)
        x, a = apply_block(cfg, kind, p_rest, x, positions, train=train)
        aux = aux + a

    x = apply_norm(cfg, x, params.get("final_norm"))
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    if cfg.logits_f32:
        logits = logits.astype(jnp.float32)
    return constrain(logits, "batch", None, "act_vocab"), aux


# ---------------------------------------------------------------------------
# Prefill: forward + populated decode caches
# ---------------------------------------------------------------------------


def apply_block_prefill(
    cfg: ModelConfig,
    kind: str,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    max_seq: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h = apply_norm(cfg, x, p.get("norm1"))
    if kind in ("attn", "attn_local"):
        y, cache = multihead_attention(
            cfg, p["attn"], h, positions, local=(kind == "attn_local"),
            cache_max_seq=max_seq,
        )
        x = x + y
    elif kind == "rglru":
        from .rglru import rglru_block as _rg

        y, cache = _rg(cfg, p["rglru"], h, return_state=True)
        x = x + y
    elif kind == "mamba":
        from .ssm import mamba_block as _mb

        y, cache = _mb(cfg, p["mamba"], h, return_state=True)
        return x + y, cache
    h2 = apply_norm(cfg, x, p.get("norm2"))
    if "moe" in p:
        y, _ = moe_block(cfg, p["moe"], h2)
    else:
        y = mlp(cfg, p["mlp"], h2)
    return x + y, cache


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    max_seq: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Full-sequence forward that also builds the decode cache.
    Returns (logits (B, S, V), cache sized for ``max_seq`` (default S))."""
    if embeds is not None:
        x = embeds.astype(cfg.dtype)
        S = x.shape[1]
    else:
        x = params["embed"]["tok"].astype(cfg.dtype)[tokens]
        S = tokens.shape[1]
    max_seq = max_seq or S
    positions = jnp.arange(S)
    x = constrain(x, "batch", None, None)

    cache: Dict[str, Any] = {"rest": []}
    if cfg.scan_layers and cfg.n_groups > 0 and "groups" in params:

        def scan_fn(carry, gp):
            xc = carry
            entries = {}
            for i, kind in enumerate(cfg.block_pattern):
                xc, c = apply_block_prefill(cfg, kind, gp[f"b{i}"], xc, positions, max_seq)
                entries[f"b{i}"] = c
            return xc, entries

        x, groups_cache = jax.lax.scan(scan_fn, x, params["groups"])
        cache["groups"] = groups_cache
        rest_start = cfg.n_groups * cfg.pattern_period
    else:
        rest_start = 0
    for j, p_rest in enumerate(params["rest"]):
        kind = cfg.block_kind(rest_start + j)
        x, c = apply_block_prefill(cfg, kind, p_rest, x, positions, max_seq)
        cache["rest"].append(c)

    x = apply_norm(cfg, x, params.get("final_norm"))
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    if cfg.logits_f32:
        logits = logits.astype(jnp.float32)
    return constrain(logits, "batch", None, "act_vocab"), cache


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def _cache_entry_spec(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    if kind == "attn":
        return kv_cache_pspec(cfg, batch, local=False, max_seq=max_seq)
    if kind == "attn_local":
        return kv_cache_pspec(cfg, batch, local=True, max_seq=max_seq)
    if kind == "rglru":
        return rglru_state_specs(cfg, batch)
    if kind == "mamba":
        return mamba_state_specs(cfg, batch)
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    """Abstract cache pytree mirroring the grouped layer structure."""
    out: Dict[str, Any] = {}
    G = cfg.n_groups

    def stack_specs(tree):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((G,) + s.shape, s.dtype), tree
        )

    if cfg.scan_layers and G > 0:
        out["groups"] = {
            f"b{i}": stack_specs(_cache_entry_spec(cfg, kind, batch, max_seq))
            for i, kind in enumerate(cfg.block_pattern)
        }
        rest_kinds = [cfg.block_kind(G * cfg.pattern_period + j) for j in range(cfg.n_rest_layers)]
    else:
        rest_kinds = [cfg.block_kind(i) for i in range(cfg.n_layers)]
    out["rest"] = [_cache_entry_spec(cfg, k, batch, max_seq) for k in rest_kinds]
    return out


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs(cfg, batch, max_seq)
    )


def apply_block_decode(
    cfg: ModelConfig,
    kind: str,
    p: Params,
    x: jax.Array,              # (B, 1, d)
    cache: Dict[str, jax.Array],
    pos: jax.Array,            # scalar
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h = apply_norm(cfg, x, p.get("norm1"))
    if kind in ("attn", "attn_local"):
        y, cache = decode_attention(cfg, p["attn"], h, cache, pos, local=(kind == "attn_local"))
        x = x + y
    elif kind == "rglru":
        y, cache = rglru_decode(cfg, p["rglru"], h, cache)
        x = x + y
    elif kind == "mamba":
        y, cache = mamba_decode(cfg, p["mamba"], h, cache)
        return x + y, cache
    h2 = apply_norm(cfg, x, p.get("norm2"))
    if "moe" in p:
        y, _ = moe_block(cfg, p["moe"], h2)
    else:
        y = mlp(cfg, p["mlp"], h2)
    return x + y, cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,                 # (B, 1) int32
    cache: Dict[str, Any],
    pos: jax.Array,                    # scalar int32
    embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence in the batch.  Returns (logits, cache)."""
    if embeds is not None:
        x = embeds.astype(cfg.dtype)
    else:
        x = params["embed"]["tok"].astype(cfg.dtype)[tokens]
    x = constrain(x, "batch", None, None)

    new_cache: Dict[str, Any] = {"rest": []}
    if cfg.scan_layers and cfg.n_groups > 0 and "groups" in params:

        def scan_fn(carry, xs):
            xc = carry
            gp, gc = xs
            gc_new = {}
            for i, kind in enumerate(cfg.block_pattern):
                xc, c = apply_block_decode(cfg, kind, gp[f"b{i}"], xc, gc[f"b{i}"], pos)
                gc_new[f"b{i}"] = c
            return xc, gc_new

        x, groups_cache = jax.lax.scan(scan_fn, x, (params["groups"], cache["groups"]))
        new_cache["groups"] = groups_cache
        rest_start = cfg.n_groups * cfg.pattern_period
    else:
        rest_start = 0
    for j, p_rest in enumerate(params["rest"]):
        kind = cfg.block_kind(rest_start + j)
        x, c = apply_block_decode(cfg, kind, p_rest, x, cache["rest"][j], pos)
        new_cache["rest"].append(c)

    x = apply_norm(cfg, x, params.get("final_norm"))
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    if cfg.logits_f32:
        logits = logits.astype(jnp.float32)
    return constrain(logits, "batch", None, "act_vocab"), new_cache
