"""Simulated testbed topologies (nodes + links + routes).

Three families:
* ``ntp_testbed()``       — the paper's §5 topology: client/server hosts
                            behind two switches, background traffic on the
                            inter-switch link.
* ``tpu_cluster()``       — a multi-pod TPU testbed: per-pod ICI ring of
                            chips, one host per pod (PCIe to each chip),
                            full DCN mesh between hosts (O(pods²) links —
                            fine at 2–8 pods, prohibitive at fleet scale).
* ``fat_tree_cluster()``  — the scale-out variant: hosts grouped into
                            racks behind ToR switches, ToRs uplinked to a
                            spine layer (O(pods) links), so 64–512-pod
                            testbeds stay cheap to build and route.

``scale(pods=N)`` is the one-call entry point sweeps and benchmarks use.
Routing is static shortest-path (BFS), cached per (src, dst); fat-tree ToR
uplinks are added in rack-rotated order so different racks deterministically
prefer different spines (poor-man's ECMP without random route state).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..hw import V5E, ChipSpec, PS_PER_S


@dataclass(slots=True)
class Link:
    """One bidirectional link: bandwidth, propagation latency, and the
    runtime FIFO state (``busy_until``) netsim serializes transfers on."""

    name: str                    # e.g. "ici.pod0.l3", "dcn.h0h1", "pcie.pod0.c2"
    a: str
    b: str
    bw: float                    # bytes/s
    latency_ps: int = 500_000    # 0.5us default
    # runtime state (owned by netsim)
    busy_until: int = 0
    bytes_tx: int = 0
    queue_len: int = 0

    @property
    def bytes_per_ps(self) -> float:
        return self.bw / PS_PER_S


@dataclass
class Topology:
    """Nodes + links + BFS-routed adjacency of one simulated testbed."""

    name: str
    chip: ChipSpec = field(default_factory=lambda: V5E)
    nodes: List[str] = field(default_factory=list)
    links: Dict[str, Link] = field(default_factory=dict)
    adj: Dict[str, List[Tuple[str, str]]] = field(default_factory=dict)  # node -> [(peer, link)]
    pods: Dict[int, List[str]] = field(default_factory=dict)             # pod -> chip node names
    hosts: List[str] = field(default_factory=list)
    _routes: Dict[Tuple[str, str], List[str]] = field(default_factory=dict)
    _route_links: Dict[Tuple[str, str], List[Link]] = field(default_factory=dict)

    def add_node(self, n: str) -> None:
        if n not in self.adj:
            self.nodes.append(n)
            self.adj[n] = []

    def add_link(self, name: str, a: str, b: str, bw: float, latency_ps: int = 500_000) -> Link:
        self.add_node(a)
        self.add_node(b)
        l = Link(name, a, b, bw, latency_ps)
        self.links[name] = l
        self.adj[a].append((b, name))
        self.adj[b].append((a, name))
        return l

    def route(self, src: str, dst: str) -> List[str]:
        """BFS shortest path, returned as list of link names."""
        key = (src, dst)
        r = self._routes.get(key)
        if r is not None:
            return r
        prev: Dict[str, Tuple[str, str]] = {}
        frontier = [src]
        seen = {src}
        while frontier and dst not in prev and dst != src:
            nxt = []
            for u in frontier:
                for v, ln in self.adj[u]:
                    if v not in seen:
                        seen.add(v)
                        prev[v] = (u, ln)
                        nxt.append(v)
            frontier = nxt
        path: List[str] = []
        cur = dst
        while cur != src:
            if cur not in prev:
                raise ValueError(f"no route {src} -> {dst}")
            u, ln = prev[cur]
            path.append(ln)
            cur = u
        path.reverse()
        self._routes[key] = path
        return path

    def route_links(self, src: str, dst: str) -> List[Link]:
        """:meth:`route`, pre-resolved to :class:`Link` objects (cached).

        The interconnect hot path walks a chunk's route once per hop;
        resolving names to links here removes a dict lookup per hop."""
        key = (src, dst)
        r = self._route_links.get(key)
        if r is None:
            r = self._route_links[key] = [self.links[n] for n in self.route(src, dst)]
        return r

    # -- mitigation hooks (driven by sim/mitigation.py) ---------------------------

    def disable_link(self, name: str) -> None:
        """Take one link out of the route tables (``disable_and_reroute``
        mitigation hook): its adjacency entries are removed and the BFS
        route caches cleared, so *future* routes detour around it.
        In-flight transfers keep their pre-resolved routes (packets already
        on the wire are not rerouted).  The link stays in :attr:`links`, so
        byte counters and installed faults remain inspectable."""
        l = self.links[name]
        self.adj[l.a] = [(v, ln) for (v, ln) in self.adj[l.a] if ln != name]
        self.adj[l.b] = [(v, ln) for (v, ln) in self.adj[l.b] if ln != name]
        self._routes.clear()
        self._route_links.clear()

    def restore_link(self, name: str) -> None:
        """Undo :meth:`disable_link`: re-add the link's adjacency entries
        (idempotent) and clear the route caches."""
        l = self.links[name]
        if not any(ln == name for _, ln in self.adj[l.a]):
            self.adj[l.a].append((l.b, name))
        if not any(ln == name for _, ln in self.adj[l.b]):
            self.adj[l.b].append((l.a, name))
        self._routes.clear()
        self._route_links.clear()

    # -- id helpers ---------------------------------------------------------------

    @staticmethod
    def chip_name(pod: int, idx: int) -> str:
        return f"pod{pod}.chip{idx:02d}"

    @staticmethod
    def host_name(pod: int) -> str:
        return f"host{pod}"


def ntp_testbed(
    link_bw: float = 1.25e9,          # 10 Gbps, ns3-ish
    latency_ps: int = 5_000_000,      # 5 us per hop
) -> Topology:
    """Paper §5: client - sw1 - sw2 - server (+ bg src/sink on sw1/sw2)."""
    t = Topology(name="ntp_testbed")
    t.add_link("eth.client_sw1", "client", "sw1", link_bw, latency_ps)
    t.add_link("eth.sw1_sw2", "sw1", "sw2", link_bw, latency_ps)
    t.add_link("eth.sw2_server", "sw2", "server", link_bw, latency_ps)
    t.add_link("eth.bgsrc_sw1", "bgsrc", "sw1", link_bw, latency_ps)
    t.add_link("eth.bgsink_sw2", "bgsink", "sw2", link_bw, latency_ps)
    t.hosts = ["client", "server", "bgsrc", "bgsink"]
    return t


def tpu_cluster(
    n_pods: int = 2,
    chips_per_pod: int = 8,
    chip: ChipSpec = V5E,
    ici_latency_ps: int = 1_000_000,    # 1 us hop
    dcn_latency_ps: int = 10_000_000,   # 10 us hop
) -> Topology:
    """Multi-pod testbed: ICI ring per pod, PCIe host links, DCN host mesh.

    (The production 16x16 pod is a 2D torus; the simulated testbed uses a
    ring per pod — collective *schedules* are modeled per ring group, which
    matches how multi-axis collectives decompose into per-axis rings.)
    """
    t = Topology(name=f"tpu_{n_pods}x{chips_per_pod}", chip=chip)
    for p in range(n_pods):
        _add_pod(t, p, chips_per_pod, chip, ici_latency_ps)
    for p in range(n_pods):
        for q in range(p + 1, n_pods):
            t.add_link(
                f"dcn.h{p}h{q}",
                t.host_name(p),
                t.host_name(q),
                chip.dcn_bw_per_host,
                dcn_latency_ps,
            )
    return t


def _add_pod(
    t: Topology, p: int, chips_per_pod: int, chip: ChipSpec, ici_latency_ps: int
) -> str:
    """One pod: ICI ring over its chips + PCIe host links; returns the host."""
    host = t.host_name(p)
    chips = [t.chip_name(p, i) for i in range(chips_per_pod)]
    t.pods[p] = chips
    t.hosts.append(host)
    for i, c in enumerate(chips):
        nxt = chips[(i + 1) % chips_per_pod]
        t.add_link(f"ici.pod{p}.l{i}", c, nxt, chip.ici_link_bw, ici_latency_ps)
        t.add_link(f"pcie.pod{p}.c{i}", host, c, chip.pcie_bw, 2_000_000)
    return host


def fat_tree_cluster(
    n_pods: int,
    chips_per_pod: int = 4,
    pods_per_rack: int = 8,
    n_spines: Optional[int] = None,
    chip: ChipSpec = V5E,
    ici_latency_ps: int = 1_000_000,     # 1 us hop
    dcn_latency_ps: int = 10_000_000,    # 10 us hop
    oversubscription: float = 2.0,
) -> Topology:
    """Multi-rack fat-tree testbed: the O(pods)-link scale-out fabric.

    Per pod: the same ICI ring + PCIe host links as :func:`tpu_cluster`.
    Across pods: each rack's hosts connect to a ToR switch
    (``dcn.h<p>tor<r>``), and every ToR uplinks to every spine switch
    (``dcn.tor<r>spine<s>``) with aggregate uplink bandwidth
    ``pods_per_rack * dcn_bw_per_host / oversubscription`` split across the
    spines.  Cross-rack DCN traffic routes host → ToR → spine → ToR → host;
    ToR uplinks are added in rack-rotated spine order, so BFS (first-found
    shortest path) deterministically spreads racks across spines.

    Link count grows linearly in ``n_pods`` (vs the mesh's quadratic
    growth), which is what keeps 64–512-pod sweeps affordable — see
    ``docs/performance.md`` for the measured scaling table.
    """
    n_racks = max(1, math.ceil(n_pods / pods_per_rack))
    if n_spines is None:
        n_spines = max(2, min(n_racks, 8))
    t = Topology(name=f"fattree_{n_pods}x{chips_per_pod}", chip=chip)
    for p in range(n_pods):
        _add_pod(t, p, chips_per_pod, chip, ici_latency_ps)
    uplink_bw = chip.dcn_bw_per_host * pods_per_rack / (n_spines * oversubscription)
    for r in range(n_racks):
        tor = f"tor{r}"
        for p in range(r * pods_per_rack, min((r + 1) * pods_per_rack, n_pods)):
            t.add_link(f"dcn.h{p}tor{r}", t.host_name(p), tor, chip.dcn_bw_per_host,
                       dcn_latency_ps)
        for j in range(n_spines):
            s = (r + j) % n_spines
            t.add_link(f"dcn.tor{r}spine{s}", tor, f"spine{s}", uplink_bw, dcn_latency_ps)
    return t


def scale(
    pods: int = 64,
    chips_per_pod: int = 4,
    fabric: str = "fat-tree",
    chip: ChipSpec = V5E,
    **kwargs,
) -> Topology:
    """Scaled-out testbed in one call: ``scale(pods=256)``.

    ``fabric="fat-tree"`` (default) builds :func:`fat_tree_cluster` —
    linear link count, the only fabric that stays tractable at 64–512
    pods.  ``fabric="mesh"`` builds the legacy full-mesh
    :func:`tpu_cluster` for small-topology parity runs.  Extra ``kwargs``
    pass through to the underlying builder.
    """
    if fabric == "fat-tree":
        return fat_tree_cluster(pods, chips_per_pod, chip=chip, **kwargs)
    if fabric == "mesh":
        return tpu_cluster(pods, chips_per_pod, chip=chip, **kwargs)
    raise ValueError(f"unknown fabric {fabric!r}; one of 'fat-tree', 'mesh'")
