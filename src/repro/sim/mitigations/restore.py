"""``checkpoint_restore``: roll a stalled host back instead of waiting."""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar, TYPE_CHECKING

from ..mitigation import MitigationPolicy, register_mitigation

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..cluster import ClusterOrchestrator


@register_mitigation
@dataclass
class CheckpointRestore(MitigationPolicy):
    """Long-stall remediation: restore from checkpoint rather than wait.

    The trigger loop polls every host's injected-but-undrained stall time
    (:attr:`~repro.sim.hostsim.HostSim.pending_stall_ps`, the telemetry a
    runtime watchdog would export).  When one crosses
    ``stall_threshold_ps`` the pending pause is cancelled
    (:meth:`~repro.sim.hostsim.HostSim.cancel_stall`) and replaced with the
    fixed ``restore_ps`` replay cost — the host still logs a ``gc_stall``
    (with ``cause=restore``), so the ``host_pause`` diagnosis signal is
    shortened, not masked (``masks`` stays empty).
    """

    mitigation_name: ClassVar[str] = "checkpoint_restore"

    #: pending stall above which restoring beats waiting (default 10 ms)
    stall_threshold_ps: int = 10_000_000_000
    #: checkpoint-restore replay cost charged instead (default 5 ms)
    restore_ps: int = 5_000_000_000

    def attach(self, cluster: "ClusterOrchestrator") -> None:
        """Watch pending host stalls; swap long ones for a restore."""

        def _probe(i: int) -> bool:
            victim = None
            for name in sorted(cluster.hosts):
                if cluster.hosts[name].pending_stall_ps >= self.stall_threshold_ps:
                    victim = cluster.hosts[name]
                    break
            if victim is None:
                return False
            cancelled = victim.cancel_stall()
            victim.inject_stall(self.restore_ps, "restore")
            self.log_trigger(
                cluster, host=victim.name, stall_us=cancelled // 1_000_000,
            )
            self.log_action(
                cluster, action="checkpoint_restore", target=victim.name,
                penalty=0.0,
                saved_us=(cancelled - self.restore_ps) // 1_000_000,
            )
            self.log_done(cluster, host=victim.name)
            return True

        self.watch(cluster, _probe)
