"""RPC request/response serving workload — one span tree per request.

The frontend (the first chip-bearing host) admits requests under an
**open-loop** Poisson arrival process (seeded, so byte-reproducible) or a
**closed-loop** fixed-concurrency process, fans each request out across
every serving pod over the interconnect, and fans the replies back in.
Every log event of a request carries its trace-context id (``rid`` /
``sub``), so the weave produces one end-to-end tree per request::

    RpcRequest r3                         (frontend host)
    ├── RpcCall r3.host0                  (local pod, no wire hop)
    │   └── RpcWork r3.host0
    │       └── Dispatch ×chips → DeviceProgram → Op / Collective
    │           └── LinkTransfer ×ICI ring chunks
    └── RpcCall r3.host1                  (remote pod)
        ├── LinkTransfer dcn.h0h1         (request leg)
        └── RpcWork r3.host1
            ├── Dispatch ×chips → DeviceProgram → ...
            └── LinkTransfer dcn.h0h1     (reply leg, "<sub>.r")

Serving is **serial per host** (one subrequest at a time, FIFO queue), so
queueing delay under open-loop overload shows up as RpcCall-minus-RpcWork
time — the tail-latency signal ``core.analysis.request_latency_stats``
summarizes and ``slowest_request`` drills into.

Setting any of the **saturation knobs** (``lb`` / ``queue_depth`` /
``timeout_ps``) switches the workload into *serving mode*: each request is
dispatched to **one** backend chosen by a registered load-balancer policy
(:mod:`repro.sim.workloads.lb`) instead of fanned out to every pod, backend
FIFOs are bounded (``queue_depth``) with deterministic drop-on-full, the
frontend arms a per-attempt deadline (``timeout_ps``) and re-issues failed
attempts with seeded exponential backoff up to ``max_retries`` times.
Every admitted ``rid`` terminates in exactly one ``rpc_done`` carrying
``outcome`` ∈ {completed, dropped, timed_out} — the conservation invariant
``issued == completed + dropped + timed_out`` that
``tests/test_serving_saturation.py`` locks down.  Drop NACKs are modeled as
instantaneous control-plane signals (the data-plane legs still pay wire
time).  With all three knobs at their ``None`` defaults the legacy
fan-out-to-all-pods schedule is byte-identical to pre-saturation runs.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import ClassVar, Optional, TYPE_CHECKING

from ..hostsim import _short
from ..workload import OpSpec, ProgramSpec, Workload, register_workload
from .lb import backend_load, lb_policy_type, make_lb_policy

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..cluster import ClusterOrchestrator
    from ..hostsim import HostSim

PS_PER_S = 1_000_000_000_000


def rpc_handler_program(
    name: str = "rpc_infer",
    tp_bytes: float = 1 << 20,
    flops: float = 2e11,
    hbm_bytes: float = 1e8,
) -> ProgramSpec:
    """The default per-request handler: a tensor-parallel inference step
    over the serving pod's ICI ring (all-gather → compute → all-reduce).
    Cross-pod (DCN-group) ops are deliberately absent: a request is served
    entirely inside one pod."""
    return ProgramSpec(name, [
        OpSpec(name="tp.ag", kind="all-gather", coll_bytes=tp_bytes),
        OpSpec(name="infer.ffn", kind="compute", flops=flops, bytes=hbm_bytes),
        OpSpec(name="tp.ar", kind="all-reduce", coll_bytes=tp_bytes),
    ])


def _ici_only(program: ProgramSpec) -> ProgramSpec:
    """Strip cross-pod (DCN-group) ops and their waits from a program.

    A request is served by one pod; a DCN-group op would rendezvous with
    homologue chips in pods that never join this request's collective and
    stall the request forever.  Sweeping ``workload=rpc`` over scenarios
    whose program is a training step therefore serves the ICI-only part.
    """
    dcn_names = {o.name for o in program.ops if o.group == "dcn"}
    ops = [
        o for o in program.ops
        if o.group != "dcn" and not (o.kind == "wait" and o.wait_for in dcn_names)
    ]
    if ops == program.ops:
        return program
    return ProgramSpec(name=program.name, ops=ops)


@dataclass
class _PodServer:
    """Per-host serving state: FIFO of pending subrequests + busy flag."""

    host: "HostSim"
    queue: deque = field(default_factory=deque)
    busy: bool = False


@register_workload
@dataclass
class RpcServing(Workload):
    """Open/closed-loop request serving with per-request trace contexts.

    Knobs beyond the standard five:

    * ``n_requests``    — total requests (default ``4 * n_steps`` so sweep
      size overrides scale serving cells too);
    * ``arrival``       — ``"open"`` (Poisson at ``rate_rps``, seeded) or
      ``"closed"`` (``concurrency`` outstanding requests, next issued on
      completion);
    * ``rate_rps`` / ``concurrency`` — the two loops' intensity dials;
    * ``request_bytes`` / ``reply_bytes`` — wire payloads per fan-out leg;
    * ``dequeue_ps``    — fixed host-runtime cost to pick up a subrequest.

    Saturation knobs (any of the first three switches on *serving mode* —
    one LB-picked backend per attempt instead of fan-out to every pod):

    * ``lb``            — registered load-balancer policy name
      (``round_robin`` / ``least_loaded`` / ``power_of_two_choices``;
      defaults to ``round_robin`` when only the other knobs are set);
    * ``queue_depth``   — bound on each backend's pending FIFO (``None`` =
      unbounded); a full queue drops the attempt deterministically;
    * ``timeout_ps``    — per-attempt frontend deadline (``None`` = none);
    * ``max_retries``   — re-issues after a drop/timeout (0 = fail fast);
    * ``retry_backoff_ps`` — base backoff; attempt ``k`` waits
      ``base * 2^(k-1) * (1 + U[0,1))`` ps from the seeded retry stream.

    After ``drive()`` + ``cluster.run()``, :attr:`outcomes` holds the
    request-outcome accounting (issued/completed/dropped/timed_out/retries,
    ``max_in_flight``, per-completed-request ``lat_ps``) — what the tier-1
    conservation gate and ``engine_bench``'s saturation section read.

    The handler program is ``program`` with any DCN-group ops stripped
    (see :func:`_ici_only`); scenarios that mean serving from the start
    pass :func:`rpc_handler_program` directly.
    """

    workload_name: ClassVar[str] = "rpc"

    n_requests: Optional[int] = None
    arrival: str = "open"                 # "open" | "closed"
    rate_rps: float = 2000.0
    concurrency: int = 4
    request_bytes: int = 32 << 10
    reply_bytes: int = 64 << 10
    dequeue_ps: int = 200_000             # 0.2 us runtime pickup cost
    lb: Optional[str] = None              # LB policy name; None = legacy fan-out
    queue_depth: Optional[int] = None     # per-backend FIFO bound; None = unbounded
    timeout_ps: Optional[int] = None      # per-attempt deadline; None = none
    max_retries: int = 1                  # re-issues after drop/timeout
    retry_backoff_ps: int = 1_000_000     # 1 us base exponential backoff

    def __post_init__(self) -> None:
        if self.arrival not in ("open", "closed"):
            raise ValueError(
                f"arrival must be 'open' or 'closed', got {self.arrival!r}"
            )
        if self.queue_depth is not None and self.queue_depth < 1:
            raise ValueError(
                f"queue_depth must be >= 1 (or None for unbounded), "
                f"got {self.queue_depth}"
            )
        if self.timeout_ps is not None and self.timeout_ps <= 0:
            raise ValueError(
                f"timeout_ps must be > 0 (or None for no deadline), "
                f"got {self.timeout_ps}"
            )
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.retry_backoff_ps < 0:
            raise ValueError(
                f"retry_backoff_ps must be >= 0, got {self.retry_backoff_ps}"
            )
        if self.lb is None and (
                self.queue_depth is not None or self.timeout_ps is not None):
            self.lb = "round_robin"
        if self.lb is not None:
            lb_policy_type(self.lb)   # unknown policy: KeyError listing names
        #: request-outcome accounting, filled by :meth:`drive` (serving mode)
        self.outcomes: dict = {}

    @property
    def total_requests(self) -> int:
        """The effective request count (``n_requests`` or ``4 * n_steps``)."""
        return self.n_requests if self.n_requests is not None else 4 * self.n_steps

    @property
    def serving_mode(self) -> bool:
        """True when a saturation knob switched on LB-picked single-backend
        serving (vs the legacy fan-out-to-every-pod schedule)."""
        return self.lb is not None

    def describe(self) -> str:
        loop = (f"open {self.rate_rps:g} rps" if self.arrival == "open"
                else f"closed x{self.concurrency}")
        if not self.serving_mode:
            return f"rpc({self.total_requests} reqs, {loop})"
        q = "unbounded" if self.queue_depth is None else f"q={self.queue_depth}"
        to = ("" if self.timeout_ps is None
              else f", timeout={self.timeout_ps / 1e6:g}us")
        return (f"rpc({self.total_requests} reqs, {loop}, lb={self.lb}, "
                f"{q}{to}, retries<={self.max_retries})")

    # -- driving -----------------------------------------------------------------

    def drive(self, cluster: "ClusterOrchestrator") -> None:
        """Arm arrivals at the frontend + serial per-pod serving queues."""
        hosts = self.serving_hosts(cluster)
        if not hosts:
            raise ValueError("rpc workload needs at least one chip-bearing host")
        frontend = hosts[0]
        handler = _ici_only(self.program)
        servers = {h.name: _PodServer(h) for h in hosts}
        sub_steps = itertools.count()     # unique dispatch-step int per sub
        n_total = self.total_requests
        state = {"issued": 0, "completed": 0}

        for h in hosts:
            self.start_clock_telemetry(h)

        def serve_next(srv: _PodServer) -> None:
            if not srv.queue:
                srv.busy = False
                return
            srv.busy = True
            sub, rid, reply = srv.queue.popleft()
            srv.host.sim.call_after(
                self.dequeue_ps, lambda: begin_work(srv, sub, rid, reply)
            )

        def begin_work(srv: _PodServer, sub: str, rid: str, reply) -> None:
            h = srv.host
            h.log_event("rpc_work_begin", sub=sub, rid=rid)
            # an injected HostPause stall drains at the subrequest boundary,
            # *after* rpc_work_begin so the gc_stall event lands inside this
            # request's RpcWork span (per-request diagnosis sees it)
            stall = h.consume_stall(sub=sub, rid=rid)
            if stall:
                h.sim.call_after(stall, lambda: run_handler(srv, sub, rid, reply))
            else:
                run_handler(srv, sub, rid, reply)

        def run_handler(srv: _PodServer, sub: str, rid: str, reply) -> None:
            h = srv.host
            step = next(sub_steps)
            pending = {"n": len(h.chips)}

            def chip_done(chip: str, _t: int) -> None:
                h.log_event("program_retire", chip=_short(chip), step=step,
                            program=handler.name)
                pending["n"] -= 1
                if pending["n"] == 0:
                    h.log_event("rpc_work_end", sub=sub, rid=rid)
                    reply()
                    serve_next(srv)

            for chip in h.chips:
                h.log_event("program_enqueue", chip=_short(chip), step=step,
                            program=handler.name)
                cluster.dispatch(h, chip, handler, step, chip_done)

        def enqueue(srv: _PodServer, sub: str, rid: str, reply) -> None:
            srv.queue.append((sub, rid, reply))
            if not srv.busy:
                serve_next(srv)

        if self.serving_mode:
            self._drive_serving(cluster, hosts, servers, enqueue, state, n_total)
            return

        def admit(i: int) -> None:
            rid = f"r{i}"
            t0 = frontend.sim.now
            frontend.log_event("rpc_recv", rid=rid, bytes=self.request_bytes)
            pending = {"n": len(hosts)}

            def fan_in(sub: str) -> None:
                frontend.log_event("rpc_reply", rid=rid, sub=sub)
                pending["n"] -= 1
                if pending["n"] == 0:
                    frontend.log_event(
                        "rpc_done", rid=rid, lat=frontend.sim.now - t0,
                        fanout=len(hosts),
                    )
                    state["completed"] += 1
                    if self.arrival == "closed" and state["issued"] < n_total:
                        issue_now()
                    if state["completed"] == n_total:
                        cluster.net.stop_all_flows()

            for h in hosts:
                sub = f"{rid}.{h.name}"
                frontend.log_event("rpc_send", rid=rid, sub=sub, dst=h.name,
                                   bytes=self.request_bytes)
                if h is frontend:
                    # local pod: no wire hop, reply is a local fan-in
                    enqueue(servers[h.name], sub, rid,
                            lambda s=sub: fan_in(s))
                else:
                    def deliver(_t: int, hh=h, s=sub) -> None:
                        enqueue(servers[hh.name], s, rid,
                                lambda: send_reply(hh, s))

                    def send_reply(hh: "HostSim", s: str) -> None:
                        cluster.net.transfer(
                            hh.name, frontend.name, self.reply_bytes,
                            meta={"rpc": f"{s}.r"},
                            on_delivered=lambda _t, s=s: fan_in(s),
                        )

                    cluster.net.transfer(
                        frontend.name, h.name, self.request_bytes,
                        meta={"rpc": sub}, on_delivered=deliver,
                    )

        def issue_now() -> None:
            i = state["issued"]
            state["issued"] += 1
            admit(i)

        self._arm_arrivals(frontend, n_total, issue_now)

    def _arm_arrivals(self, frontend: "HostSim", n_total: int, issue_now) -> None:
        """Schedule the arrival process (shared by both serving schedules).

        Open-loop pre-draws the whole Poisson schedule from stream 0
        (deterministic and identical whether or not saturation knobs are
        set); closed-loop issues the initial concurrency window.
        """
        if self.arrival == "open":
            # pre-draw the whole Poisson arrival schedule (deterministic)
            rng = self.rng(stream=0)
            t = 0.0
            for _ in range(n_total):
                t += rng.expovariate(self.rate_rps) * PS_PER_S
                frontend.sim.at(int(t), issue_now)
        else:
            for _ in range(min(self.concurrency, n_total)):
                issue_now()

    def _drive_serving(
        self,
        cluster: "ClusterOrchestrator",
        hosts: list,
        servers: dict,
        enqueue,
        state: dict,
        n_total: int,
    ) -> None:
        """Serving mode: one LB-picked backend per attempt, bounded queues
        with deterministic drop-on-full, per-attempt deadlines, seeded
        retry/backoff — every admitted ``rid`` ends in exactly one
        ``rpc_done`` with an ``outcome``.
        """
        frontend = hosts[0]
        backends = [servers[h.name] for h in hosts]
        policy = make_lb_policy(self.lb)
        rng_retry = self.rng(stream=2)    # backoff jitter
        rng_lb = self.rng(stream=3)       # power-of-two-choices sampling
        state.update(
            dropped=0, timed_out=0, retries=0, finalized=0,
            in_flight=0, max_in_flight=0, lat_ps=[],
        )
        self.outcomes = state

        def finalize(req: dict, outcome: str) -> None:
            req["done"] = True
            state["in_flight"] -= 1
            state[outcome] += 1
            lat = frontend.sim.now - req["t0"]
            if outcome == "completed":
                state["lat_ps"].append(lat)
            frontend.log_event(
                "rpc_done", rid=req["rid"], lat=lat,
                attempts=req["attempt"] + 1, outcome=outcome,
            )
            if self.arrival == "closed" and state["issued"] < n_total:
                issue_now()
            state["finalized"] += 1
            if state["finalized"] == n_total:
                cluster.net.stop_all_flows()

        def retry_or_fail(req: dict, reason: str) -> None:
            if req["attempt"] < self.max_retries:
                req["attempt"] += 1
                state["retries"] += 1
                backoff = int(
                    self.retry_backoff_ps * (2 ** (req["attempt"] - 1))
                    * (1.0 + rng_retry.random())
                )
                frontend.log_event(
                    "rpc_retry", rid=req["rid"], attempt=req["attempt"],
                    reason=reason, backoff=backoff,
                )
                frontend.sim.call_after(backoff, lambda: attempt(req))
            else:
                finalize(req, "dropped" if reason == "dropped" else "timed_out")

        def attempt(req: dict) -> None:
            rid = req["rid"]
            k = req["attempt"]
            sub = f"{rid}.a{k}"
            srv = policy.pick(backends, rng_lb)
            frontend.log_event(
                "rpc_lb_pick", rid=rid, attempt=k, policy=self.lb,
                dst=srv.host.name, qlen=backend_load(srv),
            )
            frontend.log_event("rpc_send", rid=rid, sub=sub,
                               dst=srv.host.name, bytes=self.request_bytes)
            att = {"resolved": False}

            def settle() -> bool:
                # first resolution wins: reply, drop NACK, or deadline; a
                # late reply after a timeout is ignored (the backend still
                # paid the work — realistic wasted service)
                if att["resolved"] or req["done"]:
                    return False
                att["resolved"] = True
                return True

            def on_reply() -> None:
                if not settle():
                    return
                frontend.log_event("rpc_reply", rid=rid, sub=sub)
                finalize(req, "completed")

            def on_drop() -> None:
                if not settle():
                    return
                # the NACK is an instantaneous control-plane signal; the
                # request leg already paid its wire time
                frontend.log_event("rpc_reply", rid=rid, sub=sub,
                                   status="dropped")
                retry_or_fail(req, "dropped")

            def offer(srv: _PodServer, reply) -> None:
                if (self.queue_depth is not None
                        and len(srv.queue) >= self.queue_depth):
                    srv.host.log_event(
                        "rpc_queue_drop", sub=sub, rid=rid,
                        qlen=len(srv.queue), depth=self.queue_depth,
                    )
                    on_drop()
                    return
                enqueue(srv, sub, rid, reply)

            if self.timeout_ps is not None:
                def deadline() -> None:
                    if not settle():
                        return
                    frontend.log_event(
                        "rpc_timeout", rid=rid, sub=sub, attempt=k,
                        deadline=self.timeout_ps,
                    )
                    retry_or_fail(req, "timed_out")

                frontend.sim.call_after(self.timeout_ps, deadline)

            if srv.host is frontend:
                offer(srv, on_reply)
            else:
                def send_reply() -> None:
                    cluster.net.transfer(
                        srv.host.name, frontend.name, self.reply_bytes,
                        meta={"rpc": f"{sub}.r"},
                        on_delivered=lambda _t: on_reply(),
                    )

                cluster.net.transfer(
                    frontend.name, srv.host.name, self.request_bytes,
                    meta={"rpc": sub},
                    on_delivered=lambda _t: offer(srv, send_reply),
                )

        def admit(i: int) -> None:
            rid = f"r{i}"
            req = {"rid": rid, "t0": frontend.sim.now, "attempt": 0,
                   "done": False}
            state["in_flight"] += 1
            if state["in_flight"] > state["max_in_flight"]:
                state["max_in_flight"] = state["in_flight"]
            frontend.log_event("rpc_recv", rid=rid, bytes=self.request_bytes)
            attempt(req)

        def issue_now() -> None:
            i = state["issued"]
            state["issued"] += 1
            admit(i)

        self._arm_arrivals(frontend, n_total, issue_now)
