"""Logical-axis sharding rules + an ambient sharding context.

Model code annotates parameters with logical axes (PSpec.axes) and
activations via :func:`constrain`.  A Rules table maps logical axes to mesh
axes; when no sharding context is active (CPU tests), constraints are no-ops.

Default mapping (production mesh ("pod","data","model") or ("data","model")):

  batch        -> ("pod","data")   pure DP across pods (DCN-friendly)
  vocab/mlp/heads/kv_heads/expert/inner/lru -> "model"  (TP / EP)
  embed        -> "data" when FSDP (ZeRO-3-style, intra-pod all-gathers)
  cache_seq    -> "data"           (long-context KV shards, SP)

Dims not divisible by their mesh axes fall back to replication (recorded).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .params import Rules

_ctx = threading.local()


def make_rules(
    mesh: Mesh,
    fsdp: bool = True,
    shard_cache_seq: Optional[str] = None,   # mesh axis for KV-cache seq dim
    extra: Optional[Dict[str, Any]] = None,
    parallel_mode: str = "tp",               # "tp" | "fsdp_all"
) -> Rules:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    if parallel_mode == "fsdp_all":
        # pure-FSDP mapping: NO tensor parallelism — batch shards over
        # (data, model), parameters fully shard over (data, model) on the
        # embed dim and gather per layer; eliminates all per-token TP
        # all-reduces at the cost of per-layer param all-gathers.
        fs = ("data", "model")
        rules: Dict[str, Any] = {
            "vocab": None, "mlp": None, "heads": None, "kv_heads": None,
            "expert": None, "inner": None, "inner2": None, "lru": None,
            "embed": fs, "embed_nr": None, "layers": None,
            "batch": fs, "seq": None,
            "act_heads": None, "act_kv_heads": None, "act_mlp": None,
            "act_vocab": None, "act_expert": None,
            "cache_seq": None,
        }
        if extra:
            rules.update(extra)
        return Rules(rules, sizes)
    rules: Dict[str, Any] = {
        # parameters
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "expert": "model",
        "inner": "model",
        "inner2": "model",
        "lru": "model",
        "embed": "data" if (fsdp and "data" in sizes) else None,
        "embed_nr": None,
        "layers": None,
        # activations
        "batch": dp,
        "seq": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_expert": "model",
        "cache_seq": shard_cache_seq if shard_cache_seq in sizes else None,
    }
    if extra:
        rules.update(extra)
    return Rules(rules, sizes)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Rules):
    prev = getattr(_ctx, "value", None)
    _ctx.value = (mesh, rules)
    try:
        yield
    finally:
        _ctx.value = prev


def current_context() -> Optional[Tuple[Mesh, Rules]]:
    return getattr(_ctx, "value", None)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axes; no-op without context."""
    ctx = current_context()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.act(*logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
