"""Implicit context propagation between SpanWeavers (Columbo §3.6).

Simulators are *unmodified* (here: they only write their native logs), so no
explicit trace context ever crosses a simulator boundary.  Instead, weavers
exchange SpanContexts through shared queues keyed by *natural boundary
identifiers* that appear in both simulators' logs — exactly the paper's
mechanism (PCIe/Ethernet boundaries; we use dispatch queue ids, DMA ids,
collective channel ids, and chunk ids).

Implementation detail beyond the paper: ``poll`` can be non-blocking,
blocking (online mode, §3.8), or *deferred* — a weaver may register a link
to be resolved at end-of-weave, which makes sync single-threaded processing
independent of pipeline execution order.  Deferred resolution is possible
precisely because contexts are keyed by ids from the logs, not by arrival
order.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

from .span import Span, SpanContext

Key = Tuple[Hashable, ...]


class ContextRegistry:
    """Shared, thread-safe context store for a set of SpanWeavers."""

    def __init__(self) -> None:
        self._store: Dict[Key, SpanContext] = {}
        self._cv = threading.Condition()
        self.pushes = 0
        self.hits = 0
        self.misses = 0
        self._deferred: List[Tuple[Span, Key, str]] = []

    # -- paper's push/poll ----------------------------------------------------

    def push(self, key: Key, ctx: SpanContext) -> None:
        with self._cv:
            self._store[key] = ctx
            self.pushes += 1
            self._cv.notify_all()

    def poll(self, key: Key, timeout: Optional[float] = None) -> Optional[SpanContext]:
        """Non-blocking by default; blocking with timeout for online mode."""
        with self._cv:
            if timeout:
                deadline_ok = self._cv.wait_for(lambda: key in self._store, timeout)
                if not deadline_ok:
                    self.misses += 1
                    return None
            ctx = self._store.get(key)
            if ctx is None:
                self.misses += 1
            else:
                self.hits += 1
            return ctx

    # -- deferred resolution ----------------------------------------------------

    def defer(self, span: Span, key: Key, mode: str = "parent") -> None:
        """Ask for span.parent (mode='parent') or a span link (mode='link')
        to be resolved to the context stored under ``key`` at finish time."""
        with self._cv:
            self._deferred.append((span, key, mode))

    def resolve_deferred(self) -> Dict[str, int]:
        """Resolve all deferred parent/link requests.  Returns stats."""
        resolved = 0
        orphans = 0
        with self._cv:
            for span, key, mode in self._deferred:
                ctx = self._store.get(key)
                if ctx is None:
                    orphans += 1
                    continue
                if mode == "parent":
                    span.parent = ctx
                    # adopt the upstream trace id so the whole causal chain
                    # lands in one trace
                    span.context = SpanContext(ctx.trace_id, span.context.span_id)
                else:
                    span.add_link(ctx)
                resolved += 1
            self._deferred.clear()
        self.hits += resolved
        self.misses += orphans
        return {"resolved": resolved, "orphans": orphans}

    def stats(self) -> Dict[str, int]:
        return {
            "pushes": self.pushes,
            "hits": self.hits,
            "misses": self.misses,
            "pending_deferred": len(self._deferred),
        }


class UnlockedContextRegistry(ContextRegistry):
    """Single-threaded :class:`ContextRegistry` without the condition
    variable.

    Sync execution and the inline (in-sim) weave never share the registry
    across threads, yet every push/poll paid a lock round-trip — measurable
    at millions of context exchanges per 256-pod run.  Semantics are
    identical to the base class for single-threaded use, including counter
    updates and deferred resolution; blocking ``poll`` timeouts degrade to
    an immediate miss (there is no other thread that could ever satisfy
    them).
    """

    def push(self, key: Key, ctx: SpanContext) -> None:
        self._store[key] = ctx
        self.pushes += 1

    def poll(self, key: Key, timeout: Optional[float] = None) -> Optional[SpanContext]:
        ctx = self._store.get(key)
        if ctx is None:
            self.misses += 1
        else:
            self.hits += 1
        return ctx

    def defer(self, span: Span, key: Key, mode: str = "parent") -> None:
        self._deferred.append((span, key, mode))

    def resolve_deferred(self) -> Dict[str, int]:
        resolved = 0
        orphans = 0
        store_get = self._store.get
        for span, key, mode in self._deferred:
            ctx = store_get(key)
            if ctx is None:
                orphans += 1
                continue
            if mode == "parent":
                span.parent = ctx
                span.context = SpanContext(ctx.trace_id, span.context.span_id)
            else:
                span.add_link(ctx)
            resolved += 1
        self._deferred.clear()
        self.hits += resolved
        self.misses += orphans
        return {"resolved": resolved, "orphans": orphans}
