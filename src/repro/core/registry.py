"""Pluggable simulator registry.

The paper's modularity argument ("Modular Full-System Simulation") only
holds if a new simulator *type* — a storage simulator, a DPU simulator —
can join the composition without editing core files.  This module replaces
the three hardcoded lookup tables the original API carried
(``WEAVERS`` in weaver.py, ``PARSERS``/``parser_for`` in parsers.py and
``_SYNC_ORDER`` in script.py) with one registry binding a simulator type to:

* a **parser factory** — log line -> typed Event (producers' input side),
* a **weaver factory** — ``(ContextRegistry, **options) -> SpanWeaver``,
* a **sync priority**  — offline-sync ordering hint: lower runs earlier, so
  context *pushes* (host dispatch ids, DMA ids) happen before the *polls*
  of downstream simulators; deferred resolution covers whatever is left.

Registering a custom type end to end::

    from repro.core import register_simulator

    register_simulator(
        "storage",
        parser=StorageLogParser,
        weaver=StorageSpanWeaver,
        sync_priority=30,          # after host (0), before analysis-only sims
    )
    session.add_log("storage.log", "storage")   # now just works

``SimulatorRegistry`` instances can also be created per-session to scope a
registration to one ``TraceSession`` without touching the process-wide
default.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, TYPE_CHECKING

from .errors import UnknownSimTypeError
from .events import SimType, sim_type_value

if TYPE_CHECKING:  # avoid import cycles; factories are duck-typed anyway
    from .context import ContextRegistry
    from .parsers import LogParser
    from .weaver import SpanWeaver

# Priority bands: builtins occupy 0/10/20; custom types default to 100 so
# they run after every context-pushing builtin unless they say otherwise.
DEFAULT_SYNC_PRIORITY = 100


@dataclass(frozen=True)
class SimulatorSpec:
    """Everything the engine needs to know about one simulator type."""

    sim_type: str
    parser: Callable[[], "LogParser"]
    weaver: Callable[..., "SpanWeaver"]
    sync_priority: int = DEFAULT_SYNC_PRIORITY
    description: str = ""


class SimulatorRegistry:
    """Binds simulator types to their parser/weaver factories + sync hints."""

    def __init__(self, specs: Iterable[SimulatorSpec] = ()) -> None:
        self._specs: Dict[str, SimulatorSpec] = {}
        for spec in specs:
            self._specs[spec.sim_type] = spec

    # -- registration -----------------------------------------------------------

    def register(
        self,
        sim_type,
        parser: Callable[[], "LogParser"],
        weaver: Callable[..., "SpanWeaver"],
        sync_priority: int = DEFAULT_SYNC_PRIORITY,
        description: str = "",
        replace: bool = False,
    ) -> SimulatorSpec:
        value = sim_type_value(sim_type)
        if not replace and value in self._specs:
            raise ValueError(
                f"simulator type {value!r} already registered; pass replace=True to override"
            )
        spec = SimulatorSpec(value, parser, weaver, sync_priority, description)
        self._specs[value] = spec
        return spec

    def unregister(self, sim_type) -> None:
        self._specs.pop(sim_type_value(sim_type), None)

    # -- lookup -----------------------------------------------------------------

    def get(self, sim_type) -> SimulatorSpec:
        value = sim_type_value(sim_type)
        spec = self._specs.get(value)
        if spec is None:
            raise UnknownSimTypeError(value, registered=self._specs.keys())
        return spec

    def __contains__(self, sim_type) -> bool:
        return sim_type_value(sim_type) in self._specs

    def sim_types(self) -> List[str]:
        return sorted(self._specs)

    def make_parser(self, sim_type) -> "LogParser":
        return self.get(sim_type).parser()

    def make_weaver(self, sim_type, context: "ContextRegistry", **options) -> "SpanWeaver":
        return self.get(sim_type).weaver(context, **options)

    def sync_priority(self, sim_type) -> int:
        """Ordering hint; lenient for types woven with an explicit weaver
        (they never needed a registration to run)."""
        spec = self._specs.get(sim_type_value(sim_type))
        return spec.sync_priority if spec is not None else DEFAULT_SYNC_PRIORITY

    def copy(self) -> "SimulatorRegistry":
        """Session-local registry seeded with the current registrations."""
        return SimulatorRegistry(self._specs.values())


# ---------------------------------------------------------------------------
# Process-wide default, pre-populated with the paper's three simulator types.
# ---------------------------------------------------------------------------


def _builtin_specs() -> List[SimulatorSpec]:
    from .parsers import DeviceLogParser, HostLogParser, NetLogParser
    from .weaver import DeviceSpanWeaver, HostSpanWeaver, NetSpanWeaver

    return [
        SimulatorSpec(SimType.HOST.value, HostLogParser, HostSpanWeaver, 0,
                      "host runtime: steps, data load, dispatch, DMA, ckpt, NTP"),
        SimulatorSpec(SimType.DEVICE.value, DeviceLogParser, DeviceSpanWeaver, 10,
                      "accelerator chip: programs, ops, HBM, collectives"),
        SimulatorSpec(SimType.NET.value, NetLogParser, NetSpanWeaver, 20,
                      "interconnect: ICI/DCN link transfers"),
    ]


DEFAULT_REGISTRY = SimulatorRegistry(_builtin_specs())


def register_simulator(
    sim_type,
    parser: Callable[[], "LogParser"],
    weaver: Callable[..., "SpanWeaver"],
    sync_priority: int = DEFAULT_SYNC_PRIORITY,
    description: str = "",
    replace: bool = False,
) -> SimulatorSpec:
    """Register a simulator type on the process-wide default registry."""
    return DEFAULT_REGISTRY.register(
        sim_type, parser, weaver, sync_priority, description, replace
    )


def simulator_for(sim_type) -> SimulatorSpec:
    """Look up a simulator type on the process-wide default registry."""
    return DEFAULT_REGISTRY.get(sim_type)
