"""DES collective-engine invariants (regression tests for two real bugs:
late-arrival completion and op-name rendezvous collisions)."""
import tempfile

import pytest
from _hypothesis_compat import given, settings, st

from repro.sim import ClusterOrchestrator, run_training_sim, tpu_cluster
from repro.sim.workload import OpSpec, ProgramSpec


def _run(prog, chips=4, pods=1, scale=None, bg=False):
    with tempfile.TemporaryDirectory() as d:
        kw = {}
        if bg:
            kw.update(bg_traffic_link="dcn.h0h1", bg_rate=15e9)
        cl = run_training_sim(prog, n_steps=1, n_pods=pods, chips_per_pod=chips,
                              outdir=d, compute_scale=scale, **kw)
        return cl


@pytest.mark.parametrize("kind", ["all-reduce", "all-gather", "reduce-scatter",
                                  "all-to-all", "collective-permute"])
def test_every_collective_kind_completes(kind):
    prog = ProgramSpec("p", [
        OpSpec("c0", "compute", 1e9, 1e8),
        OpSpec(f"{kind}.x", kind, coll_bytes=1e7),
        OpSpec("c1", "compute", 1e9, 1e8),
    ])
    cl = _run(prog)
    assert all(h.steps_done == 1 for h in cl.hosts.values() if h.chips)
    for inst in cl._collectives.values():
        assert all(inst.done.values()), inst.coll_id


@given(st.lists(st.floats(min_value=0.25, max_value=8.0), min_size=4, max_size=4))
@settings(max_examples=20, deadline=None)
def test_collectives_complete_under_any_straggler_skew(scales):
    """Late arrivals (chunks delivered before a chip reaches the collective)
    must still complete — regression for the arrive/recv race."""
    prog = ProgramSpec("p", [
        OpSpec("c0", "compute", 2e9, 1e8),
        OpSpec("ar", "all-reduce", coll_bytes=2e7),
        OpSpec("cp", "collective-permute", coll_bytes=1e6),
        OpSpec("c1", "compute", 1e9, 1e8),
    ])
    scale = {f"pod0.chip{i:02d}": s for i, s in enumerate(scales)}
    cl = _run(prog, chips=4, scale=scale)
    assert all(h.steps_done == 1 for h in cl.hosts.values() if h.chips)
    for inst in cl._collectives.values():
        assert all(inst.done.values())


def test_same_prefix_collective_kinds_do_not_collide():
    """all-reduce/all-gather/all-to-all with identical op names must use
    distinct rendezvous instances — regression for the kind-collision
    deadlock (assertion in CollectiveInstance.arrive guards it)."""
    prog = ProgramSpec("p", [
        OpSpec("al.0", "all-reduce", coll_bytes=1e6),
        OpSpec("al.0", "all-gather", coll_bytes=1e6),
        OpSpec("al.0", "all-to-all", coll_bytes=1e6),
    ])
    cl = _run(prog)
    assert all(h.steps_done == 1 for h in cl.hosts.values() if h.chips)
    assert len(cl._collectives) == 3


def test_cross_pod_collective_under_background_traffic_completes():
    prog = ProgramSpec("p", [
        OpSpec("c0", "compute", 2e9, 1e8),
        OpSpec("gs", "all-reduce", coll_bytes=5e7, group="dcn"),
    ])
    cl = _run(prog, chips=2, pods=2, bg=True)
    assert all(h.steps_done == 1 for h in cl.hosts.values() if h.chips)
