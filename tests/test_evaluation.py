"""Scored diagnosis: confusion-matrix scoring, fault-magnitude scaling,
and detection-sensitivity curves (core/evaluation.py + faults.scaled).

Hand-built populations pin the counting semantics; hypothesis properties
pin the invariants (healthy cells score zero findings for any seed,
precision/recall stay in [0, 1], TP + FN equals the injected count); a
small live magnitude-axis sweep ties the curve endpoints to the simulator.
"""
from dataclasses import replace

import pytest
from _hypothesis_compat import HealthCheck, given, settings, st

from repro.core.analysis import RunStats
from repro.core.evaluation import (
    ClassConfusion,
    DiagnosisEvaluation,
    SensitivityCurve,
    evaluate_diagnosis,
    sensitivity_curves,
)
from repro.sim.faults import (
    ChunkReorder,
    ClockDrift,
    ClockStep,
    DeviceSlowdown,
    FaultPlan,
    HostPause,
    LinkDegradation,
    LinkLoss,
    LossRateTrace,
    StragglerPod,
)
from repro.sim.scenarios import SCENARIOS, get_scenario
from repro.sim.workload import list_workloads

FAULT_CLASSES = (
    "link_degradation", "link_loss", "link_reorder", "host_pause",
    "clock_fault", "device_slowdown", "straggler_pod",
)


def _cell(scenario="s", seed=0, expected=(), detected=(), magnitude=1.0,
          expected_components=None, finding_components=None, diag_wall_s=0.0):
    return RunStats(
        scenario=scenario, seed=seed,
        expected=tuple(expected), detected=tuple(detected),
        wall_s=0.1, events=10, n_spans=1,
        component_us={}, critical_components=[],
        magnitude=magnitude,
        expected_components=dict(expected_components or {}),
        finding_components=dict(finding_components or {}),
        diag_wall_s=diag_wall_s,
    )


# ---------------------------------------------------------------------------
# evaluate_diagnosis on hand-built populations
# ---------------------------------------------------------------------------


def test_confusion_counts_hand_built():
    stats = [
        _cell("faulty", 0, expected=("link_loss",), detected=("link_loss",)),
        _cell("faulty", 1, expected=("link_loss",), detected=()),          # FN
        _cell("clean", 0, expected=(), detected=("link_loss",)),           # FP
        _cell("clean", 1, expected=(), detected=()),                       # TN
    ]
    ev = evaluate_diagnosis(stats)
    c = ev.classes["link_loss"]
    assert (c.tp, c.fn, c.fp, c.tn) == (1, 1, 1, 1)
    assert c.injected == 2
    assert c.precision == 0.5 and c.recall == 0.5 and c.fpr == 0.5
    assert c.f1 == pytest.approx(0.5)
    assert ev.n_cells == 4
    assert ev.healthy_cells == 2 and ev.healthy_false_positives == 1
    assert ev.healthy_fpr == 0.5
    assert "link_loss" in ev.report()


def test_confusion_vacuous_denominators():
    # no predictions and no injections: vacuously perfect, never 0/0
    ev = evaluate_diagnosis([
        _cell(expected=("host_pause",), detected=("host_pause",)),
        _cell(expected=(), detected=()),
    ])
    c = ev.classes["host_pause"]
    assert c.precision == 1.0 and c.recall == 1.0 and c.fpr == 0.0
    empty = ClassConfusion(fault_class="x")
    assert empty.precision == 1.0 and empty.recall == 1.0
    assert empty.f1 == 1.0 and empty.fpr == 0.0 and empty.component_accuracy == 1.0


def test_component_naming_accuracy():
    stats = [
        _cell("a", 0, expected=("link_loss",), detected=("link_loss",),
              expected_components={"link_loss": ["dcn.l0"]},
              finding_components={"link_loss": ["dcn.l0", "dcn.l3"]}),  # hit
        _cell("a", 1, expected=("link_loss",), detected=("link_loss",),
              expected_components={"link_loss": ["dcn.l0"]},
              finding_components={"link_loss": ["dcn.l9"]}),            # miss
        # TP without component ground truth: not scored for naming
        _cell("b", 0, expected=("host_pause",), detected=("host_pause",)),
    ]
    ev = evaluate_diagnosis(stats)
    c = ev.classes["link_loss"]
    assert c.component_total == 2 and c.component_hits == 1
    assert c.component_accuracy == 0.5
    assert ev.classes["host_pause"].component_total == 0
    assert ev.component_accuracy == 0.5      # pooled over scored TP cells


def test_diag_wall_time_folds():
    ev = evaluate_diagnosis([
        _cell(diag_wall_s=0.2), _cell(diag_wall_s=0.5), _cell(diag_wall_s=0.1),
    ])
    assert ev.diag_wall_s_total == pytest.approx(0.8)
    assert ev.diag_wall_s_max == pytest.approx(0.5)


def test_macro_skips_never_seen_classes():
    # a class seen only as TN everywhere contributes nothing to the macros
    stats = [
        _cell(expected=("link_loss",), detected=("link_loss",)),
        _cell(expected=("host_pause",), detected=()),
    ]
    ev = evaluate_diagnosis(stats)
    assert ev.macro_recall == pytest.approx((1.0 + 0.0) / 2)
    assert ev.micro_recall == pytest.approx(1 / 2)


def test_evaluate_empty_population():
    ev = evaluate_diagnosis([])
    assert ev.n_cells == 0 and not ev.classes
    assert ev.macro_f1 == 1.0          # vacuously perfect, and report() renders
    assert ev.report()


# ---------------------------------------------------------------------------
# hypothesis: confusion-matrix invariants on arbitrary populations
# ---------------------------------------------------------------------------

_subset = st.sets(st.sampled_from(FAULT_CLASSES), max_size=3)


@given(st.lists(st.tuples(_subset, _subset), max_size=24))
@settings(max_examples=60, deadline=None)
def test_confusion_invariants_hold_for_any_population(cells):
    stats = [
        _cell("s", i, expected=tuple(sorted(exp)), detected=tuple(sorted(det)))
        for i, (exp, det) in enumerate(cells)
    ]
    ev = evaluate_diagnosis(stats)
    assert ev.n_cells == len(stats)
    assert ev.healthy_cells == sum(1 for exp, _ in cells if not exp)
    for name, c in ev.classes.items():
        assert 0.0 <= c.precision <= 1.0
        assert 0.0 <= c.recall <= 1.0
        assert 0.0 <= c.f1 <= 1.0
        assert 0.0 <= c.fpr <= 1.0
        # TP + FN is exactly the number of cells that injected the class
        assert c.tp + c.fn == sum(1 for exp, _ in cells if name in exp)
        assert c.fp == sum(
            1 for exp, det in cells if name in det and name not in exp
        )
        assert c.tp + c.fn + c.fp + c.tn == len(stats)
    for metric in (ev.macro_precision, ev.macro_recall, ev.macro_f1,
                   ev.micro_precision, ev.micro_recall, ev.healthy_fpr):
        assert 0.0 <= metric <= 1.0


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.too_slow]
          if hasattr(HealthCheck, "too_slow") else [])
def test_healthy_scenario_scores_zero_findings_for_any_seed(seed):
    """The curated healthy baseline diagnoses clean under every workload
    type for arbitrary seeds: the FPR floor the leaderboard reports."""
    healthy = [n for n in SCENARIOS if not get_scenario(n).expected_classes]
    assert healthy, "library must include a healthy baseline"
    for name in healthy:
        for wl in list_workloads():
            spec = replace(get_scenario(name), workload=wl, workload_params=())
            run = spec.run(seed=seed)
            assert run.diagnosis.findings == [], (
                f"{name} under {wl} seed={seed}: healthy cell produced "
                f"findings {[f.fault_class for f in run.diagnosis.findings]}"
            )


# ---------------------------------------------------------------------------
# fault-magnitude scaling (FaultSpec.scaled / FaultPlan.scaled)
# ---------------------------------------------------------------------------

_ALL_FAULTS = (
    LinkDegradation(link="ici.pod0.l1", bw_factor=0.08),
    LinkLoss(link="dcn.l0", drop_prob=0.3),
    LinkLoss(link="dcn.l1", drop_prob=0.0,
             trace=LossRateTrace(profile="burst", peak=0.4, base=0.01)),
    ChunkReorder(link="ici.pod1.l0", jitter_ps=40_000),
    HostPause(host="host0", pause_ps=9_000_000),
    ClockDrift(host="host1", drift_ppm=150.0),
    ClockStep(host="host1", step_ps=2_000_000),
    DeviceSlowdown(chip="pod0.chip02", factor=3.0),
    StragglerPod(pod=2, factor=1.8),
)


@pytest.mark.parametrize("fault", _ALL_FAULTS, ids=lambda f: type(f).__name__)
def test_scaled_identity_at_full_magnitude(fault):
    # the byte-identity contract: magnitude 1.0 is *the same object*, so a
    # magnitude-1.0 sweep cell reproduces the unscaled scenario exactly
    assert fault.scaled(1.0) is fault


@pytest.mark.parametrize("fault", _ALL_FAULTS, ids=lambda f: type(f).__name__)
def test_scaled_zero_is_healthy_noop(fault):
    z = fault.scaled(0.0)
    neutral = {
        "bw_factor": 1.0, "drop_prob": 0.0, "jitter_ps": 0, "pause_ps": 0,
        "drift_ppm": 0.0, "step_ps": 0, "factor": 1.0,
    }
    for attr, want in neutral.items():
        if hasattr(z, attr):
            assert getattr(z, attr) == want, f"{type(fault).__name__}.{attr}"
    if getattr(z, "trace", None) is not None:
        assert z.trace.peak == 0.0 and z.trace.base == 0.0


@pytest.mark.parametrize("fault", _ALL_FAULTS, ids=lambda f: type(f).__name__)
def test_scaled_monotonic_and_preserves_timing(fault):
    intensity = {
        # higher = more intense, normalized per knob
        "bw_factor": lambda f: 1.0 - f.bw_factor,
        "drop_prob": lambda f: f.drop_prob,
        "jitter_ps": lambda f: f.jitter_ps,
        "pause_ps": lambda f: f.pause_ps,
        "drift_ppm": lambda f: abs(f.drift_ppm),
        "step_ps": lambda f: abs(f.step_ps),
        "factor": lambda f: f.factor,
    }
    knobs = [fn for attr, fn in intensity.items() if hasattr(fault, attr)]
    prev = fault.scaled(0.0)
    for mag in (0.25, 0.5, 0.75, 1.0):
        cur = fault.scaled(mag)
        for fn in knobs:
            assert fn(cur) >= fn(prev) - 1e-12, (
                f"{type(fault).__name__} not monotonic at magnitude {mag}"
            )
        # scheduling knobs are never scaled: when the fault acts moves,
        # only how hard it hits
        for attr in ("start_ps", "end_ps", "at_ps", "every_ps", "period_ps"):
            if hasattr(fault, attr) and not callable(getattr(fault, attr)):
                assert getattr(cur, attr) == getattr(fault, attr)
        prev = cur


def test_fault_targets_and_plan_scaling():
    plan = FaultPlan(faults=_ALL_FAULTS, seed=3)
    assert plan.scaled(1.0) is plan
    half = plan.scaled(0.5)
    assert half.seed == plan.seed and len(half.faults) == len(plan.faults)
    assert half.faults[0].bw_factor == pytest.approx(0.08 ** 0.5)
    with pytest.raises(ValueError):
        plan.scaled(-0.1)
    # targets: the component a correct diagnosis must name, in order
    assert plan.targets()[0] == "ici.pod0.l1"
    assert "pod2" in plan.targets()
    assert len(plan.targets()) == len(set(plan.targets()))


def test_scenario_magnitude_flows_into_fault_plan():
    spec = replace(get_scenario("degraded_ici_link"), fault_magnitude=0.5)
    plan = spec.fault_plan()
    [fault] = plan.faults
    assert fault.bw_factor == pytest.approx(0.08 ** 0.5)
    assert spec.expected_components == {"link_degradation": ("ici.pod0.l1",)}
    # the default magnitude (1.0) keeps the published faults untouched
    published = get_scenario("degraded_ici_link")
    assert published.fault_plan(seed=7).faults == published.faults


# ---------------------------------------------------------------------------
# sensitivity curves
# ---------------------------------------------------------------------------


def test_sensitivity_curves_hand_built():
    stats = [
        _cell("deg", 0, expected=("link_degradation",), detected=(), magnitude=0.0),
        _cell("deg", 1, expected=("link_degradation",), detected=(), magnitude=0.0),
        _cell("deg", 0, expected=("link_degradation",), detected=("link_degradation",),
              magnitude=0.5),
        _cell("deg", 1, expected=("link_degradation",), detected=(), magnitude=0.5),
        _cell("deg", 0, expected=("link_degradation",), detected=("link_degradation",),
              magnitude=1.0),
        _cell("deg", 1, expected=("link_degradation",), detected=("link_degradation",),
              magnitude=1.0),
        _cell("clean", 0, expected=(), detected=(), magnitude=0.5),  # no curve
    ]
    [curve] = sensitivity_curves(stats)
    assert curve.scenario == "deg" and curve.fault_class == "link_degradation"
    assert curve.points == [(0.0, 0.0), (0.5, 0.5), (1.0, 1.0)]
    assert curve.detection_threshold == 0.5
    assert "deg/link_degradation" in curve.report()
    d = curve.to_dict()
    assert d["detection_threshold"] == 0.5
    assert d["points"][0] == {"magnitude": 0.0, "detection_rate": 0.0}


def test_detection_threshold_none_when_never_fires():
    c = SensitivityCurve("s", "link_loss", points=[(0.0, 0.0), (1.0, 0.4)])
    assert c.detection_threshold is None
    assert "threshold -" in c.report()


@pytest.mark.slow
def test_magnitude_axis_sweep_end_to_end(tmp_path):
    """Live endpoints of a sensitivity curve: a zeroed fault diagnoses
    clean, full intensity diagnoses the published class, and the
    magnitude-1.0 shard is byte-identical to an axis-free run."""
    from repro.sim.sweep import SweepSpec, run_sweep

    spec = SweepSpec(scenarios=("degraded_ici_link",), seeds=(0,),
                     magnitudes=(0.0, 1.0))
    result = run_sweep(spec, str(tmp_path / "axis"), jobs=1)
    [curve] = sensitivity_curves(result.run_stats())
    assert dict(curve.points) == {0.0: 0.0, 1.0: 1.0}
    assert curve.detection_threshold == 1.0
    by_mag = {c.magnitude: c for c in result.cells}
    assert by_mag[0.0].stats.detected == ()
    assert "link_degradation" in by_mag[1.0].stats.detected
    # identity contract, measured at the shard level
    plain = run_sweep(
        SweepSpec(scenarios=("degraded_ici_link",), seeds=(0,)),
        str(tmp_path / "plain"), jobs=1,
    )
    import os

    with open(os.path.join(result.outdir, by_mag[1.0].shard), "rb") as f:
        scaled_bytes = f.read()
    with open(os.path.join(plain.outdir, plain.cells[0].shard), "rb") as f:
        plain_bytes = f.read()
    assert scaled_bytes == plain_bytes
