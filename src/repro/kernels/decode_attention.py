"""Flash-decode — Pallas TPU kernel for single-token attention over a long
KV cache.

One query row per (batch, head); the grid's last axis walks KV chunks
sequentially, carrying (m, l, acc) in VMEM scratch — the memory-bound
decode hot loop streams the cache HBM->VMEM exactly once.

grid = (B, H, S/Bs); q block (1,1,D) stays resident; k/v blocks (1,1,Bs,D).
``valid_len`` masks unwritten cache slots (SMEM scalar prefetch).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_kernel(
    valid_ref,                       # SMEM (1,) int32
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, bs: int, ns: int,
):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = valid_ref[0]
    k_lo = si * bs

    @pl.when(k_lo < valid)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (1, D) row
        k = k_ref[0, 0].astype(jnp.float32)                  # (Bs, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                     # (1, Bs)
        pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        mask = pos < valid
        s = jnp.where(mask, s, -jnp.inf)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe[:, None]), 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jax.Array,            # (B, H, D)
    k: jax.Array,            # (B, K, S, D)
    v: jax.Array,
    valid_len: jax.Array,    # scalar int32
    scale: Optional[float] = None,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    K, S = k.shape[1], k.shape[2]
    g = H // K
    scale = scale if scale is not None else D ** -0.5
    bs = min(block_s, S)
    assert S % bs == 0
    ns = S // bs
    q4 = q[:, :, None, :]    # (B, H, 1, D)

    kernel = functools.partial(_decode_kernel, scale=scale, bs=bs, ns=ns)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, ns),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, si, valid: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, si, valid: (b, h // g, si, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, si, valid: (b, h // g, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, si, valid: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(valid_len, jnp.int32).reshape(1), q4, k, v)
    return out[:, :, 0, :]
