"""AdamW from scratch (no optax) with fully-sharded optimizer state.

State pytrees mirror the parameter tree, so the same PartitionSpecs shard
them (ZeRO-style: with FSDP rules the m/v moments shard over data+model).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"     # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip(
            (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    return cfg.lr * warm * decay


def _has_low_precision(params: Any) -> bool:
    return any(
        jnp.dtype(getattr(l, "dtype", jnp.float32)) != jnp.float32
        for l in jax.tree_util.tree_leaves(params)
    )


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    out = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }
    if _has_low_precision(params):
        # bf16 params: f32 master copy lives (sharded) in the optimizer
        out["master"] = jax.tree_util.tree_map(
            lambda p: jnp.asarray(p, jnp.float32), params
        )
    return out


def abstract_opt_state(params: Any) -> Dict[str, Any]:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    out = {
        "m": jax.tree_util.tree_map(z, params),
        "v": jax.tree_util.tree_map(z, params),
    }
    if _has_low_precision(params):
        out["master"] = jax.tree_util.tree_map(z, params)
    return out


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    opt_state: Dict[str, Any],
    step: jax.Array,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        p32 = master if master is not None else p.astype(jnp.float32)
        p_new32 = p32 - lr * (update + cfg.weight_decay * p32)
        return p_new32.astype(p.dtype), m, v, p_new32

    has_master = "master" in opt_state
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_ma = (
        treedef.flatten_up_to(opt_state["master"]) if has_master else [None] * len(flat_p)
    )
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_opt = {
        "m": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
    }
    if has_master:
        new_opt["master"] = jax.tree_util.tree_unflatten(treedef, [o[3] for o in out])
    return new_p, new_opt, {"grad_norm": gnorm, "lr": lr}
