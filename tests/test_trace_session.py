"""Tests for the declarative TraceSpec / TraceSession API, the pluggable
SimulatorRegistry (custom simulator types without core edits), sharded
execution, streaming export, and the typed lifecycle exceptions."""
import json
import os
from collections import Counter
from typing import ClassVar

import pytest

from repro.core import (
    ChromeTraceExporter,
    ColumboScript,
    ContextRegistry,
    Event,
    ExecutionPolicy,
    Exporter,
    SessionNotRunError,
    SessionStateError,
    SimType,
    SimulatorRegistry,
    SourceSpec,
    SpanJSONLExporter,
    TraceSession,
    TraceSpec,
    TraceSpecError,
    UnknownSimTypeError,
    assemble_traces,
    register_simulator,
)
from repro.core.events import (
    DmaH2DComplete,
    DmaH2DIssue,
    HostStepBegin,
    HostStepEnd,
    register_event,
)
from repro.core.parsers import LogParser, _parse_kv
from repro.core.weaver import SpanWeaver
from repro.sim import run_training_sim, synthetic_program


# ---------------------------------------------------------------------------
# A complete fourth simulator type — defined here, outside repro.core, to
# prove the registry extension point (a storage simulator whose IO requests
# are caused by host-side DMA issues, the paper's "natural boundary" idea).
# ---------------------------------------------------------------------------

STORAGE = "storage"


@register_event
class StorageIoBegin(Event):
    sim_type: ClassVar[str] = STORAGE
    kind: ClassVar[str] = "io_begin"


@register_event
class StorageIoEnd(Event):
    sim_type: ClassVar[str] = STORAGE
    kind: ClassVar[str] = "io_end"


class StorageLogParser(LogParser):
    """``STOR <ts> <dev> <kind> k=v ...`` — yet another ad-hoc format."""

    sim_type = STORAGE

    def __call__(self, line):
        if not line.startswith("STOR "):
            return None
        parts = line.split()
        if len(parts) < 4:
            return None
        kind = parts[3]
        cls = {"io_begin": StorageIoBegin, "io_end": StorageIoEnd}.get(kind)
        if cls is None:
            return None
        return cls(ts=int(parts[1]), source=parts[2], attrs=_parse_kv(parts[4:]))


class StorageSpanWeaver(SpanWeaver):
    sim_type = STORAGE
    span_types = ("StorageIO",)

    def __init__(self, registry, poll_timeout: float = 0.0):
        super().__init__(registry, poll_timeout)
        self._open = {}

    def _on_io_begin(self, ev):
        from repro.core.span import new_trace_id

        b = self._begin("StorageIO", ev, new_trace_id(), None, dict(ev.attrs))
        # natural boundary: the host's DMA issue carries the same dma id
        if "dma" in ev.attrs:
            self._parent_or_defer(b, ("h2d", ev.attrs["dma"]))
        self._open[(ev.source, ev.attrs.get("io"))] = b

    def _on_io_end(self, ev):
        b = self._open.pop((ev.source, ev.attrs.get("io")), None)
        if b is not None:
            self.emit(b.finish(ev.ts))

    def on_finish(self):
        for b in self._open.values():
            b.span.attrs["unclosed"] = True
            self.emit(b.finish(b.span.start))
        self._open.clear()


def _storage_registry() -> SimulatorRegistry:
    """Session-local registry: the default three + the storage sim."""
    from repro.core import DEFAULT_REGISTRY

    reg = DEFAULT_REGISTRY.copy()
    reg.register(STORAGE, parser=StorageLogParser, weaver=StorageSpanWeaver,
                 sync_priority=30)
    return reg


HOST_EVENTS = [
    HostStepBegin(ts=0, source="host0", attrs={"step": 0}),
    DmaH2DIssue(ts=100, source="host0", attrs={"dma": "d1", "bytes": 4096}),
    DmaH2DComplete(ts=500, source="host0", attrs={"dma": "d1"}),
    HostStepEnd(ts=1000, source="host0", attrs={"step": 0}),
]

STORAGE_LOG = (
    "storage-sim boot: ignore this free-form banner\n"
    "STOR 150 ssd0 io_begin io=i1 dma=d1 bytes=4096\n"
    "STOR 400 ssd0 io_end io=i1\n"
)


# ---------------------------------------------------------------------------
# Custom simulator type end-to-end
# ---------------------------------------------------------------------------


def test_custom_sim_type_weaves_with_cross_weaver_context(tmp_path):
    log = tmp_path / "storage.log"
    log.write_text(STORAGE_LOG)

    session = TraceSession(simulators=_storage_registry())
    session.add_events(list(HOST_EVENTS), SimType.HOST)
    session.add_log(log, STORAGE)
    spans = session.run()

    io = [s for s in spans if s.name == "StorageIO"]
    h2d = [s for s in spans if s.name == "H2DTransfer"]
    assert len(io) == 1 and len(h2d) == 1
    # cross-weaver propagation resolved via the shared ContextRegistry:
    # the storage IO span parents under the host's H2DTransfer span
    assert io[0].parent is not None
    assert io[0].parent.span_id == h2d[0].context.span_id
    assert io[0].context.trace_id == h2d[0].context.trace_id
    assert session.finalize_stats["orphans"] == 0


def test_custom_sim_type_via_global_registration(tmp_path):
    """register_simulator on the process-wide default; clean up after."""
    from repro.core import DEFAULT_REGISTRY

    register_simulator(STORAGE, parser=StorageLogParser,
                       weaver=StorageSpanWeaver, sync_priority=30)
    try:
        log = tmp_path / "storage.log"
        log.write_text(STORAGE_LOG)
        spans = TraceSession().add_log(log, STORAGE).run()
        assert [s.name for s in spans] == ["StorageIO"]
        # parser_for resolves the custom type too
        from repro.core import parser_for

        assert parser_for(STORAGE).sim_type == STORAGE
    finally:
        DEFAULT_REGISTRY.unregister(STORAGE)


def test_custom_sim_type_in_declarative_spec(tmp_path):
    log = tmp_path / "storage.log"
    log.write_text(STORAGE_LOG)
    spec = TraceSpec.from_dict(
        {
            "sources": [
                {"sim_type": "host", "events": list(HOST_EVENTS)},
                {"sim_type": STORAGE, "path": str(log)},
            ],
        }
    )
    session = spec.run(simulators=_storage_registry())
    io = [s for s in session.spans if s.name == "StorageIO"]
    assert io and io[0].parent is not None


# ---------------------------------------------------------------------------
# Typed exceptions / lifecycle state machine
# ---------------------------------------------------------------------------


def test_spans_before_run_raises_typed_error():
    with pytest.raises(SessionNotRunError):
        TraceSession().spans


def test_unknown_sim_type_raises_typed_error():
    with pytest.raises(UnknownSimTypeError) as ei:
        TraceSession().add_events([], "dpu")
    assert isinstance(ei.value, KeyError)  # old except-KeyError guards survive
    assert "dpu" in str(ei.value)


def test_compose_after_run_raises_state_error():
    session = TraceSession()
    session.add_events(list(HOST_EVENTS), SimType.HOST)
    session.run()
    with pytest.raises(SessionStateError):
        session.add_events([], SimType.HOST)
    with pytest.raises(SessionStateError):
        session.run()


def test_failed_run_is_terminal_not_retryable(tmp_path):
    """A partial run leaves woven spans in the weavers; retrying on the
    same session would double-count them, so failure is terminal."""
    session = TraceSession()
    session.add_events(list(HOST_EVENTS), SimType.HOST)
    session.add_log(tmp_path / "missing.log", "host")
    with pytest.raises(FileNotFoundError):
        session.run()
    assert session.state == "failed"
    with pytest.raises(SessionStateError):
        session.run()


def test_source_spec_validates_exactly_one_input():
    with pytest.raises(TraceSpecError):
        SourceSpec(sim_type="host")
    with pytest.raises(TraceSpecError):
        SourceSpec(sim_type="host", path="a.log", events=[])
    with pytest.raises(TraceSpecError):
        ExecutionPolicy(mode="warp")


def test_columbo_script_shim_is_deprecated_but_works():
    with pytest.warns(DeprecationWarning):
        script = ColumboScript()
    p = script.add_events(list(HOST_EVENTS), SimType.HOST)
    assert p is script.pipelines[-1]  # historic contract: returns Pipeline
    with pytest.raises(SessionNotRunError):  # typed, not assert
        script.spans
    spans = script.run()
    assert any(s.name == "HostStep" for s in spans)
    assert script.spans is spans


# ---------------------------------------------------------------------------
# Sharded execution: N shards per sim type == single-log execution
# ---------------------------------------------------------------------------


def _shard_file(path: str, n: int, outdir: str):
    """Split a log into n contiguous shards (time order preserved)."""
    with open(path) as f:
        lines = f.readlines()
    per = (len(lines) + n - 1) // n
    out = []
    for i in range(n):
        sp = os.path.join(outdir, f"{os.path.basename(path)}.shard{i}")
        with open(sp, "w") as f:
            f.writelines(lines[i * per:(i + 1) * per])
        out.append(sp)
    return out


def test_sharded_execution_matches_single_log(tmp_path):
    prog = synthetic_program(n_layers=2, layer_flops=5e11, layer_bytes=2e8,
                             grad_bytes=1e8)
    cluster = run_training_sim(prog, n_steps=1, n_pods=2, chips_per_pod=4,
                               outdir=str(tmp_path / "logs"))
    paths = cluster.log_paths()

    base = TraceSession()
    for st_name, ps in paths.items():
        for p in ps:
            base.add_log(p, st_name)
    base_spans = base.run()

    sharded = TraceSession()
    shard_dir = str(tmp_path / "shards")
    os.makedirs(shard_dir)
    for st_name, ps in paths.items():
        for p in ps:
            sharded.add_shards(_shard_file(p, 4, shard_dir), st_name)
    sharded_spans = sharded.run()

    assert len(sharded_spans) == len(base_spans)
    assert Counter(s.name for s in sharded_spans) == Counter(
        s.name for s in base_spans
    )
    assert sharded.finalize_stats["orphans"] == 0
    # weaver fan-in: one weaver per source (4 shards -> 1), not per shard
    assert len(sharded.weavers) == len(base.weavers)
    # causality still resolves across the sharded boundary
    by_id = {s.context.span_id: s for s in sharded_spans}
    progs = [s for s in sharded_spans if s.name == "DeviceProgram"]
    assert progs and all(
        p.parent is not None and by_id[p.parent.span_id].name == "Dispatch"
        for p in progs
    )


# ---------------------------------------------------------------------------
# Streaming export
# ---------------------------------------------------------------------------


def test_attached_exporters_stream_during_run(tmp_path):
    jsonl = str(tmp_path / "spans.jsonl")
    chrome = str(tmp_path / "trace.chrome.json")
    je, ce = SpanJSONLExporter(jsonl), ChromeTraceExporter(chrome)
    session = (
        TraceSession()
        .add_events(list(HOST_EVENTS), SimType.HOST)
        .attach(je, ce)
    )
    spans = session.run()
    assert je.spans_written == len(spans) > 0
    recs = [json.loads(l) for l in open(jsonl)]
    assert {r["name"] for r in recs} == {s.name for s in spans}
    assert all(r["span_id"] for r in recs)
    data = json.load(open(chrome))
    assert any(e["ph"] == "X" for e in data["traceEvents"])


class _BoomExporter(Exporter):
    def begin(self):
        pass

    def consume(self, span):
        raise RuntimeError("boom")

    def finish(self):
        pass


def test_exporter_failure_does_not_starve_other_exporters(tmp_path):
    jsonl = str(tmp_path / "s.jsonl")
    je = SpanJSONLExporter(jsonl)
    session = (
        TraceSession()
        .add_events(list(HOST_EVENTS), SimType.HOST)
        .attach(_BoomExporter(), je)
    )
    with pytest.raises(RuntimeError, match="boom"):
        session.run()
    # the healthy exporter still flushed its complete output
    assert sum(1 for _ in open(jsonl)) == len(session.spans) > 0


def test_merged_host_streams_keep_per_host_dispatch_state(tmp_path):
    """Distinct hosts share chip ids after pod-stripping; one weaver over
    their merged streams must not cross open Dispatch spans (regression:
    _dispatch was keyed without the source host)."""
    prog = synthetic_program(n_layers=1, layer_flops=2e11, layer_bytes=1e8,
                             grad_bytes=5e7)
    cluster = run_training_sim(prog, n_steps=1, n_pods=2, chips_per_pod=2,
                               outdir=str(tmp_path))
    paths = cluster.log_paths()

    per_log = TraceSession()
    for st_name, ps in sorted(paths.items()):
        for p in ps:
            per_log.add_log(p, st_name)
    a = per_log.run()

    merged = TraceSession()
    for st_name, ps in sorted(paths.items()):
        merged.add_shards(ps, st_name)
    b = merged.run()

    assert Counter(s.name for s in b) == Counter(s.name for s in a)
    assert sorted(s.duration for s in b if s.name == "Dispatch") == sorted(
        s.duration for s in a if s.name == "Dispatch"
    )


def test_declarative_spec_matches_imperative(tmp_path):
    prog = synthetic_program(n_layers=1, layer_flops=2e11, layer_bytes=1e8,
                             grad_bytes=5e7)
    cluster = run_training_sim(prog, n_steps=1, n_pods=1, chips_per_pod=2,
                               outdir=str(tmp_path))
    paths = cluster.log_paths()

    imperative = TraceSession()
    for st_name, ps in sorted(paths.items()):
        for p in ps:
            imperative.add_log(p, st_name)
    spans_a = imperative.run()

    spec = TraceSpec(
        sources=[
            SourceSpec(sim_type=st_name, path=p)
            for st_name, ps in sorted(paths.items())
            for p in ps
        ],
        policy=ExecutionPolicy(mode="sync"),
    )
    spans_b = spec.run().spans
    assert Counter(s.name for s in spans_b) == Counter(s.name for s in spans_a)
    assert len(assemble_traces(spans_b)) == len(assemble_traces(spans_a))


def test_add_log_autodetects_tagged_sim_type(tmp_path):
    prog = synthetic_program(n_layers=1, layer_flops=2e11, layer_bytes=1e8,
                             grad_bytes=5e7)
    cluster = run_training_sim(prog, n_steps=1, n_pods=1, chips_per_pod=2,
                               outdir=str(tmp_path))
    session = TraceSession()
    for ps in cluster.log_paths().values():
        for p in ps:
            session.add_log(p)  # no sim_type: sniffed from the log tag
    spans = session.run()
    assert {s.sim_type for s in spans} == {"host", "device", "net"}
    assert session.finalize_stats["orphans"] == 0


def test_add_log_untagged_without_sim_type_raises(tmp_path):
    p = tmp_path / "mystery.log"
    p.write_text("no tag here\n")
    with pytest.raises(TraceSpecError):
        TraceSession().add_log(p)
