"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8)
per-expert d_ff=512, vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

vocab 49155 is not divisible by the 16-way model axis -> the embedding
falls back to replication (recorded by the sharding rules; see §Dry-run).
"""
from ..models.config import ModelConfig
from . import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        head_dim=64,
        n_experts=32,
        top_k=8,
        expert_d_ff=512,
        capacity_factor=1.25,
        mlp_act="swiglu",
        rope_theta=10_000.0,
    ),
    microbatches={"train_4k": 2},
)
