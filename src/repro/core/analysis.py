"""Trace analysis (Columbo §3.2 'Trace analysis', §5 case study figures).

Operates on finalized spans (weaver output).  Provides the analyses used by
the paper's evaluation plus the straggler/fault diagnostics the training
framework exposes as telemetry:

* per-component time breakdown of a trace (Fig. 6);
* clock-offset series from host clock_read events vs. the simulation's
  ground-truth global clock (Fig. 4) and NTP-estimated offsets (Fig. 5);
* critical path through a trace;
* straggler detection across per-chip/per-pod spans (k·MAD outliers).
"""
from __future__ import annotations

import statistics
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .span import Span, Trace, assemble_traces

PS_PER_US = 1_000_000


# ---------------------------------------------------------------------------
# Fig. 6 analogue: where did the time go, per component?
# ---------------------------------------------------------------------------


def component_breakdown(trace: Trace, leaf_only: bool = True) -> Dict[str, float]:
    """Map component -> µs of span time in this trace.

    With ``leaf_only`` (default), a span only contributes the part of its
    duration not covered by its children, so the breakdown sums to ~the
    trace's critical-path-ish total instead of double counting.
    """
    out: Dict[str, float] = defaultdict(float)
    children: Dict[int, List[Span]] = defaultdict(list)
    for s in trace.spans:
        if s.parent is not None:
            children[s.parent.span_id].append(s)
    for s in trace.spans:
        dur = s.duration
        if leaf_only and children.get(s.context.span_id):
            covered = _union_len(
                [(c.start, c.end) for c in children[s.context.span_id]], s.start, s.end
            )
            dur = max(0, dur - covered)
        out[f"{s.sim_type}:{s.component}"] += dur / PS_PER_US
    return dict(out)


def span_name_breakdown(trace: Trace) -> Dict[str, float]:
    out: Dict[str, float] = defaultdict(float)
    for s in trace.spans:
        out[s.name] += s.duration / PS_PER_US
    return dict(out)


def _union_len(ivals: List[Tuple[int, int]], lo: int, hi: int) -> int:
    ivals = sorted((max(a, lo), min(b, hi)) for a, b in ivals)
    total = 0
    cur_a, cur_b = None, None
    for a, b in ivals:
        if b <= a:
            continue
        if cur_b is None or a > cur_b:
            if cur_b is not None:
                total += cur_b - cur_a
            cur_a, cur_b = a, b
        else:
            cur_b = max(cur_b, b)
    if cur_b is not None:
        total += cur_b - cur_a
    return total


# ---------------------------------------------------------------------------
# Critical path
# ---------------------------------------------------------------------------


def critical_path(trace: Trace) -> List[Span]:
    """Longest chain of child spans ending at the latest-finishing leaf.

    Walks from each root to the descendant that determines its end time.
    """
    children: Dict[int, List[Span]] = defaultdict(list)
    for s in trace.spans:
        if s.parent is not None:
            children[s.parent.span_id].append(s)

    path: List[Span] = []
    roots = trace.roots()
    if not roots:
        return path
    cur: Optional[Span] = max(roots, key=lambda s: s.end)
    seen = set()
    while cur is not None and cur.context.span_id not in seen:
        seen.add(cur.context.span_id)
        path.append(cur)
        kids = children.get(cur.context.span_id, [])
        # the child on the critical path is the one finishing last
        cur = max(kids, key=lambda s: s.end) if kids else None
    return path


# ---------------------------------------------------------------------------
# Clock analysis (Fig. 4 / Fig. 5)
# ---------------------------------------------------------------------------


def clock_offset_series(spans: Iterable[Span], host_a: str, host_b: str) -> List[Tuple[float, float]]:
    """Measured host_a - host_b system-clock difference over global time.

    clock_read events carry ``local`` (the host's system clock, ps) and are
    timestamped with the simulation's ground-truth global clock; the sim's
    global clock plays the paper's "true and precise global clock" role.
    Returns [(global_time_us, offset_us)].
    """
    reads: Dict[str, List[Tuple[int, int]]] = {host_a: [], host_b: []}
    for s in spans:
        if s.sim_type != "host" or s.component not in reads:
            continue
        for ts, name, attrs in s.events:
            if name == "clock_read" and "local" in attrs:
                reads[s.component].append((ts, int(attrs["local"])))
    for v in reads.values():
        v.sort()
    out: List[Tuple[float, float]] = []
    bi = 0
    b = reads[host_b]
    for ts, local_a in reads[host_a]:
        # nearest host_b read at (or before) the same global instant
        while bi + 1 < len(b) and b[bi + 1][0] <= ts:
            bi += 1
        if not b:
            break
        ts_b, local_b = b[bi]
        # correct for the sampling-instant difference using the global clock
        offset = (local_a - ts) - (local_b - ts_b)
        out.append((ts / PS_PER_US, offset / PS_PER_US))
    return out


def ntp_estimated_offsets(spans: Iterable[Span], host: str) -> List[Tuple[float, float]]:
    """Chrony-style estimated offsets from NtpSync spans: ((t2-t1)+(t3-t4))/2."""
    out = []
    for s in spans:
        if s.name == "NtpSync" and s.component == host:
            a = s.attrs
            if all(k in a for k in ("t1", "t2", "t3", "t4")):
                off = ((a["t2"] - a["t1"]) + (a["t3"] - a["t4"])) / 2
                out.append((s.start / PS_PER_US, off / PS_PER_US))
    out.sort()
    return out


def ntp_path_asymmetry(spans: Iterable[Span], host: str) -> List[Tuple[float, float, float]]:
    """(t_us, req_us, resp_us) one-way delays per NTP exchange — the quantity
    whose asymmetry under background traffic explains Fig. 4/6."""
    out = []
    for s in spans:
        if s.name == "NtpSync" and s.component == host:
            a = s.attrs
            if all(k in a for k in ("t1", "t2", "t3", "t4", "true_off")):
                # with ground truth offset we can compute true one-way delays
                req = (a["t2"] - a["true_off"]) - a["t1"]
                resp = a["t4"] - (a["t3"] - a["true_off"])
                out.append((s.start / PS_PER_US, req / PS_PER_US, resp / PS_PER_US))
    out.sort()
    return out


# ---------------------------------------------------------------------------
# Straggler / fault diagnostics (framework telemetry on top of Columbo)
# ---------------------------------------------------------------------------


def straggler_report(
    spans: Iterable[Span],
    span_name: str = "DeviceProgram",
    k: float = 4.0,
) -> Dict[str, Any]:
    """Flag components whose span durations are > median + k * MAD."""
    durs: Dict[str, List[int]] = defaultdict(list)
    for s in spans:
        if s.name == span_name:
            durs[s.component].append(s.duration)
    if not durs:
        return {"stragglers": [], "median_us": 0.0, "per_component_us": {}}
    per_comp = {c: statistics.median(v) / PS_PER_US for c, v in durs.items()}
    med = statistics.median(per_comp.values())
    mad = statistics.median(abs(v - med) for v in per_comp.values()) or max(med * 0.01, 1e-9)
    stragglers = sorted(
        (c for c, v in per_comp.items() if v > med + k * mad),
        key=lambda c: -per_comp[c],
    )
    return {"stragglers": stragglers, "median_us": med, "per_component_us": per_comp}


def trace_summary(spans: Sequence[Span]) -> Dict[str, Any]:
    traces = assemble_traces(spans)
    return {
        "n_spans": len(spans),
        "n_traces": len(traces),
        "span_types": sorted({s.name for s in spans}),
        "components": sorted({f"{s.sim_type}:{s.component}" for s in spans}),
        "linked_spans": sum(1 for s in spans if s.links),
        "parented_spans": sum(1 for s in spans if s.parent is not None),
    }
