"""Discrete-event kernel shared by every component simulator.

One :class:`EventKernel` drives the whole full-system simulation: a single
binary-heap event queue with deterministic tie-breaking by ``(time, seq)``,
where ``seq`` is the global scheduling order.  Two properties follow:

* **Determinism** — two events at the same virtual instant always execute
  in the order they were scheduled, so a seeded run replays the exact same
  event sequence and produces byte-identical simulator logs (and therefore
  byte-identical woven SpanJSONL).  Asserted in ``tests/test_sweep.py``
  against golden files recorded before the kernel rewrite.
* **Idle gaps cost zero work** — nothing "ticks".  The kernel jumps the
  virtual clock straight to the next scheduled event, so a 30-second idle
  window between NTP polls costs one heap pop, not 30e12 picosecond steps.

Component simulators register on the kernel (:meth:`EventKernel.register`)
and receive a :class:`SimPort` — a scheduling facade that attributes every
executed event to the owning simulator, giving per-component event
accounting for ``benchmarks/engine_bench.py`` without touching the hot
path's ordering.  Recurring behaviours (heartbeats, clock reads, NTP polls,
background traffic) use :meth:`SimPort.every`, a cancellable
:class:`PeriodicTask` that re-arms itself *after* each firing and schedules
no trailing no-op events.

Times are integer picoseconds throughout.
"""
from __future__ import annotations

import gc
import heapq
from typing import Callable, Dict, List, Optional, Tuple


class EventHandle:
    """A scheduled event; ``cancel()`` removes it lazily (the heap entry
    stays but is skipped on pop, preserving every other event's order)."""

    __slots__ = ("fn", "port", "cancelled")

    def __init__(self, fn: Callable[[], None], port: Optional["SimPort"]) -> None:
        self.fn = fn
        self.port = port
        self.cancelled = False

    def cancel(self) -> None:
        """Mark the event dead; the kernel skips it when popped."""
        self.cancelled = True


class PeriodicTask:
    """A recurring event: fires ``fn(i)`` every ``interval_ps``.

    Replaces the per-callsite hand-rolled reschedule chains (heartbeats,
    clock reads, NTP polls, bulk flows).  The next firing is armed *after*
    ``fn`` runs — the same scheduling order as the chains it replaced, so
    seeded runs stay byte-identical — and a finished or cancelled task
    leaves no pending heap entry behind.

    * ``n``        — stop after ``n`` firings (``None`` = unbounded).
    * ``stop_ps``  — do not fire at or after this virtual time.
    * ``cancel()`` — stop immediately, removing the pending event.
    """

    __slots__ = ("kernel", "interval_ps", "fn", "n", "stop_ps", "port", "fires", "_handle", "cancelled")

    def __init__(
        self,
        kernel: "EventKernel",
        interval_ps: int,
        fn: Callable[[int], None],
        n: Optional[int] = None,
        first_at: Optional[int] = None,
        stop_ps: Optional[int] = None,
        port: Optional["SimPort"] = None,
    ) -> None:
        self.kernel = kernel
        self.interval_ps = int(interval_ps)
        self.fn = fn
        self.n = n
        self.stop_ps = stop_ps
        self.port = port
        self.fires = 0
        self.cancelled = False
        start = kernel.now + self.interval_ps if first_at is None else int(first_at)
        self._handle: Optional[EventHandle] = kernel.at(start, self._fire, port=port)

    def _fire(self) -> None:
        if self.cancelled:
            return
        if self.stop_ps is not None and self.kernel.now >= self.stop_ps:
            self._handle = None
            return
        if self.n is not None and self.fires >= self.n:
            # n == 0 (or n shrunk under us): never run fn, never re-arm —
            # matching the pre-kernel chains, which checked i >= n first
            self._handle = None
            return
        i = self.fires
        self.fires += 1
        self.fn(i)
        if self.n is None or self.fires < self.n:
            self._handle = self.kernel.at(
                self.kernel.now + self.interval_ps, self._fire, port=self.port
            )
        else:
            self._handle = None

    def cancel(self) -> None:
        """Stop the task; its pending heap entry is skipped, not executed."""
        self.cancelled = True
        if self._handle is not None:
            self._handle.cancel()
            self._handle = None


class SimPort:
    """One simulator's scheduling interface onto the shared kernel.

    Everything scheduled through a port is attributed to the owning
    component in :meth:`EventKernel.stats` — the per-simulator event
    accounting ``benchmarks/engine_bench.py`` reports — while executing on
    the one global queue (so cross-simulator ordering is exact).
    """

    __slots__ = ("kernel", "name", "events_executed")

    def __init__(self, kernel: "EventKernel", name: str) -> None:
        self.kernel = kernel
        self.name = name
        self.events_executed = 0

    @property
    def now(self) -> int:
        """Current virtual time (ps) of the shared kernel."""
        return self.kernel.now

    def at(self, t: int, fn: Callable[[], None]) -> EventHandle:
        """Schedule ``fn`` at absolute virtual time ``t``."""
        return self.kernel.at(t, fn, port=self)

    def after(self, dt: int, fn: Callable[[], None]) -> EventHandle:
        """Schedule ``fn`` ``dt`` picoseconds from now."""
        return self.kernel.at(self.kernel.now + int(dt), fn, port=self)

    def call_after(self, dt: int, fn: Callable[[], None]) -> None:
        """Fire-and-forget :meth:`after` — no :class:`EventHandle`
        allocation (see :meth:`EventKernel.call_after`)."""
        self.kernel.call_after(dt, fn, port=self)

    def every(
        self,
        interval_ps: int,
        fn: Callable[[int], None],
        n: Optional[int] = None,
        first_at: Optional[int] = None,
        stop_ps: Optional[int] = None,
    ) -> PeriodicTask:
        """Start a :class:`PeriodicTask` attributed to this simulator."""
        return PeriodicTask(
            self.kernel, interval_ps, fn, n=n, first_at=first_at, stop_ps=stop_ps, port=self
        )


class EventKernel:
    """Binary-heap DES kernel with deterministic ``(time, seq)`` ordering.

    The single event queue all component simulators share; ``seq`` is the
    global scheduling order, so same-time events execute exactly in the
    order they were scheduled — the foundation of the repo's byte-identical
    reproducibility contract.
    """

    def __init__(self) -> None:
        self.now: int = 0
        # heap entries: (time, seq, fn, port, handle); ``handle`` is None
        # for fire-and-forget events (call_at), so the hot path allocates
        # nothing beyond the entry tuple itself.  seq is unique, so heap
        # comparisons never look past the first two fields.
        self._q: List[Tuple[int, int, Callable[[], None], Optional["SimPort"], Optional[EventHandle]]] = []
        self._seq = 0
        self.events_executed = 0
        self.events_cancelled = 0
        self.ports: Dict[str, SimPort] = {}

    # -- registration -----------------------------------------------------------

    def register(self, name: str) -> SimPort:
        """Register a component simulator; returns its :class:`SimPort`.

        Ports are idempotent per name (re-registering returns the same
        port), so helpers can look one up without threading it through."""
        port = self.ports.get(name)
        if port is None:
            port = SimPort(self, name)
            self.ports[name] = port
        return port

    # -- scheduling -------------------------------------------------------------

    def at(self, t: int, fn: Callable[[], None], port: Optional[SimPort] = None) -> EventHandle:
        """Schedule ``fn`` at absolute virtual time ``t`` (>= now)."""
        t = int(t)
        if t < self.now:
            raise ValueError(f"scheduling into the past: {t} < {self.now}")
        h = EventHandle(fn, port)
        heapq.heappush(self._q, (t, self._seq, fn, port, h))
        self._seq += 1
        return h

    def call_at(self, t: int, fn: Callable[[], None], port: Optional[SimPort] = None) -> None:
        """Fire-and-forget :meth:`at`: same ordering (same ``seq`` stream),
        but no :class:`EventHandle` is allocated, so the event cannot be
        cancelled.  This is the simulators' hot-path scheduler — chunk-hop
        and op-completion events are never cancelled individually."""
        t = int(t)
        if t < self.now:
            raise ValueError(f"scheduling into the past: {t} < {self.now}")
        heapq.heappush(self._q, (t, self._seq, fn, port, None))
        self._seq += 1

    def after(self, dt: int, fn: Callable[[], None], port: Optional[SimPort] = None) -> EventHandle:
        """Schedule ``fn`` ``dt`` picoseconds from now."""
        return self.at(self.now + int(dt), fn, port=port)

    def call_after(self, dt: int, fn: Callable[[], None], port: Optional[SimPort] = None) -> None:
        """Fire-and-forget :meth:`after`: same ordering, no
        :class:`EventHandle` allocation (see :meth:`call_at`).  Op-end and
        step-sequencing events fire exactly once and are never cancelled,
        so the handle per event was pure allocator traffic — visible on
        the inline-weave profile, where span assembly leaves the kernel
        loop as the dominant remaining cost."""
        t = self.now + int(dt)
        if t < self.now:
            raise ValueError(f"scheduling into the past: {t} < {self.now}")
        heapq.heappush(self._q, (t, self._seq, fn, port, None))
        self._seq += 1

    def every(
        self,
        interval_ps: int,
        fn: Callable[[int], None],
        n: Optional[int] = None,
        first_at: Optional[int] = None,
        stop_ps: Optional[int] = None,
        port: Optional[SimPort] = None,
    ) -> PeriodicTask:
        """Start a :class:`PeriodicTask` on the kernel's queue."""
        return PeriodicTask(self, interval_ps, fn, n=n, first_at=first_at, stop_ps=stop_ps, port=port)

    # -- execution --------------------------------------------------------------

    def run(
        self,
        until: Optional[int] = None,
        max_events: int = 100_000_000,
        gc_pause: bool = True,
    ) -> int:
        """Drain the queue (optionally only up to virtual time ``until``).

        Returns the number of events executed by this call.  Cancelled
        entries are skipped without advancing the clock or the counters
        other events observe.

        ``gc_pause`` (default) suspends the *cyclic* garbage collector for
        the duration of the drain: a simulation run allocates millions of
        short-lived tuples/records that refcounting alone reclaims, and
        generational scans over the growing event/log structures were
        measured costing >2x wall time at 256 pods without ever finding a
        cycle.  The collector is restored (never force-collected) on exit,
        including on exceptions.
        """
        q = self._q
        pop = heapq.heappop
        executed = 0
        paused = gc_pause and gc.isenabled()
        if paused:
            gc.disable()
        try:
            if until is None:
                # hot loop: no deadline check, no peek — straight pops
                while q and executed < max_events:
                    t, _seq, fn, port, h = pop(q)
                    if h is not None and h.cancelled:
                        self.events_cancelled += 1
                        continue
                    self.now = t
                    fn()
                    executed += 1
                    if port is not None:
                        port.events_executed += 1
            else:
                while q and executed < max_events:
                    entry = q[0]
                    if entry[0] > until:
                        break
                    pop(q)
                    h = entry[4]
                    if h is not None and h.cancelled:
                        self.events_cancelled += 1
                        continue
                    self.now = entry[0]
                    entry[2]()
                    executed += 1
                    port = entry[3]
                    if port is not None:
                        port.events_executed += 1
        finally:
            if paused:
                gc.enable()
            # events_executed is published once per run() (not per event):
            # the counter is read by stats/benchmarks after the run, never
            # by simulator callbacks mid-run
            self.events_executed += executed
        return executed

    def empty(self) -> bool:
        """True when no events (live or cancelled) remain queued."""
        return not self._q

    def queue_len(self) -> int:
        """Number of queued heap entries (including cancelled ones)."""
        return len(self._q)

    def stats(self) -> Dict[str, object]:
        """Execution counters: totals plus per-registered-simulator events."""
        return {
            "events_executed": self.events_executed,
            "events_cancelled": self.events_cancelled,
            "virtual_time_ps": self.now,
            "queued": len(self._q),
            "per_component": {
                name: p.events_executed for name, p in sorted(self.ports.items())
            },
        }


# Historic name: the seed repo called the kernel ``Sim`` (sim/clock.py).
# The alias keeps every existing ``Sim()`` call site working unchanged.
Sim = EventKernel
