"""Batched serving example: prefill + lockstep decode waves with greedy and
temperature sampling, EOS handling, and throughput stats.

    PYTHONPATH=src python examples/serve_batch.py --arch qwen3-8b
"""
import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.models import init_params, model_pspecs
    from repro.serving import Request, ServingEngine

    cfg = get_arch(args.arch).config.reduced()
    print(f"serving reduced {args.arch}: {cfg.n_params/1e6:.1f}M params "
          f"(same block structure as the full model)")
    params = init_params(jax.random.PRNGKey(0), model_pspecs(cfg))
    engine = ServingEngine(cfg, params, batch_size=args.batch,
                           max_seq=args.prompt_len + args.max_new)

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=0.0 if i % 2 == 0 else 0.8,
        )
        for i in range(args.requests)
    ]
    engine.serve(reqs)
    for i, r in enumerate(reqs[:4]):
        print(f"req{i} (T={r.temperature}): {r.output[:10].tolist()}...")
    s = engine.stats
    print(
        f"\n{s.requests} requests in {s.waves} waves | "
        f"prefill {s.prefill_tokens} tok + decode {s.decode_tokens} tok | "
        f"{s.tokens_per_s:,.0f} tok/s end-to-end"
    )


if __name__ == "__main__":
    main()
