"""Deprecated ``ColumboScript`` shim over :class:`~repro.core.session.TraceSession`.

The paper's Columbo Scripts (§4) are user-composed trace-creation programs:
small programs wiring simulator-specific pipelines (parser -> actors ->
SpanWeaver -> exporter) into one end-to-end trace.  That role is now played
by the declarative :class:`~repro.core.session.TraceSpec` and the fluent
:class:`~repro.core.session.TraceSession`, which add a pluggable
:class:`~repro.core.registry.SimulatorRegistry` (custom simulator types
without core edits), sharded log inputs, streaming export, and typed
lifecycle errors.

Migrating::

    # old                                    # new
    script = ColumboScript()                 session = TraceSession()
    script.add_log(p, SimType.HOST)          session.add_log(p, "host")
    spans = script.run()                     spans = session.run()
    script.export(JaegerJSONExporter(f))     session.export(JaegerJSONExporter(f))
    script.run(threaded=True)                session.run(mode="threaded")

or declaratively::

    session = TraceSpec.from_dict({
        "sources": [{"sim_type": "host", "path": p}],
        "exporters": [JaegerJSONExporter(f)],
    }).run()

``ColumboScript`` remains as a thin shim so existing scripts keep working
unmodified; it emits a :class:`DeprecationWarning` and preserves the two
behavioural quirks of the old class: ``add_*`` return the created
``Pipeline`` (not ``self``) and ``run`` takes ``threaded=`` (not ``mode=``).
State misuse now raises the same typed exceptions as ``TraceSession``
(``SessionNotRunError`` instead of the historic bare assert).
"""
from __future__ import annotations

import os
import warnings
from typing import Any, Iterable, List, Optional, Sequence, Union

from .events import Event
from .pipeline import Actor, Pipeline, Producer
from .session import TraceSession
from .span import Span
from .weaver import SpanWeaver


class ColumboScript(TraceSession):
    """Deprecated alias for :class:`TraceSession` with the historic calling
    conventions.  Prefer ``TraceSession`` / ``TraceSpec`` in new code."""

    def __init__(self, poll_timeout: float = 0.0) -> None:
        warnings.warn(
            "ColumboScript is deprecated; use repro.core.TraceSession "
            "(or a declarative repro.core.TraceSpec)",
            DeprecationWarning,
            stacklevel=2,
        )
        super().__init__(poll_timeout=poll_timeout)

    # Historic contract: add_* return the created Pipeline.

    def add_log(
        self,
        path: Union[str, os.PathLike],
        sim_type=None,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_kwargs: Any,
    ) -> Pipeline:
        super().add_log(path, sim_type, actors, weaver, **weaver_kwargs)
        return self.pipelines[-1]

    def add_events(
        self,
        events: Iterable[Event],
        sim_type,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_kwargs: Any,
    ) -> Pipeline:
        super().add_events(events, sim_type, actors, weaver, **weaver_kwargs)
        return self.pipelines[-1]

    def add_pipeline(
        self,
        producer: Producer,
        sim_type,
        actors: Sequence[Actor] = (),
        weaver: Optional[SpanWeaver] = None,
        **weaver_kwargs: Any,
    ) -> Pipeline:
        self._check_building("add_pipeline")
        return self.engine.add_pipeline(producer, sim_type, actors, weaver, **weaver_kwargs)

    def run(self, threaded: bool = False) -> List[Span]:
        return super().run(mode="threaded" if threaded else "sync")
