"""``evict_straggler``: re-home a straggler pod's work onto healthy pods."""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar, Tuple, TYPE_CHECKING

from ..faults import DEVICE_SLOWDOWN, STRAGGLER_POD
from ..mitigation import MitigationPolicy, register_mitigation

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..cluster import ClusterOrchestrator


@register_mitigation
@dataclass
class EvictStraggler(MitigationPolicy):
    """Straggler eviction: normalize the slow pod, spread its work.

    The trigger loop polls per-chip compute scales
    (:meth:`~repro.sim.devicesim.DeviceSim.scale_of`); when any chip's
    scale crosses ``threshold`` its pod is declared the straggler, its
    chips are rescaled back to 1.0 (the evicted replica's shard re-homed),
    and every healthy pod pays ``spread_factor`` on subsequent ops — the
    capacity cost of absorbing the extra work, recorded as the span's
    ``penalty``.

    This policy *masks* the slow-op signature the ``device_slowdown`` /
    ``straggler_pod`` diagnosis rules read, so ``ScenarioSpec.run`` refuses
    it as an override on scenarios expecting those classes
    (:class:`~repro.sim.mitigation.MitigationConflictError`).
    """

    mitigation_name: ClassVar[str] = "evict_straggler"
    masks: ClassVar[Tuple[str, ...]] = (DEVICE_SLOWDOWN, STRAGGLER_POD)

    #: compute-scale multiplier above which a chip marks its pod straggler
    threshold: float = 1.5
    #: post-eviction compute-scale multiplier on every healthy pod's chips
    spread_factor: float = 1.15

    def attach(self, cluster: "ClusterOrchestrator") -> None:
        """Watch per-chip compute scales; evict the worst straggler pod."""

        def _probe(i: int) -> bool:
            worst_pod, worst_chip, worst_scale = None, None, 0.0
            for pod in sorted(cluster.device_sims):
                dev = cluster.device_sims[pod]
                for chip in dev.chips:
                    s = dev.scale_of(chip)
                    if s > worst_scale:
                        worst_pod, worst_chip, worst_scale = pod, chip, s
            if worst_pod is None or worst_scale < self.threshold:
                return False
            self.log_trigger(
                cluster, pod=worst_pod, chip=worst_chip,
                scale=round(worst_scale, 4),
            )
            for pod in sorted(cluster.device_sims):
                dev = cluster.device_sims[pod]
                if pod == worst_pod:
                    for chip in dev.chips:
                        cur = dev.scale_of(chip)
                        if cur != 1.0:
                            dev.rescale(chip, 1.0 / cur)
                else:
                    for chip in dev.chips:
                        dev.rescale(chip, self.spread_factor)
            self.log_action(
                cluster, action="evict", target=f"pod{worst_pod}",
                penalty=round(self.spread_factor - 1.0, 4),
            )
            self.log_done(cluster, pod=worst_pod)
            return True

        self.watch(cluster, _probe)
