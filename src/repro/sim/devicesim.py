"""Accelerator-chip simulator (the gem5 role in the paper's testbed).

One DeviceSim instance simulates all chips of one pod (one "simulator
process" per pod, as SimBricks runs one gem5 per host) and writes a
gem5-flavoured log::

    <tick>: system.pod0.chip03: OpBegin: op=op12 name=layer3.fwdbwd flops=... step=2
    <tick>: system.pod0.chip03: CollectiveChunkTx: coll=ar.5 chunk=c42 ...

Chips execute a ProgramSpec op list serially under a roofline cost model
(compute time = max(flops/MXU, bytes/HBM) + fixed overhead).  Collectives
run as ring algorithms whose chunks travel through the interconnect
simulator — cross-simulator causality therefore flows through the same
natural boundaries as in a real system (and as in the paper): the
chip→interconnect chunk handoff, and the host→chip dispatch.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional, Tuple

from .clock import LogWriter
from .engine import SimPort
from .netsim import NetSim
from .topology import Topology
from .workload import OpSpec, ProgramSpec

_COLL_ROUND_FACTORS = {
    # kind -> (rounds(N), chunk_bytes(B, N))
    "all-reduce": (lambda n: 2 * (n - 1), lambda b, n: b / n),
    "reduce-scatter": (lambda n: n - 1, lambda b, n: b / n),
    "all-gather": (lambda n: n - 1, lambda b, n: b),
    "collective-permute": (lambda n: 1, lambda b, n: b),
}


class CollectiveInstance:
    """One in-flight collective over a ring group of chips."""

    _ids = itertools.count()

    def __init__(
        self,
        cluster: "ClusterLike",
        coll_id: str,
        kind: str,
        participants: List[str],
        op_bytes: float,
    ) -> None:
        self.cluster = cluster
        self.coll_id = coll_id
        self.kind = kind
        self.ring = participants
        self.n = len(participants)
        self.idx = {c: i for i, c in enumerate(participants)}
        self.op_bytes = op_bytes
        if kind == "all-to-all":
            self.rounds = self.n - 1
            self.chunk_bytes = max(1, int(op_bytes / max(self.n, 1)))
        else:
            rf, cf = _COLL_ROUND_FACTORS[kind]
            self.rounds = max(1, rf(self.n)) if self.n > 1 else 0
            self.chunk_bytes = max(1, int(cf(op_bytes, self.n)))
        self.arrived: Dict[str, bool] = {}
        self.sent: Dict[str, int] = {c: 0 for c in participants}
        self.recv: Dict[str, int] = {c: 0 for c in participants}
        self.resume: Dict[str, Callable[[], None]] = {}
        self.done: Dict[str, bool] = {c: False for c in participants}
        self._chunk_seq = itertools.count()

    # -- entry point from the device sim -------------------------------------------

    def arrive(self, chip: str, resume: Callable[[], None]) -> None:
        assert chip not in self.arrived, (
            f"{chip} arrived twice at collective {self.coll_id} — two program "
            f"ops rendezvoused on one instance (op name/kind collision?)"
        )
        self.arrived[chip] = True
        self.resume[chip] = resume
        if self.n <= 1 or self.rounds == 0:
            self._finish(chip)
            return
        if self.kind == "all-to-all":
            # direct sends to every peer (multi-hop routes model congestion)
            for j in range(1, self.n):
                dst = self.ring[(self.idx[chip] + j) % self.n]
                self._send(chip, dst, round_no=j - 1)
        else:
            self._pump(chip)
        # chunks may have been delivered before this chip reached the
        # collective (late arrival): re-check completion now
        if self.recv[chip] >= self.rounds and not self.done[chip]:
            self._finish(chip)

    # -- ring machinery --------------------------------------------------------------

    def _pump(self, chip: str) -> None:
        """Issue every currently-eligible ring send for ``chip``."""
        while (
            self.sent[chip] < self.rounds
            and self.sent[chip] <= self.recv[chip]
            and self.arrived.get(chip)
        ):
            r = self.sent[chip]
            self.sent[chip] += 1
            dst = self.ring[(self.idx[chip] + 1) % self.n]
            self._send(chip, dst, round_no=r)

    def _send(self, src: str, dst: str, round_no: int) -> None:
        # collective chunks are the fleet-scale hot path (one per ring
        # round per participant): emit the record tuple directly instead
        # of going through log_event's kwargs marshalling
        cid = f"{self.coll_id}.k{next(self._chunk_seq)}"
        dev = self.cluster.device_sim_for(src)
        dev._emit((
            dev._kernel.now, src, "CollectiveChunkTx",
            {"coll": self.coll_id, "chunk": cid, "dst": dst, "round": round_no,
             "size": self.chunk_bytes},
        ))
        self.cluster.net.transfer(
            src,
            dst,
            self.chunk_bytes,
            meta={"coll": self.coll_id, "round": round_no, "src": src, "dst": dst},
            on_delivered=lambda t, d=dst, r=round_no, c=cid: self._on_recv(d, r, c),
            chunk_id=cid,
        )

    def _on_recv(self, chip: str, round_no: int, cid: str) -> None:
        self.recv[chip] += 1
        dev = self.cluster.device_sim_for(chip)
        dev._emit((
            dev._kernel.now, chip, "CollectiveChunkRx",
            {"coll": self.coll_id, "chunk": cid, "round": round_no,
             "size": self.chunk_bytes},
        ))
        if self.recv[chip] >= self.rounds:
            if self.arrived.get(chip) and not self.done[chip]:
                self._finish(chip)
        elif self.kind != "all-to-all":
            self._pump(chip)

    def _finish(self, chip: str) -> None:
        self.done[chip] = True
        cb = self.resume.pop(chip, None)
        if cb is not None:
            cb()

    def maybe_finish_late(self, chip: str, resume: Callable[[], None]) -> bool:
        """For async waits: True if already complete for ``chip`` (without
        registering or invoking ``resume``); otherwise registers ``resume``."""
        if self.done.get(chip):
            return True
        if not self.arrived.get(chip):
            # async start happened earlier; arriving now
            self.arrived[chip] = True
            self._pump(chip)
            if self.recv[chip] >= self.rounds:
                self.done[chip] = True
                return True
        self.resume[chip] = resume
        return False


class ClusterLike:
    """Interface the collective engine needs from the cluster orchestrator."""

    net: NetSim

    def device_sim_for(self, chip: str) -> "DeviceSim":
        raise NotImplementedError

    def get_collective(self, chip: str, op: OpSpec, step: int) -> CollectiveInstance:
        raise NotImplementedError


class DeviceSim:
    """All chips of one pod; writes one gem5-flavoured log."""

    def __init__(
        self,
        sim: SimPort,
        cluster: ClusterLike,
        pod: int,
        chips: List[str],
        log: LogWriter,
        compute_scale: Optional[Dict[str, float]] = None,
    ) -> None:
        self.sim = sim
        self.cluster = cluster
        self.pod = pod
        self.chips = chips
        self.log = log
        # hot-path bindings (clock read + emit happen per logged event)
        self._kernel = sim.kernel
        self._emit = log.emit_device
        self.chip_spec = cluster.topo.chip  # type: ignore[attr-defined]
        self.compute_scale = compute_scale or {}
        self._async: Dict[Tuple[str, str, int], CollectiveInstance] = {}
        self.ops_executed = 0

    # -- logging (gem5 flavour) -------------------------------------------------------

    def log_event(self, chip: str, ev_name: str, **attrs) -> None:
        # the sink owns the format: text (gem5 flavour) on the compatibility
        # path, a zero-format record capture on the structured fast path
        self._emit((self._kernel.now, chip, ev_name, attrs))

    # -- mitigation hooks (driven by sim/mitigation.py) ---------------------------------

    def scale_of(self, chip: str) -> float:
        """Current compute-time multiplier of one chip (1.0 = healthy) —
        the straggler telemetry mitigation trigger loops poll."""
        return self.compute_scale.get(chip, 1.0)

    def rescale(self, chip: str, factor: float) -> None:
        """Multiply one chip's compute-time scale (``evict_straggler``
        hook: re-homing work shows up as scale changes), effective for ops
        that begin after ``sim.now``."""
        self.compute_scale[chip] = self.compute_scale.get(chip, 1.0) * factor

    # -- program execution --------------------------------------------------------------

    def run_program(
        self,
        chip: str,
        program: ProgramSpec,
        step: int,
        on_done: Callable[[int], None],
    ) -> None:
        self.log_event(chip, "ProgramStart", program=program.name, step=step)
        self._exec(chip, program, step, 0, on_done)

    def _exec(
        self,
        chip: str,
        program: ProgramSpec,
        step: int,
        idx: int,
        on_done: Callable[[int], None],
    ) -> None:
        if idx >= len(program.ops):
            self.log_event(chip, "ProgramEnd", program=program.name, step=step)
            on_done(self.sim.now)
            return
        op = program.ops[idx]
        nxt = lambda: self._exec(chip, program, step, idx + 1, on_done)
        if op.kind == "compute":
            self._exec_compute(chip, op, idx, step, nxt)
        elif op.kind == "wait":
            inst = self._async.pop((chip, op.wait_for or "", step), None)
            if inst is None:
                nxt()
            else:

                def _done_wait(inst=inst) -> None:
                    self.log_event(chip, "CollectiveEnd", coll=inst.coll_id, step=step)
                    nxt()

                if inst.maybe_finish_late(chip, _done_wait):
                    _done_wait()
        else:
            self._exec_collective(chip, op, step, nxt)

    def _exec_compute(
        self, chip: str, op: OpSpec, idx: int, step: int, nxt: Callable[[], None]
    ) -> None:
        c = self.chip_spec
        scale = self.compute_scale.get(chip, 1.0)
        t_flops = op.flops / c.flops_per_ps if op.flops else 0.0
        t_bytes = op.bytes / c.hbm_bytes_per_ps if op.bytes else 0.0
        dur = int(max(t_flops, t_bytes) * scale) + c.op_overhead_ps
        self.log_event(
            chip, "OpBegin", op=f"op{idx}", name=op.name, flops=int(op.flops),
            bytes=int(op.bytes), step=step,
        )
        if t_flops >= t_bytes and op.flops:
            self.log_event(chip, "MxuIssue", op=f"op{idx}", busy_ps=int(t_flops * scale))
        if op.bytes:
            self.log_event(chip, "HbmRead", op=f"op{idx}", bytes=int(op.bytes * 0.6))
            self.log_event(chip, "HbmWrite", op=f"op{idx}", bytes=int(op.bytes * 0.4))
        self.ops_executed += 1

        def _end() -> None:
            self.log_event(chip, "OpEnd", op=f"op{idx}", name=op.name, step=step)
            nxt()

        self.sim.call_after(dur, _end)

    def _exec_collective(
        self, chip: str, op: OpSpec, step: int, nxt: Callable[[], None]
    ) -> None:
        inst = self.cluster.get_collective(chip, op, step)
        self.log_event(
            chip, "CollectiveStart", coll=inst.coll_id, kind=op.kind,
            bytes=int(op.coll_bytes), step=step, ring=inst.n,
        )
        if op.async_start:
            self._async[(chip, op.name, step)] = inst
            inst.arrive(chip, lambda: None)
            nxt()
            return

        def _done() -> None:
            self.log_event(chip, "CollectiveEnd", coll=inst.coll_id, step=step)
            nxt()

        inst.arrive(chip, _done)

    # -- DMA landing (PCIe natural boundary, device side) --------------------------------

    def dma_landed(self, chip: str, dma_id: str, nbytes: int) -> None:
        self.log_event(chip, "DmaRecv", dma=dma_id, bytes=nbytes)
