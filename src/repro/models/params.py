"""Parameter declaration: one structure drives init, sharding, and shapes.

A model declares its parameters as a pytree of :class:`PSpec` (shape +
logical axes + initializer).  From that single tree we derive:

* ``init_params``      — materialized arrays (PRNG-split deterministically)
* ``abstract_params``  — ShapeDtypeStructs (dry-run: no allocation)
* ``partition_specs``  — jax.sharding.PartitionSpec tree via logical rules
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis per dim (None = never sharded)
    init: str = "normal"                 # normal | zeros | ones | scaled | lecun
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pspec(x: Any) -> bool:
    return isinstance(x, PSpec)


def _init_leaf(key: jax.Array, p: PSpec) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "normal":
        return (p.scale * jax.random.normal(key, p.shape)).astype(p.dtype)
    if p.init in ("scaled", "lecun"):
        fan_in = p.shape[0] if len(p.shape) >= 2 else max(np.prod(p.shape), 1)
        std = p.scale / np.sqrt(fan_in)
        return (std * jax.random.normal(key, p.shape)).astype(p.dtype)
    raise ValueError(p.init)


def init_params(rng: jax.Array, tree: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_pspec)
    keys = jax.random.split(rng, len(leaves))
    out = [_init_leaf(k, p) for k, p in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree, is_leaf=is_pspec
    )


class Rules:
    """logical axis -> mesh axes, with divisibility-aware fallback.

    ``rules`` maps a logical axis name to a mesh axis (or tuple of axes).
    When a parameter dimension is not divisible by the mesh axes' total
    size, the dimension falls back to replication (recorded in
    ``fallbacks`` so EXPERIMENTS can report them).
    """

    def __init__(self, rules: Dict[str, Any], mesh_axis_sizes: Dict[str, int]):
        self.rules = dict(rules)
        self.sizes = dict(mesh_axis_sizes)
        self.fallbacks: Dict[Tuple[str, int], str] = {}

    def mesh_axes_for(self, logical: Optional[str], dim: int) -> Optional[Any]:
        if logical is None:
            return None
        ax = self.rules.get(logical)
        if ax is None:
            return None
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axes:
            total *= self.sizes.get(a, 1)
        if total <= 1:
            return None
        if dim % total != 0:
            self.fallbacks[(logical, dim)] = f"{dim} % {total} != 0"
            return None
        return ax

    def pspec(self, p: PSpec) -> P:
        return P(*[self.mesh_axes_for(a, d) for a, d in zip(p.axes, p.shape)])

    def act(self, *logical: Optional[str]) -> P:
        """PartitionSpec for an activation with the given logical axes.
        (No divisibility check: activation dims are chosen shardable.)"""
        out = []
        for l in logical:
            out.append(self.rules.get(l) if l is not None else None)
        return P(*out)


def partition_specs(tree: Any, rules: Rules) -> Any:
    return jax.tree_util.tree_map(lambda p: rules.pspec(p), tree, is_leaf=is_pspec)


def count_params(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_pspec)
    total = 0
    for l in leaves:
        shape = l.shape if hasattr(l, "shape") else ()
        n = 1
        for d in shape:
            n *= d
        total += n
    return total
