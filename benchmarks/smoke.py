"""Smoke target: the smallest end-to-end proof that the tracing stack is
alive — simulate a tiny 1-pod training step, weave it through a declarative
TraceSpec (sharded device input + streaming JSONL export), and check the
invariants CI cares about.  Runs in a few seconds; invoked as

    PYTHONPATH=src python -m benchmarks.run smoke

and by scripts/tier1.sh as the builder/CI pre-flight.
"""
import os
import tempfile
import time


def run():
    from repro.core import SourceSpec, SpanJSONLExporter, TraceSpec
    from repro.sim import run_training_sim, synthetic_program

    t0 = time.perf_counter()
    prog = synthetic_program(n_layers=1, layer_flops=2e11, layer_bytes=1e8, grad_bytes=5e7)
    with tempfile.TemporaryDirectory() as d:
        cl = run_training_sim(prog, n_steps=1, n_pods=1, chips_per_pod=2, outdir=d)
        jsonl = os.path.join(d, "spans.jsonl")
        exporter = SpanJSONLExporter(jsonl)
        spec = TraceSpec(
            sources=[
                SourceSpec(sim_type=st, path=p)
                for st, paths in sorted(cl.log_paths().items())
                for p in paths
            ],
            exporters=[exporter],
        )
        session = spec.run()
        spans = session.spans
        n_lines = sum(1 for _ in open(jsonl))
        dt = time.perf_counter() - t0
        ok = (
            len(spans) > 10
            and session.finalize_stats["orphans"] == 0
            and n_lines == len(spans)
            and any(s.name == "HostStep" for s in spans)
        )
        if not ok:
            raise RuntimeError(
                f"smoke invariants failed: spans={len(spans)} "
                f"orphans={session.finalize_stats.get('orphans')} jsonl={n_lines}"
            )
    return [
        ("smoke.e2e_trace", dt * 1e6,
         f"spans={len(spans)} orphans=0 jsonl_lines={n_lines} OK"),
    ]
