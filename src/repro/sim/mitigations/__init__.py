"""Built-in mitigation policies (importing this package registers them).

Each module defines one :class:`~repro.sim.mitigation.MitigationPolicy`
subclass and registers it under its ``mitigation_name`` — the same layout
as ``sim/workloads/`` for workload drivers:

* ``retransmit`` (:mod:`.retransmit`) — fast-retransmit dropped chunks
  under a seeded timeout cap; each re-send is a ``Retransmit`` span.
* ``disable_and_reroute`` (:mod:`.reroute`) — take the worst-dropping link
  out of the route tables (when an alternate path exists) and record the
  capacity penalty.
* ``evict_straggler`` (:mod:`.evict`) — re-home a straggler pod's work
  onto the healthy pods at a small spread cost.
* ``checkpoint_restore`` (:mod:`.restore`) — roll a stalled host back to
  its last checkpoint instead of riding out a long runtime pause.

(The ``do_nothing`` baseline lives in ``sim/mitigation.py`` itself, next to
the registry, because it *is* the contract: attach-is-a-no-op.)
"""
from .evict import EvictStraggler
from .reroute import DisableAndReroute
from .restore import CheckpointRestore
from .retransmit import Retransmit

__all__ = [
    "CheckpointRestore",
    "DisableAndReroute",
    "EvictStraggler",
    "Retransmit",
]
