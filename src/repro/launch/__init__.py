from .mesh import best_mesh_for, make_mesh, make_production_mesh
from .specs import Cell, build_cell
from .steps import make_step_fn

__all__ = [
    "Cell",
    "best_mesh_for",
    "build_cell",
    "make_mesh",
    "make_production_mesh",
    "make_step_fn",
]
