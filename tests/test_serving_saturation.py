"""The saturation-capable rpc serving engine (sim/workloads/rpc.py serving
mode + sim/workloads/lb.py): LB-policy registry semantics, the
any-seed request-conservation property (every rid terminates in exactly one
of completed / dropped / timed_out, exactly one root span per rid, zero
orphans), four-way weave byte-identity for the new drop/timeout/retry/
lb-pick event kinds, the zero-completed-requests analysis regression, and
the request-outcome accounting surfaced by ``core.analysis``.
"""
import random
import re

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.analysis import (
    RunStats,
    completed_requests,
    percentile,
    request_latency_stats,
    request_outcomes,
    request_report,
    rpc_requests,
    score_mitigations,
)
from repro.sim import (
    LbPolicy,
    RpcServing,
    ScenarioSpec,
    lb_policy_type,
    list_lb_policies,
    make_lb_policy,
    make_workload,
    register_lb_policy,
    rpc_handler_program,
)
from repro.sim.cluster import ClusterOrchestrator
from repro.sim.topology import scale
from repro.sim.workloads.lb import (
    LeastLoaded,
    PowerOfTwoChoices,
    RoundRobin,
    backend_load,
)

TERMINAL_OUTCOMES = {"completed", "dropped", "timed_out"}


def _serving_spec(name="serving_prop", **params):
    """An ad-hoc rpc serving scenario on a tiny fault-free testbed."""
    defaults = dict(n_requests=8, arrival="open", rate_rps=2e6,
                    lb="least_loaded", queue_depth=2,
                    timeout_ps=5_000_000_000, max_retries=2)
    defaults.update(params)
    return ScenarioSpec(
        name=name,
        description="rpc saturation probe",
        workload="rpc",
        workload_params=tuple(defaults.items()),
        program=rpc_handler_program,
        n_pods=2,
        chips_per_pod=2,
        clock_reads=2,
    )


def _rids_in_logs(cluster) -> set:
    """Request ids appearing anywhere in the simulator logs (same probe as
    tests/test_workloads.py, local so the modules stay independent)."""
    rids = set()
    pat = re.compile(r"\brid=(\S+)")
    for lw in cluster._logs:
        if lw.structured:
            lines = lw.render_lines()
        elif lw.path is not None:
            with open(lw.path) as f:
                lines = f.read().splitlines()
        else:
            lines = lw.lines
        for line in lines:
            rids.update(pat.findall(line))
    return rids


# ---------------------------------------------------------------------------
# LB policy registry semantics (mirrors the workload/mitigation registries)
# ---------------------------------------------------------------------------


def test_builtin_lb_policies_registered():
    assert set(list_lb_policies()) >= {
        "round_robin", "least_loaded", "power_of_two_choices"
    }
    assert lb_policy_type("round_robin") is RoundRobin
    assert lb_policy_type("least_loaded") is LeastLoaded
    assert lb_policy_type("power_of_two_choices") is PowerOfTwoChoices


def test_lb_policy_type_unknown_name():
    with pytest.raises(KeyError, match="unknown lb policy"):
        lb_policy_type("random_choice")


def test_register_lb_policy_rejects_duplicates_and_anonymous():
    with pytest.raises(ValueError, match="already registered"):
        register_lb_policy(RoundRobin)

    class NoName(LbPolicy):
        pass

    with pytest.raises(ValueError, match="lb_name"):
        register_lb_policy(NoName)


def test_make_lb_policy_unknown_knob_raises_typeerror():
    with pytest.raises(TypeError, match="least_loaded"):
        make_lb_policy("least_loaded", cursor=3)


class _FakeServer:
    """Just enough surface for backend_load(): a queue and a busy flag."""

    def __init__(self, queued: int, busy: bool = False):
        self.queue = [None] * queued
        self.busy = busy


def test_backend_load_counts_queue_plus_in_service():
    assert backend_load(_FakeServer(0)) == 0
    assert backend_load(_FakeServer(3)) == 3
    assert backend_load(_FakeServer(3, busy=True)) == 4


def test_round_robin_cycles_in_pod_order():
    servers = [_FakeServer(0) for _ in range(3)]
    rr = make_lb_policy("round_robin")
    rng = random.Random(0)
    picks = [rr.pick(servers, rng) for _ in range(6)]
    assert picks == servers + servers


def test_least_loaded_breaks_ties_to_first():
    a, b, c = _FakeServer(2), _FakeServer(1), _FakeServer(1)
    assert make_lb_policy("least_loaded").pick([a, b, c], random.Random(0)) is b
    assert make_lb_policy("least_loaded").pick([b, a, c], random.Random(0)) is b


def test_power_of_two_choices_keeps_less_loaded_and_is_seeded():
    servers = [_FakeServer(i) for i in range(8)]
    p2c = make_lb_policy("power_of_two_choices")
    picks_a = [p2c.pick(servers, random.Random(7)) for _ in range(1)]
    picks_b = [make_lb_policy("power_of_two_choices")
               .pick(servers, random.Random(7)) for _ in range(1)]
    assert picks_a == picks_b            # only randomness is the passed rng
    rng = random.Random(3)
    for _ in range(50):
        i, j = random.Random(3).sample(range(8), 2)  # peek the next draw
        assert p2c.pick(servers, rng) is (
            servers[i] if backend_load(servers[i]) <= backend_load(servers[j])
            else servers[j]
        )
        rng = random.Random(3)           # re-seed so the peek stays aligned


def test_power_of_two_choices_single_server_shortcut():
    only = _FakeServer(5)
    assert make_lb_policy("power_of_two_choices").pick(
        [only], random.Random(0)) is only


# ---------------------------------------------------------------------------
# Serving-mode knob validation (no silent ignores, same as make_workload)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kwargs,match", [
    (dict(queue_depth=0), "queue_depth"),
    (dict(timeout_ps=0), "timeout_ps"),
    (dict(timeout_ps=-5), "timeout_ps"),
    (dict(max_retries=-1), "max_retries"),
    (dict(retry_backoff_ps=-1), "retry_backoff_ps"),
])
def test_rpc_serving_knob_validation(kwargs, match):
    with pytest.raises(ValueError, match=match):
        RpcServing(**kwargs)


def test_rpc_unknown_lb_policy_raises_keyerror():
    with pytest.raises(KeyError, match="unknown lb policy"):
        RpcServing(lb="sticky_sessions")


def test_serving_mode_switches_and_defaults_lb():
    assert RpcServing().serving_mode is False
    assert RpcServing(lb="round_robin").serving_mode is True
    # queue_depth/timeout alone imply serving mode with the default policy
    assert RpcServing(queue_depth=2).lb == "round_robin"
    assert RpcServing(timeout_ps=1_000).lb == "round_robin"
    wl = RpcServing(n_requests=4, lb="least_loaded", queue_depth=3,
                    timeout_ps=2_000_000)
    assert "lb=least_loaded" in wl.describe() and "q=3" in wl.describe()


# ---------------------------------------------------------------------------
# The conservation property: any seed x rate x policy x queue bound
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    rate=st.sampled_from([500.0, 50_000.0, 2e6]),
    lb=st.sampled_from(["round_robin", "least_loaded",
                        "power_of_two_choices"]),
    queue_depth=st.sampled_from([None, 1, 4]),
)
@settings(max_examples=6, deadline=None)
def test_serving_conservation_property_any_seed(seed, rate, lb, queue_depth):
    """Property: for any seed, arrival rate, LB policy and queue bound,
    every issued rid terminates in exactly one of {completed, dropped,
    timed_out}, weaves into exactly one parentless RpcRequest root, and
    no span in the trace is an orphan."""
    spec = _serving_spec(n_requests=6, rate_rps=rate, lb=lb,
                         queue_depth=queue_depth,
                         timeout_ps=5_000_000_000, max_retries=1)
    run = spec.run(seed=seed, structured=True)
    roots = [s for s in run.spans if s.name == "RpcRequest"]
    assert len(roots) == 6 and all(s.parent is None for s in roots)
    rids = [s.attrs.get("rid") for s in roots]
    assert len(set(rids)) == 6
    assert set(rids) == _rids_in_logs(run.cluster)
    # exactly one terminal outcome per rid
    for s in roots:
        assert s.attrs.get("outcome") in TERMINAL_OUTCOMES, (
            f"rid={s.attrs.get('rid')} has no terminal outcome"
        )
    out = request_outcomes(run.spans)
    assert out["issued"] == 6
    assert out["completed"] + out["dropped"] + out["timed_out"] == 6
    if queue_depth is None:
        assert out["dropped"] == 0     # nothing to drop without a bound
    # zero orphans: every parented span resolves inside its own trace
    ids = {s.context.span_id for s in run.spans}
    for s in run.spans:
        if s.parent is not None:
            assert s.parent.span_id in ids, f"orphan span {s.name}"


def test_every_rid_has_exactly_one_rpc_done(tmp_path):
    """The conservation invariant at the log level: exactly one rpc_done
    line per rid, carrying outcome= and attempts=."""
    run = _serving_spec(n_requests=10, queue_depth=1).run(
        outdir=str(tmp_path / "logs"), seed=1
    )
    done = {}
    pat = re.compile(r"rpc_done rid=(\S+).*attempts=(\d+) outcome=(\w+)")
    for lw in run.cluster._logs:
        lines = (lw.render_lines() if lw.structured
                 else open(lw.path).read().splitlines() if lw.path
                 else lw.lines)
        for line in lines:
            m = pat.search(line)
            if m:
                assert m.group(1) not in done, f"duplicate rpc_done {m.group(1)}"
                done[m.group(1)] = (int(m.group(2)), m.group(3))
    assert len(done) == 10
    assert all(o in TERMINAL_OUTCOMES and a >= 1 for a, o in done.values())


def test_outcome_accounting_matches_span_accounting():
    """The workload's in-flight counters agree with the span-level
    accounting, and the open-loop saturation regime drives concurrency."""
    wl = make_workload(
        "rpc", program=rpc_handler_program(), clock_reads=2, seed=0,
        n_requests=30, arrival="open", rate_rps=2e6,
        lb="power_of_two_choices", queue_depth=1,
        timeout_ps=5_000_000_000, max_retries=1,
    )
    cluster = ClusterOrchestrator(scale(pods=4, chips_per_pod=2))
    wl.drive(cluster)
    cluster.run()
    out = wl.outcomes
    assert out["issued"] == 30
    assert out["completed"] + out["dropped"] + out["timed_out"] == 30
    assert out["finalized"] == 30 and out["in_flight"] == 0
    assert len(out["lat_ps"]) == out["completed"]
    # open-loop at 2M rps vs ~ms service: requests pile up concurrently
    assert out["max_in_flight"] > 1
    assert out["dropped"] > 0          # queue_depth=1 under that load drops


def test_closed_loop_serving_conserves_and_bounds_concurrency():
    wl = make_workload(
        "rpc", program=rpc_handler_program(), clock_reads=2, seed=0,
        n_requests=12, arrival="closed", concurrency=3, lb="round_robin",
        queue_depth=2, max_retries=1,
    )
    cluster = ClusterOrchestrator(scale(pods=2, chips_per_pod=2))
    wl.drive(cluster)
    cluster.run()
    out = wl.outcomes
    assert out["issued"] == 12
    assert out["completed"] + out["dropped"] + out["timed_out"] == 12
    assert out["max_in_flight"] <= 3   # the closed loop's concurrency cap


# ---------------------------------------------------------------------------
# Four-way weave byte-identity for the new event kinds
# ---------------------------------------------------------------------------


def test_saturated_weave_four_way_identity():
    """text == structured == inline == columnar on a saturated run that
    exercises every new event kind (lb picks, queue drops, timeouts,
    retries)."""
    spec = _serving_spec(n_requests=20, rate_rps=2e6, queue_depth=1,
                         timeout_ps=4_000_000_000, max_retries=2)
    text = spec.run(seed=0).span_jsonl
    structured = spec.run(seed=0, structured=True).span_jsonl
    inline = spec.run(seed=0, weave="inline").span_jsonl
    columnar = spec.run(seed=0, weave="columnar").span_jsonl
    assert text == structured == inline == columnar
    # the run actually exercised the new machinery
    assert '"RpcDrop"' in text, "saturated run wove no queue-drop spans"
    assert '"RpcRetry"' in text, "saturated run wove no retry spans"
    run = spec.run(seed=0, structured=True)
    roots_ev = [e for s in rpc_requests(run.spans) for e in s.events]
    assert any("rpc_lb_pick" in str(e) for e in roots_ev), (
        "roots carry no lb-pick span events"
    )
    assert any(s.attrs.get("lb") == "least_loaded"
               for s in rpc_requests(run.spans))
    # retry spans parent under the original request's trace
    roots = {s.context.trace_id: s for s in rpc_requests(run.spans)}
    retries = [s for s in run.spans if s.name == "RpcRetry"]
    assert retries and all(s.context.trace_id in roots for s in retries)


def test_timeout_weave_four_way_identity():
    """Deadline expiry (rpc_timeout closing the in-flight RpcCall) weaves
    byte-identically on all four paths."""
    spec = _serving_spec(name="serving_timeout", n_requests=6,
                         queue_depth=None, timeout_ps=1_000_000,
                         max_retries=1)
    text = spec.run(seed=2).span_jsonl
    assert text == spec.run(seed=2, structured=True).span_jsonl
    assert text == spec.run(seed=2, weave="inline").span_jsonl
    assert text == spec.run(seed=2, weave="columnar").span_jsonl
    assert "rpc_timeout" in text or '"deadline"' in text


def test_saturated_sharded_export_jobs_invariant():
    spec = _serving_spec(n_requests=12, queue_depth=1)
    serial = spec.run(seed=3, weave="inline").span_jsonl
    for jobs in (1, 2, 4):
        sharded = spec.run(seed=3, weave="sharded", jobs=jobs).span_jsonl
        assert sharded == serial, f"jobs={jobs} diverged on a saturated run"


def test_serving_runs_reproduce_per_seed():
    spec = _serving_spec(n_requests=8, queue_depth=2)
    assert spec.run(seed=5).span_jsonl == spec.run(seed=5).span_jsonl
    assert spec.run(seed=5).span_jsonl != spec.run(seed=6).span_jsonl


# ---------------------------------------------------------------------------
# Outcome-aware analysis + the zero-completed-requests regression
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def saturated_run():
    return _serving_spec(n_requests=20, rate_rps=2e6, queue_depth=1,
                         timeout_ps=4_000_000_000, max_retries=2).run(
        seed=0, structured=True)


def test_request_outcomes_accounting(saturated_run):
    out = request_outcomes(saturated_run.spans)
    assert out["issued"] == 20
    assert out["completed"] + out["dropped"] + out["timed_out"] == 20
    assert out["dropped"] > 0
    assert out["attempts"] >= out["issued"]
    assert out["retried"] > 0
    assert out["goodput"] == pytest.approx(out["completed"] / 20)
    assert set(out["latency_us"]) == {"least_loaded"}
    lt = out["latency_us"]["least_loaded"]
    assert lt["n"] == out["completed"]
    assert 0 < lt["p50"] <= lt["p99"] <= lt["p99.9"] <= lt["max"]


def test_request_latency_stats_counts_only_completed(saturated_run):
    stats = request_latency_stats(saturated_run.spans)
    out = request_outcomes(saturated_run.spans)
    assert stats["n"] == out["completed"] < out["issued"]
    assert stats["n"] == len(completed_requests(saturated_run.spans))
    assert {"p50", "p90", "p99", "p99.9", "max"} <= set(stats)


def test_request_report_prints_outcomes_and_policy_tail(saturated_run):
    report = request_report(saturated_run.spans)
    assert "outcomes:" in report and "goodput=" in report
    assert "lb=least_loaded" in report and "p99.9=" in report
    assert "slowest request" in report


def test_queue_bound_inflates_tail_latency():
    """The tier-1 smoke gate's ordering, as a unit test: an unbounded
    saturated queue shows a fatter p99.9 than a healthy arrival rate."""
    healthy = _serving_spec(name="svc_healthy", n_requests=12, rate_rps=200.0,
                            queue_depth=None, timeout_ps=None,
                            max_retries=0).run(seed=0, structured=True)
    slammed = _serving_spec(name="svc_slammed", n_requests=12, rate_rps=2e6,
                            queue_depth=None, timeout_ps=None,
                            max_retries=0).run(seed=0, structured=True)
    h = request_latency_stats(healthy.spans)
    s = request_latency_stats(slammed.spans)
    assert h["n"] == s["n"] == 12       # unbounded: everything completes
    assert s["p99.9"] > h["p99.9"]


def test_zero_completed_requests_analysis_is_well_formed():
    """Regression: a run where every request times out (or drops) must
    yield zeroed latency stats and a readable report, not a crash."""
    run = _serving_spec(name="svc_all_timeout", n_requests=5,
                        queue_depth=None, timeout_ps=1,
                        max_retries=0).run(seed=0, structured=True)
    out = request_outcomes(run.spans)
    assert out["issued"] == 5 and out["completed"] == 0
    assert out["timed_out"] == 5
    assert out["goodput"] == 0.0 and out["latency_us"] == {}
    stats = request_latency_stats(run.spans)
    assert stats["n"] == 0
    assert stats["p50"] == stats["p99.9"] == stats["max"] == 0.0
    report = request_report(run.spans)
    assert "no completed requests" in report
    assert "outcomes:" in report        # the accounting still prints
    assert slowest_fallback_is_consistent(run)


def slowest_fallback_is_consistent(run) -> bool:
    """With zero completed requests, slowest_request falls back to the
    slowest request of any outcome instead of returning nothing."""
    from repro.core.analysis import slowest_request

    trace = slowest_request(run.spans)
    return trace is not None and rpc_requests(trace.spans)


def test_score_mitigations_zero_requests_well_formed():
    """Regression: scoring runs that completed zero requests (empty
    request_us pools) returns a well-formed scoreboard."""
    empty = RunStats(scenario="svc", seed=0, expected=(), detected=(),
                     wall_s=0.1, events=10, n_spans=1,
                     component_us={}, critical_components=[],
                     mitigation="retransmit")
    base = RunStats(scenario="svc", seed=0, expected=(), detected=(),
                    wall_s=0.1, events=10, n_spans=1,
                    component_us={}, critical_components=[],
                    mitigation="do_nothing")
    board = score_mitigations([base, empty])
    by_name = {s.mitigation: s for s in board.scores}
    assert by_name["retransmit"].request_latency == {}
    assert by_name["retransmit"].p999_vs_baseline is None
    assert board.to_dict() and board.report()
    assert percentile([], 99.9) == 0.0  # the shared empty-pool guard


# ---------------------------------------------------------------------------
# Legacy (fan-out) behavior must be untouched by the serving engine
# ---------------------------------------------------------------------------


def test_legacy_fanout_has_no_outcome_attrs():
    """Default-knob runs stay on the fan-out schedule: no serving-mode
    attrs leak into their spans (byte-identity with the committed goldens
    is asserted in tests/test_sweep.py / test_streaming_weave.py)."""
    spec = ScenarioSpec(
        name="legacy_fanout", description="pre-saturation schedule",
        workload="rpc", workload_params=(("n_requests", 4),),
        program=rpc_handler_program, n_pods=2, chips_per_pod=2,
        clock_reads=2,
    )
    run = spec.run(seed=0, structured=True)
    roots = rpc_requests(run.spans)
    assert len(roots) == 4
    assert all("outcome" not in s.attrs and "lb" not in s.attrs
               for s in roots)
    out = request_outcomes(run.spans)
    assert out["completed"] == 4       # legacy roots default to completed
    assert set(out["latency_us"]) == {"fanout"}
