"""Cluster orchestrator — the SimBricks role: assemble component simulators
into one full-system simulation and run it.

Owns the global virtual clock, the topology, one DeviceSim per pod, one
HostSim per host, one NetSim, and the collective rendezvous table.  Writes
each simulator's log to its own file (or named pipe, §3.8), which are the
*only* interface Columbo consumes.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .clock import InlineWeaveWriter, LogWriter, StructuredLogWriter
from .engine import EventKernel
from .devicesim import ClusterLike, CollectiveInstance, DeviceSim
from .hostsim import HostClock, HostSim
from .netsim import NetSim
from .topology import Topology, ntp_testbed, tpu_cluster
from .workload import OpSpec, ProgramSpec


@dataclass
class FailurePlan:
    """Kill one host at ``fail_at_ps`` and restart it ``restart_after_ps``
    later, resuming from ``restored_step``."""

    host: str
    fail_at_ps: int
    restart_after_ps: int
    restored_step: int = 0


class ClusterOrchestrator(ClusterLike):
    """Assembles component sims over one shared :class:`EventKernel` and
    runs the full-system simulation (the SimBricks role)."""

    def __init__(
        self,
        topo: Topology,
        outdir: Optional[str] = None,
        compute_scale: Optional[Dict[str, float]] = None,
        host_kwargs: Optional[Dict] = None,
        clock_params: Optional[Dict[str, Tuple[int, float]]] = None,  # host -> (offset_ps, drift_ppm)
        online_pipes: bool = False,
        structured: bool = False,
        sink=None,
    ) -> None:
        self.sim = EventKernel()
        self.port = self.sim.register("cluster")
        self.topo = topo
        self.outdir = outdir
        self.online_pipes = online_pipes
        # structured fast path: sims hand Event records straight to the
        # trace pipeline (StructuredLogWriter); no text is ever formatted
        self.structured = structured
        # inline weave path: sims hand records straight to a
        # core.streaming.StreamingWeaver; spans assemble as the kernel runs
        self.sink = sink
        if structured and (online_pipes or outdir):
            raise ValueError(
                "structured=True captures events in memory and writes no "
                "logs; it cannot honor outdir or serve online_pipes "
                "consumers (both need the text path)"
            )
        if sink is not None and (structured or online_pipes or outdir):
            raise ValueError(
                "sink= (inline weaving) feeds events straight to the weaver "
                "and keeps no log or record buffer; it cannot be combined "
                "with structured=True, outdir, or online_pipes"
            )
        if outdir:
            os.makedirs(outdir, exist_ok=True)
        self._logs: List[LogWriter] = []

        self.net = NetSim(self.sim.register("net"), topo, self._mklog("net.log", "net"))

        self.device_sims: Dict[int, DeviceSim] = {}
        self._chip2dev: Dict[str, DeviceSim] = {}
        for pod, chips in topo.pods.items():
            dev = DeviceSim(
                self.sim.register(f"device.pod{pod}"), self, pod, chips,
                self._mklog(f"device-pod{pod}.log", "device"),
                compute_scale=compute_scale,
            )
            self.device_sims[pod] = dev
            for c in chips:
                self._chip2dev[c] = dev

        clock_params = clock_params or {}
        hk = host_kwargs or {}
        self.hosts: Dict[str, HostSim] = {}
        for pod, chips in topo.pods.items():
            name = topo.host_name(pod)
            off, drift = clock_params.get(name, (0, 0.0))
            self.hosts[name] = HostSim(
                self.sim.register(f"host.{name}"), self,
                name, self._mklog(f"host-{name}.log", "host"),
                chips=chips, clock=HostClock(off, drift), **hk,
            )
        # hosts that exist in the topology but have no chips (NTP testbed)
        for name in topo.hosts:
            if name not in self.hosts:
                off, drift = clock_params.get(name, (0, 0.0))
                self.hosts[name] = HostSim(
                    self.sim.register(f"host.{name}"), self,
                    name, self._mklog(f"host-{name}.log", "host"),
                    chips=[], clock=HostClock(off, drift), **hk,
                )

        self._collectives: Dict[Tuple, CollectiveInstance] = {}
        self._coll_seq = 0

    # -- log management -----------------------------------------------------------------

    def _mklog(self, fname: str, sim_type: str) -> LogWriter:
        if self.sink is not None:
            # inline weave: attach order fixes the per-type writer rank, so
            # equal-timestamp ties break toward the earlier-created writer —
            # the same contract MergedProducer gives the post-hoc paths
            lw = InlineWeaveWriter(sim_type, self.sink)
            self._logs.append(lw)
            return lw
        if self.structured:
            lw = StructuredLogWriter(sim_type)
            # keep the registry tag so render_lines() reproduces the text
            # log byte for byte (the parsers skip this comment line)
            lw.write(f"# columbo sim_type={sim_type}")
            self._logs.append(lw)
            return lw
        if self.outdir:
            path = os.path.join(self.outdir, fname)
            if self.online_pipes:
                # §3.8: logs go to named pipes; Columbo must already be
                # reading (open of a FIFO's write end blocks until then).
                import stat

                if not (os.path.exists(path) and stat.S_ISFIFO(os.stat(path).st_mode)):
                    if os.path.exists(path):
                        os.remove(path)
                    os.mkfifo(path)
                lw = LogWriter(path)
            else:
                lw = LogWriter(path)
        else:
            lw = LogWriter()
        # tag the log for registry lookup: parsers skip the comment line,
        # and TraceSession.add_log(path) auto-detects the simulator type
        lw.write(f"# columbo sim_type={sim_type}")
        lw.sim_type = sim_type
        self._logs.append(lw)
        return lw

    def log_paths(self) -> Dict[str, List[str]]:
        """sim_type -> log paths (input for a TraceSession/TraceSpec).
        Keys come from each simulator's registry tag, not a hardcoded
        trio, so clusters extended with custom simulator types compose
        without edits here."""
        assert self.outdir is not None
        out: Dict[str, List[str]] = {}
        for lw in self._logs:
            if lw.path is None:
                continue
            out.setdefault(lw.sim_type, []).append(lw.path)
        return out

    def event_streams(self) -> Dict[str, List[StructuredLogWriter]]:
        """sim_type -> structured writers, in creation order (the same
        order ``log_paths`` lists the text logs, so both paths merge
        shards with identical tie-breaking)."""
        out: Dict[str, List[StructuredLogWriter]] = {}
        for lw in self._logs:
            if lw.structured:
                out.setdefault(lw.sim_type, []).append(lw)
        return out

    def structured_sources(self) -> List[Tuple[str, "object"]]:
        """``(sim_type, event iterable)`` pairs ready for ``SourceSpec``.

        Multiple writers of one type (per-pod device logs, per-host logs)
        merge through the same ``MergedProducer`` that merges text shards
        (writers expose ``events()``, which is all it needs), so the
        tie-break contract — ties toward the earlier-created writer — is
        shared by construction and structured weaving stays byte-identical
        to text."""
        # late import: repro.core must not depend on repro.sim
        from ..core.pipeline import MergedProducer

        streams = self.event_streams()
        out: List[Tuple[str, object]] = []
        for st in sorted(streams):
            writers = streams[st]
            if len(writers) == 1:
                out.append((st, writers[0].events()))
            else:
                out.append((st, MergedProducer(writers).events()))
        return out

    def close(self) -> None:
        for lw in self._logs:
            lw.close()

    # -- ClusterLike interface -------------------------------------------------------------

    def device_sim_for(self, chip: str) -> DeviceSim:
        return self._chip2dev[chip]

    def get_collective(self, chip: str, op: OpSpec, step: int) -> CollectiveInstance:
        """Rendezvous: all chips of a ring group share one instance.

        * group="ici": ring over the chips of the *caller's pod* (one
          instance per pod), modeling per-axis intra-pod rings.
        * group="dcn": ring over the caller's homologue chip in every pod
          (cross-pod gradient path through hosts + DCN links; all homologue
          rings share the same DCN links, modeling contention).
        """
        if op.group == "dcn":
            pod = next(p for p, chips in self.topo.pods.items() if chip in chips)
            i = self.topo.pods[pod].index(chip)
            ring = [chips[i] for chips in self.topo.pods.values()]
            key = ("dcn", op.kind, op.name, step, i)
        else:
            pod = next(p for p, chips in self.topo.pods.items() if chip in chips)
            ring = list(self.topo.pods[pod])
            key = ("ici", pod, op.kind, op.name, step)
        inst = self._collectives.get(key)
        if inst is None:
            self._coll_seq += 1
            cid = f"{op.kind[:2]}{self._coll_seq}.{op.name}.s{step}"
            inst = CollectiveInstance(self, cid, op.kind, ring, op.coll_bytes)
            self._collectives[key] = inst
        return inst

    def dispatch(
        self,
        host: HostSim,
        chip: str,
        program: ProgramSpec,
        step: int,
        on_done: Callable[[str, int], None],
    ) -> None:
        """Host -> chip program dispatch (PCIe natural boundary)."""
        dev = self.device_sim_for(chip)
        # small dispatch latency over PCIe (command, not payload)
        self.sim.call_after(
            500_000, lambda: dev.run_program(chip, program, step, lambda t: on_done(chip, t))
        )

    # -- failure injection --------------------------------------------------------------------

    def inject_failure(self, plan: FailurePlan) -> None:
        h = self.hosts[plan.host]
        self.sim.at(plan.fail_at_ps, h.fail)
        self.sim.at(plan.fail_at_ps + plan.restart_after_ps, lambda: h.restart(plan.restored_step))

    # -- run --------------------------------------------------------------------------------

    def run(self, until: Optional[int] = None) -> int:
        self.sim.run(until=until)
        self.close()
        return self.sim.now


# -------------------------------------------------------------------------------------------
# Convenience entry points
# -------------------------------------------------------------------------------------------


def drive_training_hosts(
    cluster: ClusterOrchestrator,
    program: ProgramSpec,
    n_steps: int,
    per_host: Optional[Callable[[HostSim], None]] = None,
) -> None:
    """Arm every chip-bearing host with ``n_steps`` of ``program`` and stop
    background flows once the last host finishes (so the event queue
    drains).  ``per_host`` optionally starts per-host telemetry
    (heartbeats, clock reads).  The caller still runs ``cluster.run()``."""
    training_hosts = [h for h in cluster.hosts.values() if h.chips]
    remaining = {"n": len(training_hosts)}

    def _one_done() -> None:
        remaining["n"] -= 1
        if remaining["n"] == 0:
            cluster.net.stop_all_flows()

    for h in training_hosts:
        h.run_steps(program, n_steps, on_all_done=_one_done)
        if per_host is not None:
            per_host(h)


def run_training_sim(
    program: ProgramSpec,
    n_steps: int = 2,
    n_pods: int = 2,
    chips_per_pod: int = 4,
    outdir: Optional[str] = None,
    compute_scale: Optional[Dict[str, float]] = None,
    bg_traffic_link: Optional[str] = None,
    bg_rate: float = 40e9,
    ckpt_every: int = 0,
    failure: Optional[FailurePlan] = None,
    structured: bool = False,
    sink=None,
) -> ClusterOrchestrator:
    """Simulate n_steps of a training program on a multi-pod testbed."""
    topo = tpu_cluster(n_pods=n_pods, chips_per_pod=chips_per_pod)
    cluster = ClusterOrchestrator(
        topo, outdir=outdir, compute_scale=compute_scale,
        host_kwargs={"ckpt_every": ckpt_every}, structured=structured,
        sink=sink,
    )
    if bg_traffic_link is not None:
        link = topo.links[bg_traffic_link]
        cluster.net.start_bulk_flow(link.a, link.b, bg_rate, segment_bytes=1 << 20, flow_id="bulk0")
    if failure is not None:
        cluster.inject_failure(failure)
    drive_training_hosts(
        cluster, program, n_steps,
        per_host=lambda h: h.start_heartbeats(
            every_ps=50_000_000_000, n=max(2, n_steps * 2)
        ),
    )
    cluster.run()
    return cluster


def run_ntp_sim(
    background: bool,
    sim_seconds: float = 30.0,
    poll_s: float = 1.0,
    outdir: Optional[str] = None,
    client_offset_ps: int = 5_000_000,     # client starts 5 us ahead
    client_drift_ppm: float = 8.0,
    server_drift_ppm: float = -3.0,
    bg_rate: float = 1.2e9,                # ~saturates the 1.25 GB/s link
) -> ClusterOrchestrator:
    """The paper's §5 case study: NTP sync with/without background traffic."""
    topo = ntp_testbed()
    cluster = ClusterOrchestrator(
        topo,
        outdir=outdir,
        clock_params={
            "client": (client_offset_ps, client_drift_ppm),
            "server": (0, server_drift_ppm),
        },
    )
    horizon = int(sim_seconds * 1e12)
    client = cluster.hosts["client"]
    server = cluster.hosts["server"]
    n_polls = int(sim_seconds / poll_s) - 1
    client.start_ntp_client(server, every_ps=int(poll_s * 1e12), n=n_polls)
    client.start_clock_reads(every_ps=int(poll_s * 1e12 / 2), n=2 * n_polls)
    server.start_clock_reads(every_ps=int(poll_s * 1e12 / 2), n=2 * n_polls)
    if background:
        cluster.net.start_bulk_flow(
            "bgsrc", "bgsink", bg_rate, segment_bytes=1 << 20, stop_ps=horizon
        )
    cluster.run(until=horizon)
    return cluster
