"""Global virtual clock + log plumbing for the component simulators.

The DES kernel itself lives in :mod:`repro.sim.engine` (``EventKernel``);
this module keeps the historic ``Sim`` name importable and owns
:class:`LogWriter`, the ad-hoc per-simulator log sink.  The kernel's global
clock is the "true and precise global clock for all events" the paper
highlights as a key advantage of simulation (§1 advantage iii).  Times are
integer picoseconds.
"""
from __future__ import annotations

from typing import List, Optional

from .engine import EventHandle, EventKernel, PeriodicTask, Sim, SimPort

__all__ = ["EventHandle", "EventKernel", "LogWriter", "PeriodicTask", "Sim", "SimPort"]


class LogWriter:
    """Collects one simulator instance's ad-hoc log lines.

    Lines buffer in memory and flush to a file (or named pipe for §3.8
    online mode) — simulators in the paper write files; ours do too.
    """

    def __init__(self, path: Optional[str] = None, stream=None) -> None:
        self.path = path
        self.lines: List[str] = []
        self._stream = stream
        if path is not None and stream is None:
            self._stream = open(path, "w", buffering=1 << 20)

    def write(self, line: str) -> None:
        if self._stream is not None:
            self._stream.write(line)
            self._stream.write("\n")
        else:
            self.lines.append(line)

    def close(self) -> None:
        if self._stream is not None:
            self._stream.close()
            self._stream = None

    def __enter__(self) -> "LogWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
