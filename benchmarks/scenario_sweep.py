"""Scenario sweep: wall-clock cost of the fault-injection loop per scenario.

For every library scenario (sim/scenarios.py) this measures the full
pipeline — seeded fault injection + DES simulation + TraceSpec weave +
diagnose() — and reports one row per scenario:

    scenario.<name>,<us_per_run>,spans=<n> diag=<classes> OK|MISSED

The sweep doubles as a correctness gate for the perf numbers: a scenario
whose injected fault class is not named by diagnose() reports MISSED and
fails the run, so a "fast" regression that breaks attribution cannot hide.

    PYTHONPATH=src python -m benchmarks.run scenarios
"""
import time


def run():
    from repro.sim.scenarios import SCENARIOS

    rows = []
    missed = []
    for name, spec in SCENARIOS.items():
        t0 = time.perf_counter()
        r = spec.run()
        dt = time.perf_counter() - t0
        verdict = "OK" if r.ok else "MISSED"
        if not r.ok:
            missed.append(name)
        diag = "+".join(r.detected) or "clean"
        rows.append(
            (f"scenario.{name}", dt * 1e6,
             f"spans={len(r.spans)} diag={diag} {verdict}")
        )
    if missed:
        raise RuntimeError(f"scenarios missed their diagnosis: {missed}")
    return rows
