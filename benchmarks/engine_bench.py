"""Engine performance benchmark — the repo's perf baseline (BENCH_engine.json).

Three measurements, smallest to largest scope:

* ``kernel``    — raw DES dispatch rate: events/sec through a bare
                  :class:`repro.sim.engine.EventKernel` (256 interleaved
                  self-rescheduling timers, no simulator work).
* ``topology``  — full-system simulation events/sec at 8/64/256-pod
                  fat-tree testbeds (``scale(pods=N)``): one training step
                  with a cross-pod DCN all-reduce, in-memory logs.
* ``sweep``     — end-to-end ``(scenario, seed)`` sweep wall-time at
                  ``--jobs 1/4/8`` (simulate + weave + diagnose + shards).

Results land in ``BENCH_engine.json`` (schema ``columbo.engine_bench/v1``,
validated in ``tests/test_sweep.py``); the recorded baseline and the exact
reproduction commands live in ``docs/performance.md``.

    python -m benchmarks.engine_bench                 # full baseline (~2 min)
    python -m benchmarks.engine_bench --smoke         # tier-1 pre-flight (~10 s)
    python -m benchmarks.engine_bench --out my.json --jobs 1,2
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import tempfile
import time

SCHEMA = "columbo.engine_bench/v1"

SMOKE_TOPOLOGY_PODS = (4, 8)
FULL_TOPOLOGY_PODS = (8, 64, 256)


def bench_kernel(n_events: int = 200_000, n_timers: int = 256) -> dict:
    """Raw kernel dispatch rate: ``n_timers`` interleaved self-rescheduling
    timers with co-prime-ish intervals (a worst-ish-case heap mix), run
    until ``n_events`` have executed."""
    from repro.sim.engine import EventKernel

    k = EventKernel()
    done = [0]

    def make(i: int):
        interval = 1_000 + 7 * i

        def fire() -> None:
            done[0] += 1
            if done[0] < n_events:
                k.after(interval, fire)

        return fire

    timers = [make(i) for i in range(n_timers)]
    t0 = time.perf_counter()
    for i, fire in enumerate(timers):
        k.after(1_000 + 7 * i, fire)
    k.run(max_events=n_events)
    wall = time.perf_counter() - t0
    return {
        "n_events": k.events_executed,
        "n_timers": n_timers,
        "wall_s": round(wall, 4),
        "events_per_sec": round(k.events_executed / wall) if wall else 0,
    }


def bench_topology(pods_list=FULL_TOPOLOGY_PODS, chips_per_pod: int = 2,
                   n_steps: int = 1) -> list:
    """Full-system simulation throughput per fat-tree size: one training
    step (per-layer ICI all-gather + cross-pod DCN gradient all-reduce),
    logs kept in memory so disk I/O stays out of the measurement."""
    from repro.sim.cluster import ClusterOrchestrator, drive_training_hosts
    from repro.sim.topology import scale
    from repro.sim.workload import synthetic_program

    rows = []
    for pods in pods_list:
        program = synthetic_program(
            n_layers=1, layer_flops=5e11, layer_bytes=2e8, grad_bytes=1e8
        )
        t0 = time.perf_counter()
        topo = scale(pods=pods, chips_per_pod=chips_per_pod)
        cluster = ClusterOrchestrator(topo)
        drive_training_hosts(cluster, program, n_steps)
        cluster.run()
        wall = time.perf_counter() - t0
        ev = cluster.sim.events_executed
        rows.append({
            "pods": pods,
            "chips": pods * chips_per_pod,
            "links": len(topo.links),
            "events": ev,
            "wall_s": round(wall, 3),
            "events_per_sec": round(ev / wall) if wall else 0,
            "virtual_s": round(cluster.sim.now / 1e12, 4),
        })
    return rows


def bench_sweep(jobs_list=(1, 4, 8), scenarios=None, seeds=(0, 1, 2, 3),
                **overrides) -> dict:
    """End-to-end sweep wall-time per ``--jobs`` setting (same grid each
    time; cells are seed-pinned so outputs are identical modulo shard
    order — only the wall clock moves).  The full grid runs the curated
    library at 4 pods x 3 steps so each cell carries enough simulation to
    amortize worker startup (tiny cells measure pool overhead, not the
    engine)."""
    from repro.sim.sweep import SweepSpec, run_sweep

    if scenarios is None:
        spec = SweepSpec.library(seeds=tuple(seeds), **overrides)
    else:
        spec = SweepSpec(scenarios=tuple(scenarios), seeds=tuple(seeds), **overrides)
    cells = len(spec.cells())
    by_jobs = {}
    events = spans = 0
    for jobs in jobs_list:
        with tempfile.TemporaryDirectory(prefix="engine-bench-sweep-") as d:
            t0 = time.perf_counter()
            result = run_sweep(spec, d, jobs=jobs)
            by_jobs[str(jobs)] = round(time.perf_counter() - t0, 3)
            events = sum(c.stats.events for c in result.cells)
            spans = sum(c.stats.n_spans for c in result.cells)
    return {
        "cells": cells,
        "scenarios": list(spec.scenarios),
        "seeds": list(spec.seeds),
        "events_total": events,
        "spans_total": spans,
        "wall_s_by_jobs": by_jobs,
    }


def collect(smoke: bool = False, jobs_list=(1, 4, 8)) -> dict:
    """Run all three benches and assemble the BENCH_engine.json payload."""
    if smoke:
        kernel = bench_kernel(n_events=20_000)
        topo = bench_topology(SMOKE_TOPOLOGY_PODS)
        sweep = bench_sweep(jobs_list=(1, 2),
                            scenarios=("healthy_baseline", "throttled_chip"),
                            seeds=(0,))
    else:
        kernel = bench_kernel()
        topo = bench_topology()
        sweep = bench_sweep(jobs_list=jobs_list, n_pods=4, n_steps=3)
    return {
        "schema": SCHEMA,
        "smoke": smoke,
        "host": {
            "python": sys.version.split()[0],
            "platform": platform.platform(),
        },
        "kernel": kernel,
        "topology_scaling": topo,
        "sweep": sweep,
    }


def run():
    """``benchmarks.run`` harness hook: smoke-sized rows (name, us, derived)."""
    payload = collect(smoke=True)
    yield ("engine.kernel", 1e6 / max(payload["kernel"]["events_per_sec"], 1),
           f"{payload['kernel']['events_per_sec']}ev/s")
    for row in payload["topology_scaling"]:
        yield (f"engine.sim.pods{row['pods']}",
               row["wall_s"] * 1e6, f"{row['events_per_sec']}ev/s")
    for jobs, wall in payload["sweep"]["wall_s_by_jobs"].items():
        yield (f"engine.sweep.jobs{jobs}", wall * 1e6,
               f"{payload['sweep']['cells']}cells")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI pre-flight (~10s)")
    ap.add_argument("--out", default="BENCH_engine.json",
                    help="where to write the JSON payload")
    ap.add_argument("--jobs", default="1,4,8",
                    help="comma list of sweep --jobs settings to time")
    args = ap.parse_args()
    jobs_list = tuple(int(j) for j in args.jobs.split(",") if j.strip())
    payload = collect(smoke=args.smoke, jobs_list=jobs_list)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    k = payload["kernel"]
    print(f"[engine_bench] kernel: {k['events_per_sec']:,} events/s "
          f"({k['n_events']} events in {k['wall_s']}s)")
    for row in payload["topology_scaling"]:
        print(f"[engine_bench] sim pods={row['pods']:<4d} links={row['links']:<6d} "
              f"{row['events']:>9,} events in {row['wall_s']:>7.3f}s "
              f"-> {row['events_per_sec']:,} events/s")
    for jobs, wall in payload["sweep"]["wall_s_by_jobs"].items():
        print(f"[engine_bench] sweep jobs={jobs}: {wall}s "
              f"({payload['sweep']['cells']} cells)")
    print(f"[engine_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
