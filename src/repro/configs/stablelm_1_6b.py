"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32 = MHA) d_ff=5632
vocab=100352.  [hf:stabilityai/stablelm-2-1_6b; unverified].
"""
from ..models.config import ModelConfig
from . import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        mlp_act="swiglu",
        rope_theta=10_000.0,
    ),
    microbatches={"train_4k": 2},
)
