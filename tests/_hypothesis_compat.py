"""Optional-``hypothesis`` shim so the suite collects on minimal installs.

The property-based tests use hypothesis (declared in requirements-dev.txt /
the ``dev`` extra in pyproject.toml), but a bare ``pip install -e .`` must
still collect and run the example-based majority of the suite.  Importing
``given``/``settings``/``st`` from here yields the real library when
available; otherwise stand-ins that *skip* each property test at call time
(the per-test equivalent of ``pytest.importorskip``) while every other test
in the module keeps running.
"""
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed (pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    class _AnyStrategy:
        """Accepts any strategy construction; tests never run, so the
        returned placeholders are never drawn from."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return _AnyStrategy()

            return strategy

        def __call__(self, *args, **kwargs):
            return _AnyStrategy()

        def filter(self, *_a, **_k):
            return self

        def map(self, *_a, **_k):
            return self

    st = _AnyStrategy()

    class HealthCheck:
        all = staticmethod(lambda: [])
