"""Sharding rules, cell assembly, HLO stats parsing, and the cost-
extrapolation methodology validated against a fully-unrolled compile."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.params import PSpec, Rules
from repro.xla.hlo_stats import collective_stats, parse_shape_bytes


def test_rules_divisibility_fallback():
    r = Rules({"vocab": "model", "embed": "data"}, {"data": 16, "model": 16})
    assert r.pspec(PSpec((512, 128), ("vocab", "embed")))[0] == "model"
    # 49155 % 16 != 0 -> replicate + record
    spec = r.pspec(PSpec((49155, 128), ("vocab", "embed")))
    assert spec[0] is None
    assert ("vocab", 49155) in r.fallbacks


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert parse_shape_bytes("bf16[8]") == 16
    assert parse_shape_bytes("(f32[2,2], s8[4])") == 16 + 4
    assert parse_shape_bytes("pred[7]") == 7


def test_collective_stats_parsing():
    hlo = """
  %all-gather.1 = f32[512,2048]{0,1} all-gather(%p), channel_id=1, replica_groups=[16,16]<=[256], dimensions={1}
  %all-reduce.2 = bf16[1024]{0} all-reduce(%q), replica_groups=[8,32]<=[256], to_apply=%add
  %ar-done = bf16[4]{0} all-reduce-done(%h)
  %cp = f32[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %unrelated = f32[2]{0} add(%a, %b)
"""
    s = collective_stats(hlo)
    ag = s["per_kind"]["all-gather"]
    assert ag["count"] == 1 and ag["bytes"] == 512 * 2048 * 4 // 16
    ar = s["per_kind"]["all-reduce"]
    assert ar["count"] == 1 and ar["bytes"] == 1024 * 2
    cp = s["per_kind"]["collective-permute"]
    assert cp["count"] == 1 and cp["bytes"] == 64 * 4
    # wire model: AR rings move 2(N-1)/N * B
    assert ar["wire_bytes"] == int(2 * 1024 * 2 * 31 / 32)


def test_build_cell_shardings_match_abstract_shapes(subproc):
    out = subproc(
        """
import jax
from repro.launch.mesh import make_mesh
from repro.launch.specs import build_cell
mesh = jax.make_mesh((2, 2), ('data', 'model'))
for shape in ('train_4k', 'prefill_32k', 'decode_32k'):
    cell = build_cell('olmo-1b', shape, mesh)
    flat_a = jax.tree_util.tree_leaves(cell.abstract_args)
    flat_s = jax.tree_util.tree_leaves(cell.in_shardings,
              is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
    assert len(flat_a) == len(flat_s), (shape, len(flat_a), len(flat_s))
    for a, s in zip(flat_a, flat_s):
        assert isinstance(s, jax.sharding.NamedSharding), (shape, s)
        s.shard_shape(a.shape)   # raises if incompatible
print('CELLS_OK')
""",
        devices=4,
    )
    assert "CELLS_OK" in out


def test_cost_extrapolation_methodology(subproc):
    """Depth-1P/2P extrapolated FLOPs must match a fully-unrolled compile of
    a deeper model (the §Roofline methodology's correctness check)."""
    out = subproc(
        """
import jax, jax.numpy as jnp, dataclasses
from repro.models import ModelConfig, model_pspecs, abstract_params, forward
from repro.xla.hlo_stats import cost_summary

def flops_at_depth(L):
    cfg = ModelConfig(name='t', family='dense', n_layers=L, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
                      remat='none', scan_layers=False, unroll_inner=True,
                      attn_block_q=64)
    params = abstract_params(model_pspecs(cfg))
    toks = jax.ShapeDtypeStruct((2, 128), jnp.int32)
    c = jax.jit(lambda p, t: forward(cfg, p, t)[0]).lower(params, toks).compile()
    return cost_summary(c)['flops']

c1, c2, c6 = flops_at_depth(1), flops_at_depth(2), flops_at_depth(6)
per = c2 - c1
outside = c1 - per
pred6 = outside + 6 * per
rel = abs(pred6 - c6) / c6
assert rel < 0.02, (pred6, c6, rel)
print('EXTRAP_OK', rel)
""",
        devices=0,
    )
    assert "EXTRAP_OK" in out
