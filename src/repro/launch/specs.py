"""Abstract inputs + shardings for every (arch × shape × mesh) cell.

``input_specs()`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for every model input of a cell, and the
matching NamedSharding trees used as jit in_shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import ArchSpec, ShapeSpec, SHAPES, get_arch
from ..models.config import ModelConfig
from ..models.params import Rules, abstract_params, partition_specs
from ..models.sharding import make_rules
from ..models.transformer import cache_specs, model_pspecs
from ..training.train_step import abstract_train_state

# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    out: Dict[str, jax.ShapeDtypeStruct] = {
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)
    }
    if cfg.frontend != "none":
        # modality frontend stub: precomputed frame/patch embeddings
        out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return out


def batch_shardings(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, rules: Rules
) -> Dict[str, NamedSharding]:
    B = shape.global_batch
    dp = rules.mesh_axes_for("batch", B)  # falls back to None if indivisible
    ns = lambda spec: NamedSharding(mesh, spec)
    out: Dict[str, NamedSharding] = {}
    for k, v in batch_specs(cfg, shape).items():
        if v.ndim == 2:
            out[k] = ns(P(dp, None))
        else:
            out[k] = ns(P(dp, None, None))
    return out


# ---------------------------------------------------------------------------
# cache shardings (mirrors transformer.cache_specs structure)
# ---------------------------------------------------------------------------


def _entry_pspec(entry: Dict[str, Any], rules: Rules, stacked: bool) -> Dict[str, P]:
    """PartitionSpec dict for one cache entry (kv / mamba / rglru)."""
    pre = (None,) if stacked else ()
    out: Dict[str, P] = {}
    for key, arr in entry.items():
        dims = arr.shape[1:] if stacked else arr.shape
        if key in ("k", "v", "k_scale", "v_scale"):
            b, kheads, s, hd = dims
            out[key] = P(
                *pre,
                rules.mesh_axes_for("batch", b),
                rules.mesh_axes_for("kv_heads", kheads),
                rules.mesh_axes_for("cache_seq", s),
                None,
            )
        elif key == "conv":
            b, w, inner = dims
            out[key] = P(*pre, rules.mesh_axes_for("batch", b), None,
                         rules.mesh_axes_for("inner", inner))
        elif key == "ssm":
            b, inner, st = dims
            out[key] = P(*pre, rules.mesh_axes_for("batch", b),
                         rules.mesh_axes_for("inner", inner), None)
        elif key == "h":
            b, w = dims
            out[key] = P(*pre, rules.mesh_axes_for("batch", b),
                         rules.mesh_axes_for("lru", w))
        else:
            out[key] = P(*pre, *([None] * len(dims)))
    return out


def cache_shardings(
    cfg: ModelConfig, batch: int, max_seq: int, mesh: Mesh, rules: Rules
) -> Dict[str, Any]:
    specs = cache_specs(cfg, batch, max_seq)
    ns = lambda spec: NamedSharding(mesh, spec)
    out: Dict[str, Any] = {"rest": []}
    if "groups" in specs:
        out["groups"] = {
            name: jax.tree_util.tree_map(
                ns, _entry_pspec(entry, rules, stacked=True),
                is_leaf=lambda x: isinstance(x, P),
            )
            for name, entry in specs["groups"].items()
        }
    for entry in specs["rest"]:
        out["rest"].append(
            jax.tree_util.tree_map(
                ns, _entry_pspec(entry, rules, stacked=False),
                is_leaf=lambda x: isinstance(x, P),
            )
        )
    return out


# ---------------------------------------------------------------------------
# full cell assembly
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cell:
    arch: ArchSpec
    cfg: ModelConfig
    shape: ShapeSpec
    mesh: Mesh
    rules: Rules
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate: Tuple[int, ...]
    kind: str
    microbatches: int = 1
    out_shardings: Any = None


def _effective_microbatches(requested: int, global_batch: int, dp_total: int) -> int:
    """Largest mb <= requested with (global_batch/mb) divisible by the DP
    degree — a smaller per-microbatch batch would replicate instead of
    shard (sub-DP microbatches blow up memory, not shrink it)."""
    cap = max(global_batch // max(dp_total, 1), 1)
    mb = min(requested, cap)
    while mb > 1 and (global_batch % mb or (global_batch // mb) % dp_total):
        mb -= 1
    return max(mb, 1)


def build_cell(
    arch_name: str,
    shape_name: str,
    mesh: Mesh,
    fsdp: bool = True,
    zero1: bool = False,
    parallel_mode: str = "tp",
    cfg_overrides: Optional[Dict[str, Any]] = None,
) -> Cell:
    """``zero1=True``: ZeRO-1 — parameters replicated over the data axis
    (bf16 storage recommended) while optimizer moments + master stay
    FSDP-sharded; gradients reduce-scatter into the optimizer shards and
    fresh params all-gather ONCE per step instead of per microbatch.

    ``parallel_mode="fsdp_all"``: no TP; batch + params shard over the full
    (data, model) grid (per-token TP all-reduces disappear)."""
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    cfg = arch.config_for(shape_name)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    # KV-cache sequence sharding (SP for serving):
    #  * long-context batch=1 decode: shard seq over "data" (batch unusable)
    #  * KV heads not divisible by the model axis: shard seq over "model"
    #    (otherwise the replicated-head cache blows past per-chip HBM)
    shard_cache_seq = None
    if shape.kind == "decode":
        model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        # serving avoids ZeRO-3 when the TP-sharded params fit replicated
        # over data: per-token param re-gathers dominate the decode step
        # otherwise (measured 158x collective-term reduction; §Perf).  Very
        # large models (chameleon/llama4) keep FSDP for memory.
        if cfg.n_params * 2 / model_size <= 4e9:
            fsdp = False
        if shape.global_batch == 1:
            shard_cache_seq = "data"
        elif cfg.n_kv_heads % model_size != 0 and cfg.uses_attention:
            shard_cache_seq = "model"
    rules = make_rules(mesh, fsdp=fsdp, shard_cache_seq=shard_cache_seq,
                       parallel_mode=parallel_mode)
    ns = lambda spec: NamedSharding(mesh, spec)

    pspecs = model_pspecs(cfg)
    params_abs = abstract_params(pspecs)
    if zero1:
        rules_params = make_rules(mesh, fsdp=False, shard_cache_seq=shard_cache_seq,
                                  parallel_mode=parallel_mode)
        rules_opt = rules  # keep FSDP sharding for the optimizer states
        params_shard = jax.tree_util.tree_map(
            ns, partition_specs(pspecs, rules_params), is_leaf=lambda x: isinstance(x, P)
        )
        opt_param_shard = jax.tree_util.tree_map(
            ns, partition_specs(pspecs, rules_opt), is_leaf=lambda x: isinstance(x, P)
        )
        rules = rules_params   # activations follow the replicated-param rules
    else:
        params_shard = jax.tree_util.tree_map(
            ns, partition_specs(pspecs, rules), is_leaf=lambda x: isinstance(x, P)
        )
        opt_param_shard = params_shard

    dp_axes = rules.rules.get("batch")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if dp_axes is None:
        dp_total = 1
    elif isinstance(dp_axes, tuple):
        dp_total = 1
        for a in dp_axes:
            dp_total *= sizes.get(a, 1)
    else:
        dp_total = sizes.get(dp_axes, 1)

    if shape.kind == "train":
        state_abs = abstract_train_state(params_abs)
        opt_shard = {"m": opt_param_shard, "v": opt_param_shard}
        if "master" in state_abs["opt"]:
            opt_shard["master"] = opt_param_shard
        state_shard = {
            "params": params_shard,
            "opt": opt_shard,
            "step": ns(P()),
        }
        batch_abs = batch_specs(cfg, shape)
        batch_shard = batch_shardings(cfg, shape, mesh, rules)
        mb = _effective_microbatches(
            arch.microbatches.get(shape.name, 1), shape.global_batch, dp_total
        )
        # pin the output state to the input shardings: without this XLA may
        # materialize replicated gradients (all-reduce + slice) instead of
        # reduce-scattering into the FSDP shards
        metric_shard = {
            k: ns(P()) for k in ("loss", "ce", "moe_aux", "z", "grad_norm", "lr")
        }
        return Cell(arch, cfg, shape, mesh, rules,
                    (state_abs, batch_abs), (state_shard, batch_shard), (0,), "train",
                    microbatches=mb, out_shardings=(state_shard, metric_shard))

    if shape.kind == "prefill":
        # prefill caches of archs with non-shardable KV heads shard the
        # sequence dim over "model" (same rule as decode) via out_shardings
        model_size = sizes.get("model", 1)
        if (cfg.n_kv_heads % model_size != 0 and cfg.uses_attention
                and parallel_mode == "tp"):
            rules = make_rules(mesh, fsdp=fsdp, shard_cache_seq="model")
        batch_abs = batch_specs(cfg, shape)
        batch_shard = batch_shardings(cfg, shape, mesh, rules)
        B = shape.global_batch
        out_shard = (
            ns(P(rules.mesh_axes_for("batch", B), rules.mesh_axes_for("vocab", cfg.vocab_size))),
            cache_shardings(cfg, B, shape.seq, mesh, rules),
        )
        return Cell(arch, cfg, shape, mesh, rules,
                    (params_abs, batch_abs), (params_shard, batch_shard), (), "prefill",
                    out_shardings=out_shard)

    # decode
    B, S = shape.global_batch, shape.seq
    tokens_abs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    cache_abs = cache_specs(cfg, B, S)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    tok_shard = {"tokens": ns(P(rules.mesh_axes_for("batch", B), None))}
    cshard = cache_shardings(cfg, B, S, mesh, rules)
    return Cell(
        arch, cfg, shape, mesh, rules,
        (params_abs, tokens_abs, cache_abs, pos_abs),
        (params_shard, tok_shard, cshard, ns(P())),
        (2,),
        "decode",
    )
