"""Training step: CE loss (vocab-sharding-friendly), microbatch gradient
accumulation, AdamW, donated state — the function every dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import forward
from .optimizer import AdamWConfig, abstract_opt_state, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    microbatches: int = 1
    moe_aux_weight: float = 0.01
    z_loss_weight: float = 1e-4


def cross_entropy(
    logits: jax.Array,          # (B, S, V) f32, possibly vocab-sharded
    labels: jax.Array,          # (B, S) int32
) -> Tuple[jax.Array, jax.Array]:
    """Mean CE + mean log-Z (for z-loss).  One-hot einsum keeps the label
    lookup a contraction (GSPMD-partitionable over the sharded vocab dim)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)          # (B, S)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return jnp.mean(logz - gold), jnp.mean(jnp.square(logz))


def make_loss_fn(cfg: ModelConfig, tc: TrainConfig):
    def loss_fn(params, batch: Dict[str, jax.Array]):
        logits, aux = forward(
            cfg,
            params,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            train=True,
        )
        ce, z = cross_entropy(logits, batch["labels"])
        loss = ce + tc.moe_aux_weight * aux + tc.z_loss_weight * z
        return loss, {"ce": ce, "moe_aux": aux, "z": z}

    return loss_fn


def init_train_state(cfg: ModelConfig, params: Any) -> Dict[str, Any]:
    return {"params": params, "opt": init_opt_state(params), "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(params_abs: Any) -> Dict[str, Any]:
    return {
        "params": params_abs,
        "opt": abstract_opt_state(params_abs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(cfg, tc)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params = state["params"]
        mb = tc.microbatches
        if mb > 1:

            def mb_reshape(x):
                b = x.shape[0]
                return x.reshape((mb, b // mb) + x.shape[1:])

            batches = jax.tree_util.tree_map(mb_reshape, batch)

            def acc_step(acc, mbatch):
                (loss, metrics), grads = grad_fn(params, mbatch)
                acc_g, acc_l, acc_m = acc
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, grads)
                return (acc_g, acc_l + loss, {k: acc_m[k] + v for k, v in metrics.items()}), None

            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            init = (zeros_g, jnp.zeros((), jnp.float32), {
                "ce": jnp.zeros(()), "moe_aux": jnp.zeros(()), "z": jnp.zeros(())})
            (grads, loss, metrics), _ = jax.lax.scan(acc_step, init, batches)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = {k: v / mb for k, v in metrics.items()}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        new_params, new_opt, opt_metrics = adamw_update(
            tc.adamw, params, grads, state["opt"], state["step"]
        )
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, out_metrics

    return train_step
