"""Workload specs for the device simulator.

A ProgramSpec is the op timeline one chip executes per step.  It can be built

* **from a compiled XLA artifact** (``program_from_compiled``) — aggregate
  FLOPs/bytes from ``cost_analysis()`` sliced into per-layer segments, with
  the *actual* collective schedule parsed from the optimized HLO placed at
  its position in program order.  This is the full-system-simulation step:
  the simulated chips execute what the real compiler produced.
* **synthetically** (``synthetic_program``) — for tests and the case study.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from ..xla.hlo_stats import collective_stats, cost_summary


@dataclass(frozen=True)
class OpSpec:
    """One op on a chip's timeline: compute (roofline-costed), a
    collective, or a wait joining an async collective."""

    name: str
    kind: str = "compute"         # compute | all-reduce | all-gather | reduce-scatter
                                  # | all-to-all | collective-permute | wait
    flops: float = 0.0            # per device
    bytes: float = 0.0            # HBM bytes touched, per device
    coll_bytes: float = 0.0       # collective operand bytes, per device
    group: str = "ici"            # which ring group executes it: "ici" | "dcn"
    async_start: bool = False     # start collective without blocking
    wait_for: Optional[str] = None  # for kind="wait": name of async collective


@dataclass
class ProgramSpec:
    """The ordered op timeline every chip executes once per step."""

    name: str
    ops: List[OpSpec] = field(default_factory=list)

    @property
    def total_flops(self) -> float:
        return sum(o.flops for o in self.ops)

    @property
    def total_bytes(self) -> float:
        return sum(o.bytes for o in self.ops)

    @property
    def collectives(self) -> List[OpSpec]:
        return [o for o in self.ops if o.kind not in ("compute", "wait")]

    def symbols(self) -> Dict[str, str]:
        """op id -> human name (for the SymbolizeActor)."""
        return {f"op{i}": o.name for i, o in enumerate(self.ops)}


def program_from_compiled(
    compiled: Any,
    name: str = "train_step",
    n_segments: int = 16,
    dcn_axis_bytes_fraction: float = 0.0,
    hlo_text: Optional[str] = None,
) -> ProgramSpec:
    """Slice a compiled module's aggregate cost into a traceable op timeline.

    Not cycle-accurate (we do not schedule individual HLO ops): compute cost
    is spread uniformly over ``n_segments`` layer-like segments, and each
    parsed collective is placed after segment ``round(i/n_coll * n_segments)``
    preserving program order.  Aggregates (FLOPs, HBM bytes, collective bytes
    and their kinds/counts) are exactly the compiled module's.
    """
    cost = cost_summary(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = collective_stats(text)["ops"]

    seg_flops = cost["flops"] / n_segments
    seg_bytes = cost["bytes_accessed"] / n_segments

    ops: List[OpSpec] = []
    n_coll = len(colls)
    placed = 0
    for seg in range(n_segments):
        ops.append(
            OpSpec(name=f"{name}.seg{seg}", kind="compute", flops=seg_flops, bytes=seg_bytes)
        )
        # place collectives whose order position maps into this segment
        while placed < n_coll and (placed + 1) * n_segments <= (seg + 1) * n_coll:
            c = colls[placed]
            group = "dcn" if dcn_axis_bytes_fraction > 0 and placed % 2 == 1 else "ici"
            ops.append(
                OpSpec(
                    name=c["name"],
                    kind=c["kind"],
                    coll_bytes=float(c["bytes"]),
                    group=group,
                )
            )
            placed += 1
    for c in colls[placed:]:
        ops.append(OpSpec(name=c["name"], kind=c["kind"], coll_bytes=float(c["bytes"])))
    return ProgramSpec(name=name, ops=ops)


def synthetic_program(
    name: str = "train_step",
    n_layers: int = 4,
    layer_flops: float = 5e12,
    layer_bytes: float = 2e9,
    grad_bytes: float = 1e9,
    overlap_grad_reduce: bool = False,
    cross_pod: bool = True,
) -> ProgramSpec:
    """A miniature training step: n layers of compute + per-layer all-gather
    (FSDP-style) + one gradient all-reduce (optionally async/overlapped,
    optionally on the cross-pod DCN group)."""
    ops: List[OpSpec] = []
    for i in range(n_layers):
        ops.append(
            OpSpec(name=f"layer{i}.ag", kind="all-gather", coll_bytes=layer_bytes / 8)
        )
        ops.append(
            OpSpec(name=f"layer{i}.fwdbwd", kind="compute", flops=layer_flops, bytes=layer_bytes)
        )
    ar = OpSpec(
        name="grad.ar",
        kind="all-reduce",
        coll_bytes=grad_bytes,
        group="dcn" if cross_pod else "ici",
        async_start=overlap_grad_reduce,
    )
    if overlap_grad_reduce:
        # start the reduce before the optimizer segment, wait at the end
        ops.append(ar)
        ops.append(OpSpec(name="optimizer", kind="compute", flops=layer_flops / 4, bytes=grad_bytes))
        ops.append(OpSpec(name="grad.ar.wait", kind="wait", wait_for="grad.ar"))
    else:
        ops.append(ar)
        ops.append(OpSpec(name="optimizer", kind="compute", flops=layer_flops / 4, bytes=grad_bytes))
    return ProgramSpec(name=name, ops=ops)
