"""The mitigation subsystem: registry semantics, the policy x fault and
policy x workload compose matrices, weave invariants, byte-identity of the
``do_nothing`` baseline and the structured fast path, time-varying loss
traces, conflict checking, the sweep mitigations axis, and the
``score_mitigations()`` scoreboard.

The contract under test: remediation policies attach to the *same* seeded
fault trace the workload experiences, fire deterministically, weave their
trigger/action/done trail into ``Mitigation`` span subtrees, and are scored
against a baseline that is provably inert.
"""
import json
import os

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.analysis import (
    MitigationScoreboard,
    RunStats,
    request_latency_stats,
    score_mitigations,
)
from repro.sim import (
    ChunkReorder,
    ClockDrift,
    ClockStep,
    DeviceSlowdown,
    DoNothing,
    HostPause,
    LinkDegradation,
    LinkLoss,
    LossRateTrace,
    MitigationConflictError,
    MitigationPolicy,
    ScenarioSpec,
    StragglerPod,
    SweepSpec,
    get_scenario,
    list_mitigations,
    make_mitigation,
    mitigation_type,
    register_mitigation,
    run_sweep,
    synthetic_program,
)
from repro.sim.mitigation import _MITIGATIONS

PS_PER_MS = 1_000_000_000

BUILTIN_POLICIES = (
    "do_nothing", "retransmit", "disable_and_reroute", "evict_straggler",
    "checkpoint_restore",
)


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------


def test_builtins_registered():
    assert set(list_mitigations()) == set(BUILTIN_POLICIES)


def test_make_mitigation_unknown_name_lists_available():
    with pytest.raises(KeyError, match="unknown mitigation.*retransmit"):
        make_mitigation("no_such_policy")


def test_make_mitigation_rejects_unknown_knob():
    with pytest.raises(TypeError, match="mitigation 'retransmit'"):
        make_mitigation("retransmit", not_a_knob=1)


def test_register_requires_name_and_rejects_duplicates():
    class Nameless(MitigationPolicy):
        """Intentionally missing its registry key."""

    with pytest.raises(ValueError, match="non-empty mitigation_name"):
        register_mitigation(Nameless)
    with pytest.raises(ValueError, match="already registered"):
        register_mitigation(mitigation_type("do_nothing"))
    # replace=True is the explicit override path; restore afterwards
    original = mitigation_type("do_nothing")
    try:
        register_mitigation(original, replace=True)
    finally:
        _MITIGATIONS["do_nothing"] = original


def test_policy_describe_and_rng_streams():
    p = make_mitigation("retransmit", seed=3)
    assert p.describe()
    # per-(seed, stream) determinism, disjoint streams
    assert p.rng(0).random() == make_mitigation("retransmit", seed=3).rng(0).random()
    assert p.rng(0).random() != p.rng(1).random()


# ---------------------------------------------------------------------------
# Policy x fault matrix: every builtin composes with every fault type
# ---------------------------------------------------------------------------


def _micro_program():
    return synthetic_program(
        n_layers=1, layer_flops=2e11, layer_bytes=1e8, grad_bytes=5e7
    )


# every fault spec type (plus a fault-free row), each on a topology the
# 2-pod x 2-chip micro testbed actually has
FAULT_CONDITIONS = {
    "healthy": (),
    "link_degradation": (LinkDegradation(link="ici.pod0.l0", bw_factor=0.2),),
    "link_loss": (LinkLoss(link="dcn.h0h1", drop_prob=0.4,
                           retransmit_ps=PS_PER_MS),),
    "link_reorder": (ChunkReorder(link="ici.pod0.l0", jitter_ps=2 * PS_PER_MS),),
    "host_pause": (HostPause(host="host0", pause_ps=20 * PS_PER_MS),),
    "clock_step": (ClockStep(host="host1", step_ps=5 * PS_PER_MS),),
    "clock_drift": (ClockDrift(host="host1", drift_ppm=400.0),),
    "device_slowdown": (DeviceSlowdown(chip="pod1.chip00", factor=3.0),),
    "straggler_pod": (StragglerPod(pod=1, factor=2.5),),
}


def _micro_spec(faults, policy, workload="collective", **kw):
    return ScenarioSpec(
        name="micro_mitigation",
        description="policy x fault compose matrix cell",
        faults=faults,
        expected=(),              # the matrix asserts weaving, not diagnosis
        n_steps=1,
        chips_per_pod=2,
        clock_reads=4,
        program=_micro_program,
        workload=workload,
        mitigation=policy,
        **kw,
    )


@pytest.mark.parametrize("policy", BUILTIN_POLICIES)
@pytest.mark.parametrize("condition", sorted(FAULT_CONDITIONS))
def test_policy_composes_with_every_fault(policy, condition):
    run = _micro_spec(FAULT_CONDITIONS[condition], policy).run()
    assert run.spans, f"{policy} x {condition}: no spans woven"
    assert run.session.finalize_stats["orphans"] == 0, f"{policy} x {condition}"


@pytest.mark.parametrize("policy", BUILTIN_POLICIES)
@pytest.mark.parametrize("workload", ("collective", "rpc", "storage", "pipeline"))
def test_policy_composes_with_every_workload(policy, workload):
    faults = (LinkLoss(link="dcn.h0h1", drop_prob=0.3, retransmit_ps=PS_PER_MS),)
    run = _micro_spec(faults, policy, workload=workload).run()
    assert run.spans, f"{policy} x {workload}: no spans woven"
    assert run.session.finalize_stats["orphans"] == 0, f"{policy} x {workload}"


@pytest.mark.parametrize("policy", BUILTIN_POLICIES)
def test_text_equals_structured_per_policy(policy):
    spec = _micro_spec(
        (LinkLoss(link="dcn.h0h1", drop_prob=0.4, retransmit_ps=PS_PER_MS),),
        policy, workload="rpc",
    )
    assert spec.run(structured=True).span_jsonl == spec.run().span_jsonl


# ---------------------------------------------------------------------------
# Weave invariants on the mitigation scenario
# ---------------------------------------------------------------------------


def _rid_roots(spans):
    """rid -> list of RpcRequest root spans carrying it."""
    roots = {}
    for s in spans:
        if s.name == "RpcRequest":
            roots.setdefault(s.attrs.get("rid"), []).append(s)
    return roots


def test_every_rid_weaves_to_exactly_one_root():
    run = get_scenario("link_loss_rpc").run(mitigation="retransmit")
    roots = _rid_roots(run.spans)
    assert roots, "no RpcRequest spans woven"
    for rid, spans in roots.items():
        assert len(spans) == 1, f"rid {rid} woven into {len(spans)} roots"
        assert spans[0].parent is None, f"rid {rid} root has a parent"


@given(seed=st.integers(min_value=0, max_value=2**32 - 1),
       policy=st.sampled_from(BUILTIN_POLICIES))
@settings(max_examples=6, deadline=None)
def test_weave_invariants_any_seed(seed, policy):
    """Property: for any seed and any policy, the mitigated weave is
    orphan-free, every rid maps to exactly one root span, and the same
    seed reproduces byte-identical SpanJSONL."""
    spec = get_scenario("link_loss_rpc")
    run = spec.run(seed=seed, mitigation=policy)
    assert run.session.finalize_stats["orphans"] == 0
    for rid, spans in _rid_roots(run.spans).items():
        assert len(spans) == 1, f"rid {rid}: {len(spans)} roots"
    again = spec.run(seed=seed, mitigation=policy)
    assert run.span_jsonl == again.span_jsonl


def test_retransmit_weaves_mitigation_subtree():
    run = get_scenario("link_loss_rpc").run(mitigation="retransmit")
    roots = [s for s in run.spans if s.name == "Mitigation"]
    assert len(roots) == 1
    root = roots[0]
    assert root.parent is None
    assert root.attrs["policy"] == "retransmit"
    assert root.attrs["action"] == "fast_retransmit"
    assert float(root.attrs["penalty"]) == 0.0
    event_names = {name for _, name, _ in root.events}
    assert "mitigation_action" in event_names
    retrans = [s for s in run.spans if s.name == "Retransmit"]
    assert retrans, "retransmit fired but wove no Retransmit spans"
    for s in retrans:
        assert s.parent is not None
        assert s.parent.span_id == root.context.span_id
        assert s.context.trace_id == root.context.trace_id


def test_reroute_records_capacity_penalty():
    run = get_scenario("link_loss_rpc").run(mitigation="disable_and_reroute")
    roots = [s for s in run.spans if s.name == "Mitigation"]
    assert len(roots) == 1
    assert roots[0].attrs["action"] == "disable_link"
    assert roots[0].attrs["target"] == "dcn.h0h1"
    assert float(roots[0].attrs["penalty"]) > 0.0


def test_do_nothing_adds_no_spans_and_is_byte_identical():
    spec = get_scenario("link_loss_rpc")
    baseline = spec.run()          # mitigation defaults to do_nothing
    assert not any(s.name in ("Mitigation", "Retransmit") for s in baseline.spans)
    explicit = spec.run(mitigation="do_nothing")
    assert explicit.span_jsonl == baseline.span_jsonl


def test_untriggered_policy_expires_quietly():
    # no faults -> retransmit's probe never fires; the watch loop must
    # expire after max_polls without keeping the kernel alive or logging
    run = _micro_spec((), "retransmit").run()
    assert not any(s.name in ("Mitigation", "Retransmit") for s in run.spans)


# ---------------------------------------------------------------------------
# LossRateTrace: time-varying fault intensity
# ---------------------------------------------------------------------------


def test_loss_rate_trace_profiles():
    assert LossRateTrace("constant", peak=0.3).rate(10**12) == 0.3
    step = LossRateTrace("step", peak=0.5, base=0.1, at_ps=100)
    assert step.rate(99) == 0.1 and step.rate(100) == 0.5
    ramp = LossRateTrace("ramp", peak=0.4, base=0.0, at_ps=0, ramp_ps=100)
    assert ramp.rate(0) == 0.0
    assert ramp.rate(50) == pytest.approx(0.2)
    assert ramp.rate(1_000) == 0.4
    burst = LossRateTrace("burst", peak=0.9, base=0.05, at_ps=100, ramp_ps=50)
    assert burst.rate(99) == 0.05
    assert burst.rate(100) == 0.9 and burst.rate(149) == 0.9
    assert burst.rate(150) == 0.05
    assert "constant" in LossRateTrace("constant").describe()


def test_loss_rate_trace_rejects_unknown_profile():
    with pytest.raises(ValueError, match="profile must be one of"):
        LossRateTrace("sawtooth")


def test_constant_trace_byte_identical_to_plain_drop_prob():
    plain = _micro_spec(
        (LinkLoss(link="dcn.h0h1", drop_prob=0.4, retransmit_ps=PS_PER_MS),),
        "do_nothing",
    )
    traced = _micro_spec(
        (LinkLoss(link="dcn.h0h1", drop_prob=0.99, retransmit_ps=PS_PER_MS,
                  trace=LossRateTrace("constant", peak=0.4)),),
        "do_nothing",
    )
    assert plain.run().span_jsonl == traced.run().span_jsonl


def test_burst_trace_changes_the_run():
    base = _micro_spec(
        (LinkLoss(link="dcn.h0h1", drop_prob=0.4, retransmit_ps=PS_PER_MS),),
        "do_nothing",
    )
    burst = _micro_spec(
        (LinkLoss(link="dcn.h0h1", drop_prob=0.4, retransmit_ps=PS_PER_MS,
                  trace=LossRateTrace("burst", peak=0.9, base=0.0,
                                      at_ps=0, ramp_ps=PS_PER_MS)),),
        "do_nothing",
    )
    assert base.run().span_jsonl != burst.run().span_jsonl


# ---------------------------------------------------------------------------
# Conflict checking: run(mitigation=...) vs the expected diagnosis
# ---------------------------------------------------------------------------


def test_masking_override_raises_conflict():
    for scenario in ("throttled_chip", "straggler_pod2"):
        with pytest.raises(MitigationConflictError, match="evict_straggler"):
            get_scenario(scenario).run(mitigation="evict_straggler")


def test_conflict_opt_out_via_expected_override():
    run = get_scenario("throttled_chip").run(
        mitigation="evict_straggler", expected=(),
        mitigation_params=(("threshold", 1.5),),
    )
    assert run.ok    # expected=() makes the acceptance check vacuous
    assert any(s.name == "Mitigation" for s in run.spans)


def test_non_masking_override_is_allowed():
    run = get_scenario("lossy_dcn").run(mitigation="retransmit")
    assert any(s.name == "Mitigation" for s in run.spans)


def test_cross_type_mitigation_override_resets_params():
    # a retransmit-knobbed spec overridden to checkpoint_restore must not
    # leak timeout_ps into the new policy's constructor
    spec = _micro_spec(
        (LinkLoss(link="dcn.h0h1", drop_prob=0.4, retransmit_ps=PS_PER_MS),),
        "retransmit",
        mitigation_params=(("timeout_ps", 50_000_000),),
    )
    run = spec.run(mitigation="checkpoint_restore")
    assert run.scenario.mitigation == "checkpoint_restore"
    assert run.scenario.mitigation_params == ()


def test_same_type_override_keeps_params():
    spec = _micro_spec(
        (LinkLoss(link="dcn.h0h1", drop_prob=0.4, retransmit_ps=PS_PER_MS),),
        "retransmit",
        mitigation_params=(("timeout_ps", 50_000_000),),
    )
    run = spec.run(mitigation="retransmit")
    assert run.scenario.mitigation_params == (("timeout_ps", 50_000_000),)


# ---------------------------------------------------------------------------
# Sweep mitigations axis
# ---------------------------------------------------------------------------


def test_sweep_mitigations_axis_cells_and_shards(tmp_path):
    spec = SweepSpec(
        scenarios=("link_loss_rpc",),
        seeds=(0,),
        mitigations=("do_nothing", "retransmit"),
    )
    assert spec.cells() == [
        ("link_loss_rpc", None, "do_nothing", None, None, 0),
        ("link_loss_rpc", None, "retransmit", None, None, 0),
    ]
    result = run_sweep(spec, str(tmp_path), jobs=1, structured=True)
    assert [c.mitigation for c in result.cells] == ["do_nothing", "retransmit"]
    assert [c.shard for c in result.cells] == [
        os.path.join("shards", "link_loss_rpc.do_nothing.seed0.spans.jsonl"),
        os.path.join("shards", "link_loss_rpc.retransmit.seed0.spans.jsonl"),
    ]
    assert [c.stats.mitigation for c in result.cells] == [
        "do_nothing", "retransmit",
    ]
    with open(os.path.join(str(tmp_path), "sweep.json")) as f:
        payload = json.load(f)
    assert payload["schema"] == "columbo.sweep/v5"
    assert payload["mitigations"] == ["do_nothing", "retransmit"]
    board = result.score_mitigations()
    assert board["retransmit"].triggers == 1
    assert "mitigation scoreboard" in result.report()


def test_sweep_v2_payload_still_loads(tmp_path):
    from repro.sim.sweep import load_sweep

    cell_stats = RunStats(scenario="healthy_baseline", seed=0).to_dict()
    payload = {
        "schema": "columbo.sweep/v2",
        "scenarios": ["healthy_baseline"],
        "seeds": [0],
        "workloads": None,
        "overrides": {},
        "jobs": 1,
        "structured": False,
        "cells": [{"scenario": "healthy_baseline", "workload": None,
                   "seed": 0, "ok": True,
                   "shard": "shards/healthy_baseline.seed0.spans.jsonl",
                   "stats": cell_stats}],
    }
    with open(tmp_path / "sweep.json", "w") as f:
        json.dump(payload, f)
    result = load_sweep(str(tmp_path))
    assert result.spec.mitigations is None
    assert result.cells[0].mitigation is None
    assert result.cells[0].stats.mitigation == ""


# ---------------------------------------------------------------------------
# Scoring
# ---------------------------------------------------------------------------


def test_request_latency_stats_has_p999():
    assert request_latency_stats([])["p99.9"] == 0.0
    run = get_scenario("link_loss_rpc").run()
    stats = request_latency_stats(run.spans)
    assert stats["p99.9"] >= stats["p99"] >= stats["p50"] > 0.0


def _stats(mitigation, request_us, mitigation_us=(), penalty=0.0):
    return RunStats(
        scenario="link_loss_rpc", seed=0, mitigation=mitigation,
        request_us=list(request_us), mitigation_us=list(mitigation_us),
        capacity_penalty=penalty,
    )


def test_score_mitigations_hand_built():
    runs = [
        _stats("do_nothing", [1000.0, 2000.0, 9000.0]),
        _stats("retransmit", [900.0, 1500.0, 3000.0],
               mitigation_us=[120.0], penalty=0.0),
        _stats("disable_and_reroute", [950.0, 1600.0, 12000.0],
               mitigation_us=[80.0], penalty=0.25),
    ]
    board = score_mitigations(runs)
    assert isinstance(board, MitigationScoreboard)
    assert board.baseline == "do_nothing"
    # baseline first, actives alphabetical after
    assert [s.mitigation for s in board.scores] == [
        "do_nothing", "disable_and_reroute", "retransmit",
    ]
    retr = board["retransmit"]
    assert retr.beats_baseline is True
    assert retr.p999_vs_baseline < 1.0
    assert retr.triggers == 1
    assert retr.mitigation_us["mean_us"] == pytest.approx(120.0)
    slow = board["disable_and_reroute"]
    assert slow.beats_baseline is False
    assert slow.capacity_penalty == pytest.approx(0.25)
    base = board["do_nothing"]
    assert base.p999_vs_baseline is None and base.beats_baseline is None
    report = board.report()
    assert "beats do_nothing" in report and "retransmit" in report
    d = board.to_dict()
    assert d["baseline"] == "do_nothing" and len(d["scores"]) == 3
    with pytest.raises(KeyError):
        board["no_such_policy"]


def test_runstats_roundtrip_with_mitigation_fields():
    rs = _stats("retransmit", [1.0, 2.0], mitigation_us=[3.0], penalty=0.5)
    assert RunStats.from_dict(rs.to_dict()) == rs
