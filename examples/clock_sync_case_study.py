"""The paper's §5 case study, reproduced end to end.

Two hosts (client/server) behind two switches run chrony-style NTP.
Scenario 1: quiet network.  Scenario 2: a BulkSend flow saturates the
inter-switch link.  Columbo traces reveal *why* NTP breaks: the response
path queues behind the bulk flow while the request path doesn't — the
asymmetry NTP cannot model (Figs. 4, 5, 6).

    PYTHONPATH=src python examples/clock_sync_case_study.py
"""
import os
import statistics
import tempfile
from collections import defaultdict

from repro.core import (
    JaegerJSONExporter,
    SourceSpec,
    TraceSpec,
    clock_offset_series,
    ntp_estimated_offsets,
)
from repro.sim import run_ntp_sim


def scenario(background: bool, outdir: str):
    cluster = run_ntp_sim(background=background, sim_seconds=15.0, outdir=outdir)
    spec = TraceSpec(sources=[
        SourceSpec(sim_type=st, path=p)
        for st, paths in sorted(cluster.log_paths().items())
        for p in paths
    ])
    return spec.run().spans


def main() -> None:
    out = os.environ.get("CASESTUDY_OUT", "results/clock_sync")
    os.makedirs(out, exist_ok=True)
    results = {}
    for bg in (False, True):
        tag = "scenario2_bg" if bg else "scenario1_base"
        spans = scenario(bg, os.path.join(out, tag))
        results[tag] = spans
        JaegerJSONExporter(os.path.join(out, f"{tag}.jaeger.json")).export(spans)

    print("=== Fig. 4: measured clock skew (ground-truth global clock) ===")
    for tag, spans in results.items():
        skew = [abs(o) for _, o in clock_offset_series(spans, "client", "server")[2:]]
        print(f"  {tag:18s} max |skew| = {max(skew):8.2f} us   mean = {statistics.mean(skew):8.2f} us")

    print("\n=== Fig. 5: chrony-estimated offsets (what the system *thinks*) ===")
    for tag, spans in results.items():
        est = [abs(o) for _, o in ntp_estimated_offsets(spans, "client")[2:]]
        print(f"  {tag:18s} max |est| = {max(est):8.2f} us   mean = {statistics.mean(est):8.2f} us")

    print("\n=== Fig. 6: where do NTP packets spend their time? (mean us per link) ===")
    for tag, spans in results.items():
        per = defaultdict(lambda: defaultdict(list))
        for s in spans:
            if s.name == "LinkTransfer" and s.attrs.get("proto") == "ntp":
                per[s.attrs.get("dir")][s.component].append(s.duration / 1e6)
        print(f"  {tag}:")
        for direction in ("req", "resp"):
            comps = {c: statistics.mean(v) for c, v in per[direction].items()}
            line = "  ".join(f"{c.split('.', 1)[1]}={v:7.1f}" for c, v in sorted(comps.items()))
            print(f"    {direction:4s}: {line}")

    print(
        "\nConclusion (paper §5): with background traffic the response direction "
        "queues on the inter-switch link while the request does not; NTP assumes "
        "symmetric paths, so the estimated offset stays plausible while the true "
        "clocks drift apart. The hardware-enriched trace makes the root cause "
        "directly visible."
    )
    print(f"\ntraces: {out}/scenario*.jaeger.json (load in Jaeger UI)")


if __name__ == "__main__":
    main()
