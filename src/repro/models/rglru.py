"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block: x -> [linear branch + gate branch] -> temporal conv (width 4) ->
RG-LRU recurrence -> output projection.

RG-LRU:   r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
          a_t = exp(-c * softplus(Lambda) * r_t)
          h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Diagonal linear recurrence -> same chunked associative scan as the SSM.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import PSpec

Params = Dict[str, Any]
_C = 8.0  # Griffin's fixed constant


def rglru_pspecs(cfg: ModelConfig) -> Params:
    d, w = cfg.d_model, cfg.resolved_lru_width
    return {
        "in_x": PSpec((d, w), ("embed", "lru"), init="lecun"),
        "in_gate": PSpec((d, w), ("embed", "lru"), init="lecun"),
        "conv_w": PSpec((cfg.conv_width, w), (None, "lru"), init="lecun"),
        "conv_b": PSpec((w,), ("lru",), init="zeros"),
        "w_a": PSpec((w, w), ("lru", None), init="lecun"),
        "w_x": PSpec((w, w), ("lru", None), init="lecun"),
        "lam": PSpec((w,), ("lru",), init="ones"),
        "out": PSpec((w, d), ("lru", "embed"), init="lecun"),
    }


def _recurrence(a: jax.Array, bx: jax.Array, h0: jax.Array, chunk: int, unroll: bool):
    """h_t = a_t h_{t-1} + bx_t over axis 1; a, bx: (B, L, w)."""
    B, L, W = a.shape
    chunk = min(chunk, L)
    n = L // chunk
    assert n * chunk == L
    a_c = a.reshape(B, n, chunk, W).transpose(1, 0, 2, 3)
    bx_c = bx.reshape(B, n, chunk, W).transpose(1, 0, 2, 3)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def step(h, inputs):
        ac, bc = inputs
        aa, hh = jax.lax.associative_scan(comb, (ac, bc), axis=1)
        hh = hh + aa * h[:, None]
        return hh[:, -1], hh

    if unroll:
        hs, h = [], h0
        for i in range(n):
            h, hh = step(h, (a_c[i], bx_c[i]))
            hs.append(hh)
        h_all = jnp.stack(hs, axis=0)
    else:
        h, h_all = jax.lax.scan(step, h0, (a_c, bx_c))
    return h_all.transpose(1, 0, 2, 3).reshape(B, L, W), h


def _gates(cfg: ModelConfig, p: Params, u: jax.Array):
    """a_t (decay) and gated input for the recurrence, in f32."""
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(u32 @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(u32 @ p["w_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u32)
    return a, gated


def rglru_block(
    cfg: ModelConfig, p: Params, x: jax.Array, chunk: int = 0, return_state: bool = False
):
    chunk = chunk or cfg.scan_chunk
    B, L, d = x.shape
    dt = x.dtype
    xs = x @ p["in_x"].astype(dt)
    gate = jax.nn.gelu(x @ p["in_gate"].astype(dt))

    w = p["conv_w"].astype(dt)
    dc = w.shape[0]
    xp = jnp.pad(xs, ((0, 0), (dc - 1, 0), (0, 0)))
    u = sum(xp[:, i : i + L, :] * w[i] for i in range(dc)) + p["conv_b"].astype(dt)

    a, gated = _gates(cfg, p, u)
    h0 = jnp.zeros((B, a.shape[-1]), jnp.float32)
    h_all, h_final = _recurrence(a, gated, h0, chunk, cfg.unroll_inner)

    y = h_all.astype(dt) * gate
    out = y @ p["out"].astype(dt)
    if return_state:
        conv_state = xs[:, L - (dc - 1) :, :] if L >= dc - 1 else jnp.pad(
            xs, ((0, 0), (dc - 1 - L, 0), (0, 0))
        )
        return out, {"conv": conv_state.astype(jnp.dtype(cfg.dtype)), "h": h_final}
    return out


# -- decode ---------------------------------------------------------------------


def rglru_state_specs(cfg: ModelConfig, batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    w, dc = cfg.resolved_lru_width, cfg.conv_width
    return {
        "conv": jax.ShapeDtypeStruct((batch, dc - 1, w), jnp.dtype(cfg.dtype)),
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
    }


def rglru_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                    # (B, 1, d)
    state: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    dt = x.dtype
    xs = x[:, 0] @ p["in_x"].astype(dt)                     # (B, w)
    gate = jax.nn.gelu(x[:, 0] @ p["in_gate"].astype(dt))

    w = p["conv_w"].astype(dt)
    window = jnp.concatenate([state["conv"].astype(dt), xs[:, None, :]], axis=1)
    u = jnp.einsum("bcw,cw->bw", window, w) + p["conv_b"].astype(dt)

    a, gated = _gates(cfg, p, u[:, None, :])
    a, gated = a[:, 0], gated[:, 0]
    h = a * state["h"] + gated

    y = h.astype(dt) * gate
    out = (y @ p["out"].astype(dt))[:, None, :]
    return out, {"conv": window[:, 1:].astype(state["conv"].dtype), "h": h}
