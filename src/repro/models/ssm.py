"""Mamba-1 selective-state-space block (falcon-mamba-7b).

Diagonal linear recurrence  h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t,
y_t = C_t · h_t + D x_t, gated by silu(z).  Training/prefill runs a chunked
scan: carry the (B, d_inner, state) state across fixed-size time chunks,
associative-scan inside each chunk (bounded activation memory).  Decode
carries (conv window, ssm state).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import PSpec

Params = Dict[str, Any]


def mamba_pspecs(cfg: ModelConfig) -> Params:
    d, di, ds, dtr, dc = (
        cfg.d_model,
        cfg.d_inner,
        cfg.ssm_state,
        cfg.resolved_dt_rank,
        cfg.ssm_conv,
    )
    return {
        "in_proj": PSpec((d, 2 * di), ("embed", "inner2"), init="lecun"),
        "conv_w": PSpec((dc, di), (None, "inner"), init="lecun"),
        "conv_b": PSpec((di,), ("inner",), init="zeros"),
        "x_proj": PSpec((di, dtr + 2 * ds), ("inner", None), init="lecun"),
        "dt_proj": PSpec((dtr, di), (None, "inner"), init="lecun"),
        "dt_bias": PSpec((di,), ("inner",), init="zeros"),
        "A_log": PSpec((di, ds), ("inner", None), init="ones"),
        "D": PSpec((di,), ("inner",), init="ones"),
        "out_proj": PSpec((di, d), ("inner", "embed"), init="lecun"),
    }


def _ssm_scan_chunked(
    u: jax.Array,          # (B, L, di)  conv+silu activations
    delta: jax.Array,      # (B, L, di)  softplus dt
    b_in: jax.Array,       # (B, L, ds)
    c_out: jax.Array,      # (B, L, ds)
    A: jax.Array,          # (di, ds)
    h0: jax.Array,         # (B, di, ds)
    chunk: int,
    unroll: bool,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked selective scan.  The (B, chunk, di, ds)-sized decay/input
    tensors are built *inside* each chunk step, so nothing O(L * di * ds)
    ever materializes — peak memory is O(chunk * di * ds) per device.
    Returns (y (B, L, di) = sum_ds h*c, final state)."""
    B, L, di = u.shape
    ds = A.shape[1]
    chunk = min(chunk, L)
    n = L // chunk
    assert n * chunk == L, (L, chunk)

    def to_chunks(x):
        return x.reshape(B, n, chunk, *x.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(u), to_chunks(delta), to_chunks(b_in), to_chunks(c_out))

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_step(h, inp):
        uc, dc, bc, cc = inp                               # (B, chunk, ...)
        a = jnp.exp(dc[..., None] * A)                     # (B, chunk, di, ds)
        bx = (dc * uc)[..., None] * bc[:, :, None, :]
        aa, hh = jax.lax.associative_scan(comb, (a, bx), axis=1)
        hh = hh + aa * h[:, None]                          # inject carry
        y = jnp.einsum("bldn,bln->bld", hh, cc)
        return hh[:, -1], y

    if unroll:
        ys, h = [], h0
        for i in range(n):
            h, y = chunk_step(h, tuple(x[i] for x in xs))
            ys.append(y)
        y_all = jnp.stack(ys, axis=0)
    else:
        h, y_all = jax.lax.scan(chunk_step, h0, xs)
    return y_all.swapaxes(0, 1).reshape(B, L, di), h


def mamba_block(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                  # (B, L, d)
    chunk: int = 0,
    return_state: bool = False,
) -> Any:
    B, L, d = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    dt = x.dtype
    chunk = chunk or cfg.scan_chunk

    xz = x @ p["in_proj"].astype(dt)
    xs, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over time
    w = p["conv_w"].astype(dt)                              # (dc, di)
    dc = w.shape[0]
    xp = jnp.pad(xs, ((0, 0), (dc - 1, 0), (0, 0)))
    conv = sum(xp[:, i : i + L, :] * w[i] for i in range(dc)) + p["conv_b"].astype(dt)
    u = jax.nn.silu(conv)

    proj = u @ p["x_proj"].astype(dt)                       # (B, L, dtr + 2 ds)
    dtr = cfg.resolved_dt_rank
    dt_in, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(dt_in @ p["dt_proj"].astype(dt) + p["dt_bias"].astype(dt))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (di, ds)

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    y, h_final = _ssm_scan_chunked(
        u.astype(jnp.float32), delta.astype(jnp.float32),
        b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32),
        A, h0, chunk, cfg.unroll_inner,
    )
    y = y.astype(dt) + u * p["D"].astype(dt)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    if return_state:
        dc = p["conv_w"].shape[0]
        conv_state = xs[:, L - (dc - 1) :, :] if L >= dc - 1 else jnp.pad(
            xs, ((0, 0), (dc - 1 - L, 0), (0, 0))
        )
        state = {"conv": conv_state.astype(jnp.dtype(cfg.dtype)), "ssm": h_final}
        return out, state
    return out


# -- decode -------------------------------------------------------------------


def mamba_state_specs(cfg: ModelConfig, batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    di, ds, dc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jax.ShapeDtypeStruct((batch, dc - 1, di), jnp.dtype(cfg.dtype)),
        "ssm": jax.ShapeDtypeStruct((batch, di, ds), jnp.float32),
    }


def mamba_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                  # (B, 1, d)
    state: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, _, d = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    dt = x.dtype

    xz = x[:, 0] @ p["in_proj"].astype(dt)                  # (B, 2di)
    xs, z = jnp.split(xz, 2, axis=-1)

    w = p["conv_w"].astype(dt)
    dc = w.shape[0]
    window = jnp.concatenate([state["conv"].astype(dt), xs[:, None, :]], axis=1)  # (B, dc, di)
    conv = jnp.einsum("bcd,cd->bd", window, w) + p["conv_b"].astype(dt)
    u = jax.nn.silu(conv)

    proj = u @ p["x_proj"].astype(dt)
    dtr = cfg.resolved_dt_rank
    dt_in, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(dt_in @ p["dt_proj"].astype(dt) + p["dt_bias"].astype(dt))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    delta32 = delta.astype(jnp.float32)
    a = jnp.exp(delta32[..., None] * A)                     # (B, di, ds)
    bx = (delta32 * u.astype(jnp.float32))[..., None] * b_ssm.astype(jnp.float32)[:, None, :]
    h = a * state["ssm"] + bx

    y = jnp.einsum("bds,bs->bd", h, c_ssm.astype(jnp.float32)).astype(dt)
    y = y + u * p["D"].astype(dt)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(dt))[:, None, :]
    return out, {"conv": window[:, 1:].astype(state["conv"].dtype), "ssm": h}
