"""Storage / checkpoint I/O workload — bulk flows contending with training.

Every non-head host runs the classic training loop **and** a serial chain
of checkpoint rounds against the storage head (the first chip-bearing
host): write rounds push ``shards × shard_bytes`` over the DCN toward the
head, read rounds (restore path) pull the same volume back.  Shards of a
round are enqueued back-to-back, so they queue behind — and delay — the
training step's gradient all-reduce chunks on the shared links: the
contention signal the paper's §5 background-traffic case study examines,
now at checkpoint scale.

Span shape: each round weaves into the existing ``Checkpoint`` span
(``ckpt_begin`` → ``ckpt_shard_write`` / ``ckpt_shard_read`` events →
``ckpt_end``) parented under whatever ``HostStep`` is open when the round
begins; the shard transfers appear as root ``LinkTransfer`` spans tagged
with their ``flow=ckpt.<host>.r<round>.s<shard>`` id.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar, Optional, TYPE_CHECKING

from ..workload import Workload, register_workload

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..cluster import ClusterOrchestrator
    from ..hostsim import HostSim


@register_workload
@dataclass
class StorageIO(Workload):
    """Training plus checkpoint write/read rounds over the shared fabric.

    Knobs beyond the standard five:

    * ``rounds``       — checkpoint rounds per non-head host (default
      ``n_steps``, so sweep size overrides scale the I/O volume too);
    * ``shards`` / ``shard_bytes`` — per-round volume (shards are enqueued
      back-to-back: maximal contention);
    * ``gap_ps``       — idle gap between a host's rounds;
    * ``read_back``    — alternate write rounds with read (restore) rounds.
    """

    workload_name: ClassVar[str] = "storage"

    rounds: Optional[int] = None
    shards: int = 4
    shard_bytes: int = 8 << 20
    gap_ps: int = 2_000_000_000           # 2 ms between rounds
    read_back: bool = True

    @property
    def total_rounds(self) -> int:
        """Effective rounds per writer host (``rounds`` or ``n_steps``)."""
        return self.rounds if self.rounds is not None else self.n_steps

    def describe(self) -> str:
        vol = self.shards * self.shard_bytes / 1e6
        return (f"storage({self.total_rounds} rounds x {vol:.0f} MB"
                f"{' rw' if self.read_back else ' w'}) + training")

    def drive(self, cluster: "ClusterOrchestrator") -> None:
        """Arm the training loop plus per-host checkpoint round chains."""
        from ..cluster import drive_training_hosts  # late: cluster imports workload

        drive_training_hosts(
            cluster, self.program, self.n_steps,
            per_host=self.start_clock_telemetry,
        )
        hosts = self.serving_hosts(cluster)
        if len(hosts) < 2:
            return                        # nothing to ship checkpoints to
        head = hosts[0]

        def run_round(h: "HostSim", r: int) -> None:
            if r >= self.total_rounds:
                return
            direction = "read" if (self.read_back and r % 2 == 1) else "write"
            h.log_event("ckpt_begin", round=r, dir=direction, shards=self.shards)
            src, dst = ((head.name, h.name) if direction == "read"
                        else (h.name, head.name))
            pending = {"n": self.shards}

            def shard_done(i: int) -> None:
                kind = "ckpt_shard_read" if direction == "read" else "ckpt_shard_write"
                h.log_event(kind, round=r, shard=i, bytes=self.shard_bytes)
                pending["n"] -= 1
                if pending["n"] == 0:
                    h.log_event("ckpt_end", round=r, dir=direction)
                    h.sim.call_after(self.gap_ps, lambda: run_round(h, r + 1))

            for i in range(self.shards):
                cluster.net.transfer(
                    src, dst, self.shard_bytes,
                    meta={"flow": f"ckpt.{h.name}.r{r}.s{i}"},
                    on_delivered=lambda _t, i=i: shard_done(i),
                )

        for i, h in enumerate(hosts[1:], 1):
            # stagger writer starts 1 us apart so round 0 of every writer
            # doesn't land on the head's links at the same instant
            h.sim.call_after(1_000_000 * i, lambda hh=h: run_round(hh, 0))
