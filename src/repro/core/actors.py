"""Predefined event-stream actors (Columbo §3.5 'building blocks').

Actors filter, modify, or enrich the type-specific event stream before it
reaches the SpanWeaver.  The paper's examples: filtering events, resolving a
function address to its name (we resolve HLO op ids to fused-op names via a
symbol table extracted from the compiled module).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .events import Event


class FilterActor:
    """Keep events satisfying a predicate."""

    def __init__(self, pred: Callable[[Event], bool]):
        self.pred = pred
        self.dropped = 0

    def process(self, ev: Event) -> Iterable[Event]:
        if self.pred(ev):
            return (ev,)
        self.dropped += 1
        return ()

    def flush(self) -> Iterable[Event]:
        return ()


class KindFilterActor(FilterActor):
    """Keep only the given event kinds (or drop them with ``exclude=True``)."""

    def __init__(self, kinds: Sequence[str], exclude: bool = False):
        kindset: Set[str] = set(kinds)
        if exclude:
            super().__init__(lambda e: e.kind not in kindset)
        else:
            super().__init__(lambda e: e.kind in kindset)


class TimeWindowActor(FilterActor):
    """Keep events with t0 <= ts < t1 (ps) — 'small subsection of the data'."""

    def __init__(self, t0: int, t1: int):
        super().__init__(lambda e: t0 <= e.ts < t1)


class SourceFilterActor(FilterActor):
    """Keep events from the named component instances only."""

    def __init__(self, sources: Sequence[str]):
        srcset = set(sources)
        super().__init__(lambda e: e.source in srcset)


class MapActor:
    """Apply fn(event) -> event | None | iterable of events."""

    def __init__(self, fn: Callable[[Event], Any]):
        self.fn = fn

    def process(self, ev: Event) -> Iterable[Event]:
        out = self.fn(ev)
        if out is None:
            return ()
        if isinstance(out, Event):
            return (out,)
        return out

    def flush(self) -> Iterable[Event]:
        return ()


class TagActor(MapActor):
    """Attach constant attributes to every event (e.g. run id, scenario)."""

    def __init__(self, **tags: Any):
        def fn(ev: Event) -> Event:
            ev.attrs.update(tags)
            return ev

        super().__init__(fn)


class SymbolizeActor:
    """Resolve ``op=<id>`` to a human name via a symbol table.

    The paper's analogue is resolving a function's address to its name; ours
    maps HLO op ids ("fusion.12") to their fused-op kind + einsum label, using
    the symbol table the device simulator dumps alongside its log.
    """

    def __init__(self, symbols: Dict[str, str], attr: str = "op", out_attr: str = "op_name"):
        self.symbols = symbols
        self.attr = attr
        self.out_attr = out_attr
        self.misses = 0

    def process(self, ev: Event) -> Iterable[Event]:
        op = ev.attrs.get(self.attr)
        if op is not None:
            name = self.symbols.get(op)
            if name is None:
                self.misses += 1
            else:
                ev.attrs[self.out_attr] = name
        return (ev,)

    def flush(self) -> Iterable[Event]:
        return ()


class RateMeterActor:
    """Pass-through that counts events/bytes — used by throughput benches."""

    def __init__(self) -> None:
        self.count = 0
        self.first_ts: Optional[int] = None
        self.last_ts: Optional[int] = None

    def process(self, ev: Event) -> Iterable[Event]:
        self.count += 1
        if self.first_ts is None:
            self.first_ts = ev.ts
        self.last_ts = ev.ts
        return (ev,)

    def flush(self) -> Iterable[Event]:
        return ()


class ReorderBufferActor:
    """Re-sorts a nearly-sorted stream within a bounded window of ps.

    Component simulators flush their logs in loose timestamp order around
    boundaries; weavers assume monotone streams per source.  This actor
    restores order with bounded memory (window must exceed the simulator's
    max log reordering).
    """

    def __init__(self, window_ps: int = 1_000_000):
        self.window = window_ps
        self._buf: List[Tuple[int, int, Event]] = []
        self._seq = 0

    def process(self, ev: Event) -> Iterable[Event]:
        import heapq

        heapq.heappush(self._buf, (ev.ts, self._seq, ev))
        self._seq += 1
        out: List[Event] = []
        while self._buf and self._buf[0][0] <= ev.ts - self.window:
            out.append(heapq.heappop(self._buf)[2])
        return out

    def flush(self) -> Iterable[Event]:
        import heapq

        out: List[Event] = []
        while self._buf:
            out.append(heapq.heappop(self._buf)[2])
        return out
