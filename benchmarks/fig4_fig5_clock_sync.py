"""Fig. 4 + Fig. 5: clock sync with/without background traffic.

Fig. 4 — measured client-server system-clock difference (ground truth via
the simulation's global clock).  Fig. 5 — chrony's own estimated offsets.
The reproduced claim: estimates look similar in both scenarios, while the
*true* skew is far worse under background traffic (path asymmetry).
"""
import statistics
import tempfile
import time


def _scenario(background: bool, seconds: float = 10.0):
    from repro.core import TraceSession, clock_offset_series, ntp_estimated_offsets
    from repro.sim import run_ntp_sim

    with tempfile.TemporaryDirectory() as d:
        cl = run_ntp_sim(background=background, sim_seconds=seconds, outdir=d)
        session = TraceSession()
        for p in cl.log_paths()["host"]:
            session.add_log(p, "host")
        for p in cl.log_paths()["net"]:
            session.add_log(p, "net")
        spans = session.run()
    skew = [o for _, o in clock_offset_series(spans, "client", "server")[2:]]
    est = [o for _, o in ntp_estimated_offsets(spans, "client")[2:]]
    return skew, est


def run():
    rows = []
    results = {}
    for bg in (False, True):
        t0 = time.perf_counter()
        skew, est = _scenario(bg)
        us = (time.perf_counter() - t0) * 1e6
        tag = "bg" if bg else "base"
        results[tag] = (skew, est)
        rows.append(
            (
                f"fig4.skew.{tag}",
                us,
                f"max_abs_us={max(abs(s) for s in skew):.2f} "
                f"mean_abs_us={statistics.mean(abs(s) for s in skew):.2f} n={len(skew)}",
            )
        )
        rows.append(
            (
                f"fig5.est.{tag}",
                us,
                f"max_abs_us={max(abs(e) for e in est):.2f} "
                f"mean_abs_us={statistics.mean(abs(e) for e in est):.2f}",
            )
        )
    ratio = max(abs(s) for s in results["bg"][0]) / max(
        1e-9, max(abs(s) for s in results["base"][0])
    )
    rows.append(("fig4.bg_over_base_skew_ratio", 0.0, f"{ratio:.1f}x (paper: >>1)"))
    return rows
