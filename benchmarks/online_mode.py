"""§3.8 online analysis: Columbo reads named pipes in parallel with the
simulation — no log persistence.  Measures streamed events/s and verifies
span output matches the offline run.
"""
import os
import tempfile
import threading
import time


def run():
    from repro.core import TraceSession, make_fifo
    from repro.sim import run_training_sim, synthetic_program

    rows = []
    prog = synthetic_program(n_layers=2, layer_flops=3e11, layer_bytes=1e8, grad_bytes=5e7)
    with tempfile.TemporaryDirectory() as d:
        names = {
            "host": [os.path.join(d, "host-host0.log")],
            "device": [os.path.join(d, "device-pod0.log")],
            "net": [os.path.join(d, "net.log")],
        }
        for ps in names.values():
            for p in ps:
                make_fifo(p)
        session = TraceSession(poll_timeout=5.0)
        for k, ps in names.items():
            for p in ps:
                session.add_log(p, k)
        t0 = time.perf_counter()
        sim_holder = {}

        def _sim():
            sim_holder["cluster"] = run_training_sim(
                prog, n_steps=2, n_pods=1, chips_per_pod=4, outdir=d
            )

        th = threading.Thread(target=_sim)
        th.start()
        spans = session.run(mode="threaded", join_timeout=60)
        th.join()
        stats = session.finalize_stats
        dt = time.perf_counter() - t0
        n_events = sum(p.events_in for p in session.pipelines)
        rows.append(
            ("online.named_pipes", dt * 1e6,
             f"{n_events/dt:,.0f} ev/s spans={len(spans)} orphans={stats['orphans']} "
             f"(no log persisted)")
        )
    return rows
