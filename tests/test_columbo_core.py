"""Unit + property tests for the Columbo core (the paper's contribution)."""
import json

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    ChromeTraceExporter,
    ColumboScript,
    ContextRegistry,
    DeviceSpanWeaver,
    HostSpanWeaver,
    IterableProducer,
    JaegerJSONExporter,
    KindFilterActor,
    OTLPJSONExporter,
    Pipeline,
    RateMeterActor,
    ReorderBufferActor,
    SimType,
    SpanContext,
    TimeWindowActor,
    assemble_traces,
    event_type_counts,
    finalize_spans,
    parser_for,
    reset_ids,
    span_type_counts,
    trace_summary,
)
from repro.core.events import HostStepBegin, HostStepEnd, OpBegin, OpEnd, ProgramEnd, ProgramStart


# ---------------------------------------------------------------------------
# Table 1 inventory
# ---------------------------------------------------------------------------


def test_event_and_span_inventory_covers_paper_table1():
    ev = event_type_counts()
    sp = span_type_counts()
    # paper Table 1: host 16/6, NIC 9/4, network 3/1 — ours must match or
    # exceed per simulator type
    assert ev["host"] >= 16 and sp["host"] >= 6
    assert ev["device"] >= 9 and sp["device"] >= 4
    assert ev["net"] >= 3 and sp["net"] >= 1


# ---------------------------------------------------------------------------
# Parsers
# ---------------------------------------------------------------------------


def test_device_parser_roundtrip():
    p = parser_for(SimType.DEVICE)
    ev = p("123: system.pod0.chip03: OpBegin: op=op7 name=layer3 flops=99 step=2")
    assert ev is not None and ev.kind == "op_begin"
    assert ev.ts == 123 and ev.source == "pod0.chip03"
    assert ev.attrs == {"op": "op7", "name": "layer3", "flops": 99, "step": 2}


def test_host_parser_roundtrip():
    p = parser_for(SimType.HOST)
    ev = p("main_time = 77: hostsim-host1: ev=dma_h2d_issue dma=d3.host1 bytes=1024")
    assert ev is not None and ev.kind == "dma_h2d_issue"
    assert ev.ts == 77 and ev.source == "host1"
    assert ev.attrs["dma"] == "d3.host1" and ev.attrs["bytes"] == 1024


def test_net_parser_marks_and_time():
    p = parser_for(SimType.NET)
    for mark, kind in [("+", "chunk_enqueue"), ("-", "chunk_tx"), ("r", "chunk_rx")]:
        ev = p(f"{mark} 0.000001000000 /IciList/pod0/l1 chunk=c1 size=64")
        assert ev is not None and ev.kind == kind
        assert ev.ts == 1_000_000  # 1 us in ps
        assert ev.source == "IciList.pod0.l1"


@given(st.text(max_size=200))
@settings(max_examples=200, deadline=None)
def test_parsers_never_crash_on_garbage(line):
    for t in SimType:
        parser_for(t)(line)  # must not raise; None or Event both fine


@given(
    st.integers(min_value=0, max_value=2**48),
    st.integers(min_value=0, max_value=99),
    st.dictionaries(
        st.from_regex(r"[a-z][a-z0-9_]{0,8}", fullmatch=True).filter(lambda s: s != "ev"),
        st.integers(min_value=-(2**31), max_value=2**31),
        max_size=4,
    ),
)
@settings(max_examples=100, deadline=None)
def test_device_parser_roundtrip_property(ts, chip, attrs):
    kv = " ".join(f"{k}={v}" for k, v in attrs.items())
    line = f"{ts}: system.pod0.chip{chip:02d}: OpBegin: {kv}"
    ev = parser_for(SimType.DEVICE)(line)
    assert ev is not None
    assert ev.ts == ts
    assert ev.attrs == attrs


# ---------------------------------------------------------------------------
# Actors / pipeline
# ---------------------------------------------------------------------------


def _mk_events(n=10, src="pod0.chip00"):
    evs = []
    for i in range(n):
        evs.append(OpBegin(ts=i * 100, source=src, attrs={"op": f"op{i}"}))
        evs.append(OpEnd(ts=i * 100 + 50, source=src, attrs={"op": f"op{i}"}))
    return evs


def test_filter_and_meter_actors():
    evs = _mk_events(10)
    meter = RateMeterActor()
    pipe = Pipeline(
        IterableProducer(evs),
        actors=[KindFilterActor(["op_begin"]), meter],
        consumer=_Collect(),
    )
    pipe.run_sync()
    assert meter.count == 10
    assert pipe.events_in == 20 and pipe.events_out == 10


def test_time_window_actor():
    evs = _mk_events(10)
    col = _Collect()
    Pipeline(IterableProducer(evs), [TimeWindowActor(200, 500)], col).run_sync()
    assert all(200 <= e.ts < 500 for e in col.events)
    assert len(col.events) == 6


@given(st.lists(st.integers(min_value=0, max_value=10_000), min_size=1, max_size=80))
@settings(max_examples=60, deadline=None)
def test_reorder_buffer_sorts_any_stream(tss):
    evs = [OpBegin(ts=t, source="c", attrs={}) for t in tss]
    col = _Collect()
    Pipeline(IterableProducer(evs), [ReorderBufferActor(window_ps=20_000)], col).run_sync()
    out = [e.ts for e in col.events]
    assert out == sorted(tss)
    assert len(out) == len(tss)


class _Collect:
    def __init__(self):
        self.events = []

    def consume(self, ev):
        self.events.append(ev)

    def on_finish(self):
        pass


# ---------------------------------------------------------------------------
# Context propagation + weaving
# ---------------------------------------------------------------------------


def test_dispatch_context_propagation_sync():
    reset_ids()
    host_events = [
        HostStepBegin(ts=0, source="host0", attrs={"step": 0}),
        _pe(10, "chip00", 0),
        _pr(500, "chip00", 0),
        HostStepEnd(ts=600, source="host0", attrs={"step": 0}),
    ]
    dev_events = [
        ProgramStart(ts=20, source="pod0.chip00", attrs={"step": 0, "program": "train_step"}),
        OpBegin(ts=30, source="pod0.chip00", attrs={"op": "op0"}),
        OpEnd(ts=40, source="pod0.chip00", attrs={"op": "op0"}),
        ProgramEnd(ts=450, source="pod0.chip00", attrs={"step": 0, "program": "train_step"}),
    ]
    script = ColumboScript()
    script.add_events(host_events, SimType.HOST)
    script.add_events(dev_events, SimType.DEVICE)
    spans = script.run()
    traces = assemble_traces(spans)
    assert len(traces) == 1, trace_summary(spans)
    t = list(traces.values())[0]
    prog = [s for s in t.spans if s.name == "DeviceProgram"][0]
    disp = [s for s in t.spans if s.name == "Dispatch"][0]
    assert prog.parent is not None and prog.parent.span_id == disp.context.span_id


def test_deferred_resolution_is_order_independent():
    """Device pipeline processed BEFORE the host pipeline pushes contexts:
    deferred resolution must still unify the trace."""
    reset_ids()
    host_events = [
        HostStepBegin(ts=0, source="host0", attrs={"step": 0}),
        _pe(10, "chip00", 0),
        _pr(500, "chip00", 0),
        HostStepEnd(ts=600, source="host0", attrs={"step": 0}),
    ]
    dev_events = [
        ProgramStart(ts=20, source="pod0.chip00", attrs={"step": 0, "program": "train_step"}),
        ProgramEnd(ts=450, source="pod0.chip00", attrs={"step": 0, "program": "train_step"}),
    ]
    script = ColumboScript()
    # add DEVICE first; run_sync honors host-first ordering, so bypass it by
    # running pipelines manually in the "wrong" order:
    p_dev = script.add_events(dev_events, SimType.DEVICE)
    p_host = script.add_events(host_events, SimType.HOST)
    p_dev.run_sync()
    p_host.run_sync()
    spans = []
    for w in script.weavers:
        spans.extend(w.spans)
    stats = finalize_spans(spans, script.registry)
    assert stats["orphans"] == 0
    assert len({s.context.trace_id for s in spans}) == 1


def _pe(ts, chip, step):
    from repro.core.events import ProgramEnqueue

    return ProgramEnqueue(ts=ts, source="host0",
                          attrs={"chip": chip, "step": step, "program": "train_step"})


def _pr(ts, chip, step):
    from repro.core.events import ProgramRetire

    return ProgramRetire(ts=ts, source="host0",
                         attrs={"chip": chip, "step": step, "program": "train_step"})


def test_finalize_rewrites_parent_trace_ids():
    reset_ids()
    reg = ContextRegistry()
    from repro.core.span import Span, new_span_id, new_trace_id

    a = Span("A", 0, 10, SpanContext(new_trace_id(), new_span_id()))
    b = Span("B", 1, 9, SpanContext(new_trace_id(), new_span_id()), parent=a.context)
    c = Span("C", 2, 8, SpanContext(new_trace_id(), new_span_id()), parent=b.context)
    finalize_spans([a, b, c], reg)
    assert a.context.trace_id == b.context.trace_id == c.context.trace_id


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------


def _tiny_spans():
    reset_ids()
    script = ColumboScript()
    script.add_events(
        [
            HostStepBegin(ts=0, source="host0", attrs={"step": 0}),
            HostStepEnd(ts=1000, source="host0", attrs={"step": 0}),
        ],
        SimType.HOST,
    )
    return script.run()


def test_jaeger_exporter_structure(tmp_path):
    spans = _tiny_spans()
    path = str(tmp_path / "t.json")
    JaegerJSONExporter(path).export(spans)
    data = json.load(open(path))
    assert data["data"] and data["data"][0]["spans"]
    s = data["data"][0]["spans"][0]
    assert {"traceID", "spanID", "operationName", "startTime", "duration",
            "processID"} <= set(s)


def test_chrome_exporter_structure(tmp_path):
    spans = _tiny_spans()
    path = str(tmp_path / "c.json")
    ChromeTraceExporter(path).export(spans)
    data = json.load(open(path))
    phases = {e["ph"] for e in data["traceEvents"]}
    assert "X" in phases and "M" in phases


def test_otlp_exporter_structure(tmp_path):
    spans = _tiny_spans()
    path = str(tmp_path / "o.json")
    OTLPJSONExporter(path).export(spans)
    data = json.load(open(path))
    sp = data["resourceSpans"][0]["scopeSpans"][0]["spans"][0]
    assert sp["endTimeUnixNano"] >= sp["startTimeUnixNano"]
